"""Model assembly: pattern-grouped layer stacks, forward passes, losses.

The forward pass iterates the config's pattern program (see
``repro.models.common``): one ``lax.scan`` per group, heterogeneous layer
kinds inside the pattern.  Three entry points:

  forward_train(cfg, params, batch)            -> loss-ready logits
  prefill(cfg, params, inputs)                 -> (last logits, caches)
  decode_step(cfg, params, caches, tok, pos)   -> (logits, new caches)

``ShardCtx`` carries mesh information; when present, activations get
sharding constraints and MoE layers run expert-parallel under shard_map.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import recurrent as rec
from repro.models.attention import (decode_attention, init_cache,
                                    prefill_attention)
from repro.models.common import LayerSpec, ModelConfig, rms_norm
from repro.models.moe import dense_ffn, moe_ffn
from repro.models.scan_utils import maybe_scan


@dataclass(frozen=True)
class ShardCtx:
    mesh: Mesh
    dp_axes: Tuple[str, ...] = ("data",)     # batch axes (may include "pod")
    tp_axis: str = "model"

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp_axis]


def _constrain(x: jax.Array, ctx: Optional[ShardCtx], spec) -> jax.Array:
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# Layer application (training / prefill form)
# ---------------------------------------------------------------------------
def _ffn_part(cfg: ModelConfig, p: Dict[str, Any], x: jax.Array,
              ctx: Optional[ShardCtx]) -> jax.Array:
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        if ctx is None:
            y = moe_ffn(cfg, _moe_params(p), h)
        else:
            m = cfg.moe
            from jax.experimental.shard_map import shard_map
            dp = ctx.dp_axes
            pspec_x = P(dp, None, None)
            especs = {
                "router": P(None, None),
                "w_gate": P(ctx.tp_axis, None, None),
                "w_up": P(ctx.tp_axis, None, None),
                "w_down": P(ctx.tp_axis, None, None),
            }
            fn = shard_map(
                functools.partial(moe_ffn, cfg, axis_name=ctx.tp_axis,
                                  axis_size=ctx.tp_size),
                mesh=ctx.mesh,
                in_specs=(especs, pspec_x),
                out_specs=pspec_x,
                check_rep=False,
            )
            y = fn(_moe_params(p), h)
    else:
        y = dense_ffn(p, h)
    return x + y


def _moe_params(p: Dict[str, Any]) -> Dict[str, Any]:
    return {k: p[k] for k in ("router", "w_gate", "w_up", "w_down")}


def _fsdp_gather(cfg: ModelConfig, p: Dict[str, Any],
                 ctx: Optional[ShardCtx]) -> Dict[str, Any]:
    """§Perf hillclimb #1: constrain weights to their FSDP-axis-free spec so
    XLA gathers the (small) weights per layer instead of all-reducing the
    (large) partial activations."""
    if ctx is None or not cfg.fsdp_gather:
        return p
    from repro.parallel.sharding import weight_compute_spec
    out = {}
    for k, v in p.items():
        if hasattr(v, "ndim") and v.ndim >= 2:
            out[k] = _constrain(v, ctx, weight_compute_spec(k, v.shape,
                                                            ctx.mesh))
        else:
            out[k] = v
    return out


def apply_layer_train(cfg: ModelConfig, spec: LayerSpec, p: Dict[str, Any],
                      x: jax.Array, positions: jax.Array,
                      ctx: Optional[ShardCtx]) -> jax.Array:
    p = _fsdp_gather(cfg, p, ctx)
    if spec.kind == "attn":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        attn_out, _ = prefill_attention(cfg, p, h, spec.window, positions,
                                        ctx=ctx)
        x = x + attn_out
        x = _ffn_part(cfg, p, x, ctx)
    elif spec.kind == "mlstm":
        x = rec.mlstm_block(cfg, p, x)
    elif spec.kind == "slstm":
        x = rec.slstm_block(cfg, p, x)
    elif spec.kind == "rglru":
        x = rec.rglru_block(cfg, p, x)
        if spec.has_ffn:
            x = _ffn_part(cfg, p, x, ctx)
    else:
        raise ValueError(spec.kind)
    return x


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)


def apply_groups_train(cfg: ModelConfig, params: Dict[str, Any], x: jax.Array,
                       positions: jax.Array, ctx: Optional[ShardCtx]) -> jax.Array:
    for gi, (pattern, reps) in enumerate(cfg.blocks):
        stacked = params["groups"][gi]

        def body(xc, layer_params, pattern=pattern):
            for spec, p in zip(pattern, layer_params):
                xc = apply_layer_train(cfg, spec, p, xc, positions, ctx)
            return xc, None

        body = _remat(cfg, body) if cfg.remat != "none" else body
        if reps == 1:
            x, _ = body(x, jax.tree.map(lambda a: a[0], stacked))
        else:
            x, _ = maybe_scan(body, x, stacked, length=reps)
    return x


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------
def embed(cfg: ModelConfig, params, tokens_or_embeds: jax.Array,
          ctx: Optional[ShardCtx]) -> jax.Array:
    if tokens_or_embeds.dtype in (jnp.int32, jnp.int64):
        table = params["embed"]
        if ctx is not None and cfg.fsdp_gather:
            from repro.parallel.sharding import weight_compute_spec
            table = _constrain(table, ctx,
                               weight_compute_spec("embed", table.shape,
                                                   ctx.mesh))
        x = table[tokens_or_embeds] * (cfg.d_model ** 0.5)
        x = x.astype(cfg.jdtype())
    else:
        x = tokens_or_embeds.astype(cfg.jdtype())   # frontend stub: embeddings
    if ctx is not None:
        x = _constrain(x, ctx, P(ctx.dp_axes, None, None))
    return x


def logits_fn(cfg: ModelConfig, params, x: jax.Array,
              ctx: Optional[ShardCtx]) -> jax.Array:
    x = rms_norm(x, params["out_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        emb = params["embed"]
        if ctx is not None and cfg.fsdp_gather:
            from repro.parallel.sharding import weight_compute_spec
            emb = _constrain(emb, ctx,
                             weight_compute_spec("embed", emb.shape, ctx.mesh))
        head = emb.T
    logits = x @ head.astype(x.dtype)
    if cfg.logits_fp32:
        logits = logits.astype(jnp.float32)
    if ctx is not None:
        logits = _constrain(logits, ctx, P(ctx.dp_axes, None, ctx.tp_axis))
    return logits


def forward_train(cfg: ModelConfig, params, inputs: jax.Array,
                  ctx: Optional[ShardCtx] = None) -> jax.Array:
    """inputs: (B, S) int tokens or (B, S, D) frontend embeddings."""
    B, S = inputs.shape[:2]
    x = embed(cfg, params, inputs, ctx)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = apply_groups_train(cfg, params, x, positions, ctx)
    return logits_fn(cfg, params, x, ctx)


def lm_loss(cfg: ModelConfig, params, inputs: jax.Array, targets: jax.Array,
            ctx: Optional[ShardCtx] = None) -> jax.Array:
    logits = forward_train(cfg, params, inputs, ctx)
    # fused stable CE: exp/log temps fuse into the vocab reductions — no
    # materialized fp32 (B,S,V) copy (matters at 262k vocab)
    lmax = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = (logits - lmax).astype(jnp.float32)
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    tgt = jnp.take_along_axis(shifted, targets[..., None].astype(jnp.int32),
                              axis=-1)[..., 0]
    return jnp.mean(lse - tgt)


# ---------------------------------------------------------------------------
# Caches / decode
# ---------------------------------------------------------------------------
def init_layer_state(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_seq: int):
    if spec.kind == "attn":
        return init_cache(cfg, spec.window, batch, max_seq, cfg.jdtype())
    if spec.kind == "mlstm":
        return rec.mlstm_init_state(cfg, batch)
    if spec.kind == "slstm":
        return rec.slstm_init_state(cfg, batch)
    if spec.kind == "rglru":
        return rec.rglru_init_state(cfg, batch)
    raise ValueError(spec.kind)


def init_caches(cfg: ModelConfig, batch: int, max_seq: int):
    """Nested (per group, per pattern position) stacked caches."""
    groups = []
    for pattern, reps in cfg.blocks:
        per_pos = []
        for spec in pattern:
            one = init_layer_state(cfg, spec, batch, max_seq)
            per_pos.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a, (reps,) + a.shape), one))
        groups.append(tuple(per_pos))
    return tuple(groups)


def apply_layer_decode(cfg: ModelConfig, spec: LayerSpec, p, x, cache,
                       position, ctx):
    p = _fsdp_gather(cfg, p, ctx)
    if spec.kind == "attn":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        attn_out, new_cache = decode_attention(cfg, p, h, cache, position)
        x = x + attn_out
        x = _ffn_part(cfg, p, x, ctx)
        return x, new_cache
    if spec.kind == "mlstm":
        return rec.mlstm_step(cfg, p, x, cache)
    if spec.kind == "slstm":
        return rec.slstm_step(cfg, p, x, cache)
    if spec.kind == "rglru":
        x, st = rec.rglru_step(cfg, p, x, cache)
        if spec.has_ffn:
            x = _ffn_part(cfg, p, x, ctx)
        return x, st
    raise ValueError(spec.kind)


def decode_step(cfg: ModelConfig, params, caches, tokens: jax.Array,
                position: jax.Array, ctx: Optional[ShardCtx] = None):
    """tokens: (B,) int32; position: scalar int32. Returns (logits, caches)."""
    x = embed(cfg, params, tokens[:, None], ctx)
    new_groups = []
    for gi, (pattern, reps) in enumerate(cfg.blocks):
        stacked = params["groups"][gi]
        caches_g = caches[gi]

        def body(xc, xs, pattern=pattern):
            layer_params, layer_caches = xs
            new_lc = []
            for spec, p, c in zip(pattern, layer_params, layer_caches):
                xc, nc = apply_layer_decode(cfg, spec, p, xc, c, position, ctx)
                new_lc.append(nc)
            return xc, tuple(new_lc)

        if reps == 1:
            x, ncs = body(x, (jax.tree.map(lambda a: a[0], stacked),
                              jax.tree.map(lambda a: a[0], caches_g)))
            ncs = jax.tree.map(lambda a: a[None], ncs)
        else:
            x, ncs = maybe_scan(body, x, (stacked, caches_g), length=reps)
        new_groups.append(ncs)
    logits = logits_fn(cfg, params, x, ctx)
    return logits[:, 0], tuple(new_groups)


def prefill(cfg: ModelConfig, params, inputs: jax.Array,
            ctx: Optional[ShardCtx] = None, max_seq: Optional[int] = None):
    """Run the full prompt, building caches.  Returns (last logits, caches).

    inputs: (B, S) tokens or (B, S, D) embeddings.
    """
    B, S = inputs.shape[:2]
    max_seq = max_seq or S
    x = embed(cfg, params, inputs, ctx)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    new_groups = []
    for gi, (pattern, reps) in enumerate(cfg.blocks):
        stacked = params["groups"][gi]

        def body(xc, layer_params, pattern=pattern):
            new_lc = []
            for spec, p in zip(pattern, layer_params):
                xc, st = apply_layer_prefill(cfg, spec, p, xc, positions,
                                             max_seq, ctx)
                new_lc.append(st)
            return xc, tuple(new_lc)

        if reps == 1:
            x, ncs = body(x, jax.tree.map(lambda a: a[0], stacked))
            ncs = jax.tree.map(lambda a: a[None], ncs)
        else:
            x, ncs = maybe_scan(body, x, stacked, length=reps)
        new_groups.append(ncs)
    logits = logits_fn(cfg, params, x[:, -1:], ctx)
    return logits[:, 0], tuple(new_groups)


def apply_layer_prefill(cfg: ModelConfig, spec: LayerSpec, p, x, positions,
                        max_seq, ctx):
    p = _fsdp_gather(cfg, p, ctx)
    B, S = x.shape[:2]
    if spec.kind == "attn":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        cache = init_cache(cfg, spec.window, B, max_seq, cfg.jdtype())
        attn_out, new_cache = prefill_attention(cfg, p, h, spec.window,
                                                positions, cache, ctx=ctx)
        x = x + attn_out
        x = _ffn_part(cfg, p, x, ctx)
        return x, new_cache
    if spec.kind == "mlstm":
        return rec.mlstm_block(cfg, p, x, return_state=True)
    if spec.kind == "slstm":
        return rec.slstm_block(cfg, p, x, return_state=True)
    if spec.kind == "rglru":
        x, st = rec.rglru_block(cfg, p, x, return_state=True)
        if spec.has_ffn:
            x = _ffn_part(cfg, p, x, ctx)
        return x, st
    raise ValueError(spec.kind)
