"""Attention substrate: GQA, RoPE, qk-norm, sliding-window / global layers.

Three execution paths, chosen by shape and window:

* **banded** (window layers, train/prefill): the sequence is chunked at the
  window size and each query chunk attends to exactly two key chunks (its own
  and the previous one) gathered into a banded tensor — one einsum, no scan,
  true O(S·w) FLOPs.  This is the pure-JAX analog of the Pallas
  sliding-window kernel in ``repro.kernels.swa`` (the dry-run compiles this
  path; the kernel is the TPU-target implementation).
* **chunked-full** (global layers, train/prefill): scan over query chunks,
  full einsum against all keys per chunk — O(S²) FLOPs, O(S·chunk) memory.
* **decode**: one query token against a KV cache; window layers use a ring
  buffer of size w (O(w) per token), global layers read the full cache
  (O(S) per token).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, rms_norm, rope

NEG_INF = -1e30


def _split_heads(x: jax.Array, n: int, dh: int) -> jax.Array:
    return x.reshape(*x.shape[:-1], n, dh)


def qkv_project(cfg: ModelConfig, p: Dict[str, Any], x: jax.Array,
                positions: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, S, D) -> q (B,S,H,dh), k/v (B,S,KV,dh) with RoPE + qk-norm."""
    q = _split_heads(x @ p["wq"], cfg.n_heads, cfg.dh)
    k = _split_heads(x @ p["wk"], cfg.n_kv_heads, cfg.dh)
    v = _split_heads(x @ p["wv"], cfg.n_kv_heads, cfg.dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
    k = rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    return q, k, v


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (..., Sq, KV, G, dh), k: (..., Sk, KV, dh) -> (..., KV, G, Sq, Sk)."""
    return jnp.einsum("...qkgd,...skd->...kgqs", q, k,
                      preferred_element_type=jnp.float32)


def _gqa_context(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs: (..., KV, G, Sq, Sk), v: (..., Sk, KV, dh) -> (..., Sq, KV, G, dh)."""
    return jnp.einsum("...kgqs,...skd->...qkgd", probs, v)


def full_attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                           q_chunk: int) -> jax.Array:
    """Causal full attention, scanned over query chunks (O(S·c) memory)."""
    B, S, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = dh ** -0.5
    c = min(q_chunk, S)
    if S % c != 0:
        pad = c - S % c
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = q.shape[1] // c
    qc = q.reshape(B, nq, c, KV, G, dh)
    kpos = jnp.arange(S)

    def body(_, xs):
        i, qi = xs                                     # qi: (B, c, KV, G, dh)
        s = _gqa_scores(qi, k) * scale                 # (B, KV, G, c, S)
        qpos = i * c + jnp.arange(c)
        mask = kpos[None, :] <= qpos[:, None]          # causal
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        probs = jax.nn.softmax(s, axis=-1)
        out = _gqa_context(probs.astype(v.dtype), v)   # (B, c, KV, G, dh)
        return None, out

    if nq == 1:
        _, out = body(None, (jnp.int32(0), qc[:, 0]))
        outs = out[:, None]
    else:
        _, outs = jax.lax.scan(body, None,
                               (jnp.arange(nq), jnp.moveaxis(qc, 1, 0)))
        outs = jnp.moveaxis(outs, 0, 1)
    out = outs.reshape(B, nq * c, H, dh)[:, :S]
    return out


def banded_window_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                            window: int) -> jax.Array:
    """Sliding-window causal attention in one einsum (no scan).

    Chunk size = window; each query chunk attends to [prev chunk ‖ own
    chunk], masked to the causal window.  FLOPs: 2·S·2w·H·dh per matmul —
    truly sub-quadratic.
    """
    B, S, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = dh ** -0.5
    c = window
    pad = (-S) % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nq = Sp // c
    qc = q.reshape(B, nq, c, KV, G, dh)
    # banded keys/values: [chunk i-1 ‖ chunk i] for each chunk i
    kprev = jnp.pad(k, ((0, 0), (c, 0), (0, 0), (0, 0)))[:, :-c]
    kc = jnp.concatenate([kprev.reshape(B, nq, c, KV, dh),
                          k.reshape(B, nq, c, KV, dh)], axis=2)  # (B,nq,2c,KV,dh)
    vprev = jnp.pad(v, ((0, 0), (c, 0), (0, 0), (0, 0)))[:, :-c]
    vc = jnp.concatenate([vprev.reshape(B, nq, c, KV, dh),
                          v.reshape(B, nq, c, KV, dh)], axis=2)
    s = jnp.einsum("bnqkgd,bnskd->bnkgqs", qc, kc,
                   preferred_element_type=jnp.float32) * scale
    # relative mask: key global pos = (n-1)c + s_idx; query = n·c + q_idx
    qi = jnp.arange(c)[:, None]
    si = jnp.arange(2 * c)[None, :]
    delta = (c + qi) - si                 # q_pos - k_pos
    band = (delta >= 0) & (delta < window)
    # first chunk's "previous" keys are padding — mask them out
    nvalid = jnp.arange(nq)[:, None, None] > 0
    valid = band[None] & (nvalid | (si[None] >= c))
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bnkgqs,bnskd->bnqkgd", probs.astype(vc.dtype), vc)
    return out.reshape(B, Sp, H, dh)[:, :S]


def attention_train(cfg: ModelConfig, q, k, v, window: Optional[int]) -> jax.Array:
    B, S, H, dh = q.shape
    if window is not None and S > window:
        return banded_window_attention(q, k, v, window)
    return full_attention_chunked(q, k, v, cfg.q_chunk)


# ---------------------------------------------------------------------------
# Decode (single-token) attention with KV caches
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, spec_window: Optional[int], batch: int,
               max_seq: int, dtype) -> Dict[str, jax.Array]:
    """KV cache for one attention layer (unstacked).

    Window layers use a ring buffer of size ``window`` with per-slot global
    positions; global layers use the full sequence buffer.
    """
    size = min(spec_window, max_seq) if spec_window else max_seq
    return {
        "k": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.dh), dtype),
        "v": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.dh), dtype),
        "pos": jnp.full((size,), -1, jnp.int32),   # global position per slot
    }


def decode_attention(cfg: ModelConfig, p: Dict[str, Any], x: jax.Array,
                     cache: Dict[str, jax.Array], position: jax.Array
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, 1, D); returns (context (B,1,H*dh), new cache)."""
    B = x.shape[0]
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    G = H // KV
    pos1 = jnp.full((B, 1), position, jnp.int32)
    q, k_new, v_new = qkv_project(cfg, p, x, pos1)
    size = cache["k"].shape[1]
    slot = position % size
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
    pos = jax.lax.dynamic_update_slice(cache["pos"],
                                       position[None].astype(jnp.int32), (slot,))
    qg = q.reshape(B, 1, KV, G, dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * (dh ** -0.5)
    valid = (pos >= 0) & (pos <= position)
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    ctx = ctx.reshape(B, 1, H * dh)
    return ctx @ p["wo"], {"k": k, "v": v, "pos": pos}


def prefill_attention(cfg: ModelConfig, p: Dict[str, Any], x: jax.Array,
                      window: Optional[int], positions: jax.Array,
                      cache: Optional[Dict[str, jax.Array]] = None,
                      ctx=None) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Training/prefill attention; fills the cache if given.

    With ``cfg.attn_head_shard`` (§Perf #2): K/V are expanded to H heads and
    q/k/v constrained head-sharded on the TP axis, so every attention einsum
    contracts only local dims — replacing per-chunk fp32-score all-reduces
    with the single standard TP all-reduce after the output projection.
    """
    q, k, v = qkv_project(cfg, p, x, positions)
    k_store, v_store = k, v
    if ctx is not None and cfg.attn_head_shard:
        from jax.sharding import NamedSharding, PartitionSpec as P
        tp = ctx.mesh.shape[ctx.tp_axis]
        if cfg.n_heads % tp == 0:
            G = cfg.n_heads // cfg.n_kv_heads
            if G > 1:
                k = jnp.repeat(k, G, axis=2)
                v = jnp.repeat(v, G, axis=2)
            spec = NamedSharding(ctx.mesh,
                                 P(ctx.dp_axes, None, ctx.tp_axis, None))
            q = jax.lax.with_sharding_constraint(q, spec)
            k = jax.lax.with_sharding_constraint(k, spec)
            v = jax.lax.with_sharding_constraint(v, spec)
    out = attention_train(cfg, q, k, v, window)
    B, S = x.shape[:2]
    new_cache = None
    if cache is not None:
        k, v = k_store, v_store
        size = cache["k"].shape[1]
        if size >= S:
            new_cache = {
                "k": jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0)),
                "pos": jax.lax.dynamic_update_slice(
                    cache["pos"], jnp.arange(S, dtype=jnp.int32), (0,)),
            }
        else:  # ring buffer smaller than the prefill: keep the tail
            tail_k = k[:, -size:]
            tail_v = v[:, -size:]
            tail_p = jnp.arange(S - size, S, dtype=jnp.int32)
            # ring alignment: global position p lives in slot p % size
            roll = (S - size) % size
            new_cache = {
                "k": jnp.roll(tail_k, shift=roll, axis=1),
                "v": jnp.roll(tail_v, shift=roll, axis=1),
                "pos": jnp.roll(tail_p, shift=roll, axis=0),
            }
    out = out.reshape(B, S, cfg.n_heads * cfg.dh)
    return out @ p["wo"], new_cache
