"""Model substrate: configs, parameter initialization, shared layers.

Every assigned architecture is described by a :class:`ModelConfig` whose
``blocks`` field is a *pattern program*: a list of (pattern, repeats) groups,
where a pattern is a tuple of :class:`LayerSpec`s.  The forward pass scans
over ``repeats`` within each group (one compiled body per group), which keeps
HLO size O(#distinct layer kinds) instead of O(#layers) — essential for the
512-device dry-run compiles — while supporting heterogeneous stacks
(gemma3's 5:1 local:global, recurrentgemma's 1:2 RG-LRU:attention,
xLSTM's mLSTM/sLSTM alternation).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Layer / model configuration
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class LayerSpec:
    """One layer in the pattern program."""
    kind: str                   # "attn" | "mlstm" | "slstm" | "rglru"
    window: Optional[int] = None   # attention window (None = full/causal)
    has_ffn: bool = True           # xLSTM blocks carry their own projections


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    blocks: Tuple[Tuple[Tuple[LayerSpec, ...], int], ...] = ()
    moe: Optional[MoEConfig] = None
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0   # chatglm3: rotary on half the head dim ("2d")
    qk_norm: bool = False        # qwen3
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    # modality frontend stub: inputs are precomputed embeddings, not token ids
    frontend: Optional[str] = None   # None | "audio" | "vlm"
    max_seq: int = 131_072
    # --- runtime / performance knobs (hillclimbed in §Perf) ---
    remat: str = "full"          # "none" | "dots" | "full"
    q_chunk: int = 512
    kv_chunk: int = 1024
    mlstm_chunk: int = 256
    logits_fp32: bool = False
    attest: bool = True          # fingerprint grads/params each step (uBFT)
    fsdp_gather: bool = False    # ZeRO-3 per-layer weight gather (§Perf #1)
    attn_head_shard: bool = False  # expand KV to H heads + shard heads (§Perf #2)

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def jdtype(self):
        return jnp.dtype(self.dtype)

    def layer_list(self) -> List[LayerSpec]:
        out: List[LayerSpec] = []
        for pattern, reps in self.blocks:
            out.extend(list(pattern) * reps)
        return out

    def validate(self) -> None:
        assert len(self.layer_list()) == self.n_layers, (
            f"{self.name}: pattern program has {len(self.layer_list())} "
            f"layers, config says {self.n_layers}")


def default_blocks(n_layers: int) -> Tuple:
    """Uniform full-attention stack."""
    return (((LayerSpec("attn"),), n_layers),)


def params_count(tree: Any) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# Shared primitives
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float,
         fraction: float = 1.0) -> jax.Array:
    """Rotary embedding over the leading ``fraction`` of the head dim.

    x: (..., S, H, dh); positions: (..., S) int32.
    chatglm3's "RoPE 2d" applies rotary to half the dimensions.
    """
    dh = x.shape[-1]
    rot = int(dh * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    ang = ang[..., None, :]                                  # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------
def _init(key, shape, scale, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_layer_params(cfg: ModelConfig, spec: LayerSpec, key) -> Dict[str, Any]:
    """Parameters for one layer of the given kind (unstacked)."""
    D, dh = cfg.d_model, cfg.dh
    H, KV = cfg.n_heads, cfg.n_kv_heads
    dt = cfg.jdtype()
    ks = jax.random.split(key, 16)
    s_in = D ** -0.5
    p: Dict[str, Any] = {"ln1": jnp.zeros((D,), dt)}

    if spec.kind == "attn":
        p["wq"] = _init(ks[0], (D, H * dh), s_in, dt)
        p["wk"] = _init(ks[1], (D, KV * dh), s_in, dt)
        p["wv"] = _init(ks[2], (D, KV * dh), s_in, dt)
        p["wo"] = _init(ks[3], (H * dh, D), (H * dh) ** -0.5, dt)
        if cfg.qk_norm:
            p["q_norm"] = jnp.zeros((dh,), dt)
            p["k_norm"] = jnp.zeros((dh,), dt)
    elif spec.kind == "mlstm":
        # matrix-LSTM: q/k/v + input/forget gates, per-head matrix memory
        p["wq"] = _init(ks[0], (D, H * dh), s_in, dt)
        p["wk"] = _init(ks[1], (D, H * dh), s_in, dt)
        p["wv"] = _init(ks[2], (D, H * dh), s_in, dt)
        p["wi"] = _init(ks[4], (D, H), s_in, dt)
        p["wf"] = _init(ks[5], (D, H), s_in, dt)
        p["bf"] = jnp.full((H,), 3.0, dt)   # forget bias: remember by default
        p["wo"] = _init(ks[3], (H * dh, D), (H * dh) ** -0.5, dt)
        p["up"] = _init(ks[6], (D, 2 * D), s_in, dt)   # block up-projection
        p["down"] = _init(ks[7], (D, D), D ** -0.5, dt)
    elif spec.kind == "slstm":
        # scalar-LSTM with exponential gating (recurrent weights diagonal-
        # block approximated by per-head dense)
        p["wz"] = _init(ks[0], (D, D), s_in, dt)
        p["wi"] = _init(ks[1], (D, D), s_in, dt)
        p["wf"] = _init(ks[2], (D, D), s_in, dt)
        p["wo_gate"] = _init(ks[4], (D, D), s_in, dt)
        p["rz"] = _init(ks[5], (cfg.n_heads, cfg.d_model // cfg.n_heads,
                                cfg.d_model // cfg.n_heads), s_in, dt)
        p["wo"] = _init(ks[3], (D, D), D ** -0.5, dt)
        p["up"] = _init(ks[6], (D, 2 * D), s_in, dt)
        p["down"] = _init(ks[7], (D, D), D ** -0.5, dt)
    elif spec.kind == "rglru":
        # RG-LRU (RecurrentGemma): conv1d + gated linear recurrence at
        # lru_width = d_model; the MLP lives in the shared FFN part below
        W = D
        p["w_in"] = _init(ks[0], (D, 2 * W), s_in, dt)   # x and gate
        p["conv"] = _init(ks[1], (4, W), 0.1, dt)
        p["wa"] = _init(ks[2], (W, W), W ** -0.5, dt)
        p["wx"] = _init(ks[4], (W, W), W ** -0.5, dt)
        p["lam"] = _init(ks[5], (W,), 1.0, jnp.float32)  # recurrence gate param
        p["w_out"] = _init(ks[3], (W, D), W ** -0.5, dt)
    else:
        raise ValueError(spec.kind)

    if spec.has_ffn and spec.kind in ("attn", "rglru"):
        p["ln2"] = jnp.zeros((D,), dt)
        if cfg.moe is not None:
            m = cfg.moe
            p["router"] = _init(ks[8], (D, m.n_experts), s_in, jnp.float32)
            p["w_gate"] = _init(ks[9], (m.n_experts, D, m.d_expert), s_in, dt)
            p["w_up"] = _init(ks[10], (m.n_experts, D, m.d_expert), s_in, dt)
            p["w_down"] = _init(ks[11], (m.n_experts, m.d_expert, D),
                                m.d_expert ** -0.5, dt)
        else:
            p["w_gate"] = _init(ks[9], (D, cfg.d_ff), s_in, dt)
            p["w_up"] = _init(ks[10], (D, cfg.d_ff), s_in, dt)
            p["w_down"] = _init(ks[11], (cfg.d_ff, D), cfg.d_ff ** -0.5, dt)
    return p


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    """Full parameter pytree with per-group stacked layer params."""
    cfg.validate()
    dt = cfg.jdtype()
    k_emb, k_out, k_layers = jax.random.split(key, 3)
    params: Dict[str, Any] = {
        "embed": _init(k_emb, (cfg.vocab, cfg.d_model), cfg.d_model ** -0.5, dt),
        "out_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _init(k_out, (cfg.d_model, cfg.vocab),
                                  cfg.d_model ** -0.5, dt)
    groups = []
    kg = k_layers
    for gi, (pattern, reps) in enumerate(cfg.blocks):
        kg, kp = jax.random.split(kg)
        # stack `reps` copies of each pattern position
        stacked = []
        for li, spec in enumerate(pattern):
            keys = jax.random.split(jax.random.fold_in(kp, li), reps)
            per = [init_layer_params(cfg, spec, keys[r]) for r in range(reps)]
            stacked.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))
        groups.append(tuple(stacked))
    params["groups"] = tuple(groups)
    return params
