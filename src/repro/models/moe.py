"""Mixture-of-Experts FFN with expert parallelism.

Strategy (default, "EP-as-TP"): activations are replicated across the
``model`` mesh axis (as they already are for tensor parallelism); experts are
sharded across it.  Every device routes the full local token set, computes
*its* experts' contributions through a sort-based fixed-capacity dispatch
(no (T, E, C) one-hot — O(T·k) memory), and the contributions are combined
with the same all-reduce that tensor parallelism already pays.  For top-k≥2
this moves strictly fewer bytes than a token all-to-all (2·D vs k·D per
token) and composes with XLA's collective fusion; the all-to-all variant is
kept as a hillclimb alternative (see EXPERIMENTS.md §Perf).

The routed computation is ragged; we use fixed per-expert capacity
C = max(min_cap, ceil(T·k/E · capacity_factor)) with token dropping
(standard dropping MoE), realized with scatter(mode="drop") /
gather(mode="fill").
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig


def _capacity(T: int, k: int, E: int, cf: float) -> int:
    return max(min(T, 32), int(math.ceil(T * k / E * cf)))


def route(cfg: ModelConfig, router_w: jax.Array, x: jax.Array
          ) -> Tuple[jax.Array, jax.Array]:
    """x: (T, D) -> (weights (T,k), experts (T,k)); deterministic."""
    m = cfg.moe
    logits = x.astype(jnp.float32) @ router_w          # (T, E)
    top_w, top_e = jax.lax.top_k(logits, m.top_k)
    top_w = jax.nn.softmax(top_w, axis=-1)
    return top_w, top_e


def moe_ffn_local(cfg: ModelConfig, p: Dict[str, Any], x: jax.Array,
                  e0: int, e_local: int) -> jax.Array:
    """MoE FFN over the expert slice [e0, e0+e_local).

    x: (T, D); expert weights in ``p`` are the *local* slices
    (e_local, D, F)/(e_local, F, D).  Returns this slice's contribution
    (T, D) — caller psums across the expert-sharding axis.
    """
    m = cfg.moe
    T, D = x.shape
    k = m.top_k
    C = _capacity(T, k, m.n_experts, m.capacity_factor)

    top_w, top_e = route(cfg, p["router"], x)
    flat_e = top_e.reshape(-1)                       # (T·k,)
    flat_w = top_w.reshape(-1).astype(x.dtype)
    flat_tok = jnp.repeat(jnp.arange(T), k)

    le = flat_e - e0
    mine = (le >= 0) & (le < e_local)
    key = jnp.where(mine, le, e_local)               # sentinel = not mine
    order = jnp.argsort(key, stable=True)
    key_s = key[order]
    tok_s = flat_tok[order]
    w_s = flat_w[order]
    # position within each expert's segment (sorted, so first-occurrence math)
    first = jnp.searchsorted(key_s, key_s, side="left")
    seg_pos = jnp.arange(T * k) - first
    keep = (key_s < e_local) & (seg_pos < C)
    dest = jnp.where(keep, key_s * C + seg_pos, e_local * C)  # overflow slot

    buf = jnp.zeros((e_local * C, D), x.dtype)
    buf = buf.at[dest].set(x[tok_s], mode="drop")
    buf = buf.reshape(e_local, C, D)

    h = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    act = jax.nn.silu(h) * u
    y = jnp.einsum("ecf,efd->ecd", act, p["w_down"]).reshape(e_local * C, D)

    rows = y.at[dest].get(mode="fill", fill_value=0)  # (T·k, D) gathered back
    contrib = rows * (w_s * keep.astype(x.dtype))[:, None]
    out = jnp.zeros((T, D), x.dtype).at[tok_s].add(contrib)
    return out


def dense_ffn(p: Dict[str, Any], x: jax.Array) -> jax.Array:
    """SwiGLU FFN. x: (..., D)."""
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


def moe_ffn(cfg: ModelConfig, p: Dict[str, Any], x: jax.Array,
            axis_name: Optional[str] = None, axis_size: int = 1) -> jax.Array:
    """MoE FFN over (B, S, D) activations.

    When ``axis_name`` is given (inside shard_map), experts are sharded over
    that axis: ``p``'s expert tensors are local slices and the result is
    psummed.  Without it (CPU smoke tests), all experts are local.
    ``axis_size`` must be the static mesh-axis size.
    """
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    m = cfg.moe
    if axis_name is None:
        out = moe_ffn_local(cfg, p, xt, 0, m.n_experts)
    else:
        e_local = m.n_experts // axis_size
        e0 = jax.lax.axis_index(axis_name) * e_local
        out = moe_ffn_local(cfg, p, xt, e0, e_local)
        out = jax.lax.psum(out, axis_name)
    return out.reshape(B, S, D)
