"""Scan utilities + cost-mode switch.

``compiled.cost_analysis()`` counts a while-loop body ONCE, regardless of
trip count (verified empirically — see EXPERIMENTS.md §Dry-run methodology).
The dry-run therefore compiles every cell twice:

* **run mode** (default): inner loops are ``lax.scan`` — small HLO, fast
  512-device compiles, faithful ``memory_analysis()``;
* **cost mode** (``cost_mode()`` context): inner loops unroll via Python so
  a *standalone one-layer body* compile yields exact per-layer FLOPs/bytes/
  collective counts, which the costing driver multiplies by the statically
  known trip counts (layer-group repeats, chunk counts, time steps).
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

_COST_MODE = contextvars.ContextVar("repro_cost_mode", default=False)


@contextlib.contextmanager
def cost_mode():
    tok = _COST_MODE.set(True)
    try:
        yield
    finally:
        _COST_MODE.reset(tok)


def in_cost_mode() -> bool:
    return _COST_MODE.get()


def maybe_scan(body: Callable, init: Any, xs: Any, length: Optional[int] = None):
    """lax.scan in run mode; exact Python unroll in cost mode."""
    if not in_cost_mode():
        return jax.lax.scan(body, init, xs, length=length)
    n = length if length is not None else jax.tree.leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs) if xs is not None else None
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys
