"""Recurrent blocks: xLSTM (mLSTM + sLSTM) and RG-LRU (RecurrentGemma).

Training forms:
* **mLSTM** — chunkwise-parallel form (the xLSTM paper's training mode):
  intra-chunk attention-like einsums + an inter-chunk recurrence over the
  per-head matrix memory C ∈ R^{dh×dh}.  Linear in S.
* **sLSTM** — inherently sequential (recurrent gate connections); scanned
  over time with input projections hoisted out of the loop.  Linear in S.
* **RG-LRU** — gated linear recurrence computed with
  ``jax.lax.associative_scan`` (log-depth, no while loop → exact
  cost_analysis) + short conv1d, per RecurrentGemma.

Decode forms: single-step state updates; state replaces the KV cache.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, rms_norm
from repro.models.scan_utils import maybe_scan


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def _mlstm_gates(cfg, p, x):
    """Returns (q, k, v, i_tilde, f_tilde) for x: (B, S, D)."""
    B, S, D = x.shape
    H, dh = cfg.n_heads, cfg.dh
    q = (x @ p["wq"]).reshape(B, S, H, dh) * (dh ** -0.5)
    k = (x @ p["wk"]).reshape(B, S, H, dh) * (dh ** -0.5)
    v = (x @ p["wv"]).reshape(B, S, H, dh)
    it = (x @ p["wi"]).astype(jnp.float32)                  # (B, S, H)
    ft = (x @ p["wf"]).astype(jnp.float32) + p["bf"].astype(jnp.float32)
    return q, k, v, it, ft


def mlstm_train(cfg: ModelConfig, p: Dict[str, Any], x: jax.Array,
                chunk: int = 256) -> jax.Array:
    """Chunkwise-parallel mLSTM. x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    H, dh = cfg.n_heads, cfg.dh
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // c
    q, k, v, it, ft = _mlstm_gates(cfg, p, x)
    # reshape into chunks: (B, nc, c, H, ...)
    qc = q.reshape(B, nc, c, H, dh)
    kc = k.reshape(B, nc, c, H, dh)
    vc = v.reshape(B, nc, c, H, dh)
    itc = it.reshape(B, nc, c, H)
    ftc = ft.reshape(B, nc, c, H)
    logsig_f = jax.nn.log_sigmoid(ftc)                      # (B, nc, c, H)
    csum_f = jnp.cumsum(logsig_f, axis=2)                   # within chunk
    total_f = csum_f[:, :, -1]                              # (B, nc, H)

    def body(carry, xs):
        C_prev, n_prev, m_prev = carry
        qi, ki, vi, iti, fi_csum, fi_tot = xs
        # log decay from chunk start to position t: fi_csum (B, c, H)
        # intra-chunk D matrix: D[t,s] = exp(csum_t - csum_s + i_s) (s<=t)
        lg_q = fi_csum                                       # (B, c, H)
        a = lg_q[:, :, None, :] - fi_csum[:, None, :, :] + iti[:, None, :, :]
        mask = jnp.tril(jnp.ones((c, c), bool))
        a = jnp.where(mask[None, :, :, None], a, -jnp.inf)
        # inter-chunk: contribution decays by csum_t from chunk start
        b = lg_q + m_prev[:, None, :]                        # (B, c, H)
        m_new = jnp.maximum(jnp.max(a, axis=2), b)           # (B, c, H)
        Dmat = jnp.exp(a - m_new[:, :, None, :])             # (B, c, c, H)
        scale_q = jnp.exp(b - m_new)                         # (B, c, H)
        # intra: (q_t · k_s) D[t,s] v_s
        s_qk = jnp.einsum("bthd,bshd->btsh", qi, ki,
                          preferred_element_type=jnp.float32)
        intra = jnp.einsum("btsh,btsh,bshd->bthd", s_qk, Dmat,
                           vi.astype(jnp.float32))
        # inter: q_t · C_prev, decayed
        inter = jnp.einsum("bthd,bhde->bthe", qi.astype(jnp.float32), C_prev)
        inter = inter * scale_q[..., None]
        num = intra + inter
        # normalizer n
        n_intra = jnp.einsum("btsh,btsh,bshd->bthd", s_qk, Dmat,
                             jnp.ones_like(vi, jnp.float32))[..., :1]
        n_inter = (jnp.einsum("bthd,bhd->bth", qi.astype(jnp.float32), n_prev)
                   * scale_q)[..., None]
        denom = jnp.maximum(jnp.abs(n_intra + n_inter), 1.0)
        h = (num / denom).astype(x.dtype)                    # (B, c, H, dh)
        # update inter-chunk state: C = exp(f_tot + m_prev - m_next)·C_prev
        #                               + Σ_s exp(f_tot - csum_s + i_s)·k_s v_sᵀ
        m_next = jnp.maximum(fi_tot + m_prev, jnp.max(
            fi_tot[:, None] - fi_csum + iti, axis=1))        # (B, H)
        dec = jnp.exp(fi_tot + m_prev - m_next)              # (B, H)
        w_s = jnp.exp(fi_tot[:, None] - fi_csum + iti - m_next[:, None])
        C_new = (C_prev * dec[..., None, None] +
                 jnp.einsum("bsh,bshd,bshe->bhde", w_s,
                            ki.astype(jnp.float32), vi.astype(jnp.float32)))
        n_new = (n_prev * dec[..., None] +
                 jnp.einsum("bsh,bshd->bhd", w_s, ki.astype(jnp.float32)))
        return (C_new, n_new, m_next), h

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    xs = (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
          jnp.moveaxis(itc, 1, 0), jnp.moveaxis(csum_f, 1, 0),
          jnp.moveaxis(total_f, 1, 0))
    (Cf, nf, mf), hs = maybe_scan(body, (C0, n0, m0), xs, length=nc)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, Sp, H * dh)[:, :S]
    return h, {"C": Cf, "n": nf, "m": mf}


def mlstm_block(cfg: ModelConfig, p: Dict[str, Any], x: jax.Array,
                return_state: bool = False):
    """Full mLSTM residual block: norm → mLSTM → out-proj → gated MLP."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    inner, state = mlstm_train(cfg, p, h, chunk=cfg.mlstm_chunk)
    y = inner @ p["wo"]
    u, g = jnp.split(h @ p["up"], 2, axis=-1)
    y = y + (jax.nn.silu(g) * u) @ p["down"]
    out = x + y
    return (out, state) if return_state else out


def mlstm_init_state(cfg: ModelConfig, batch: int):
    H, dh = cfg.n_heads, cfg.dh
    return {"C": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, H, dh), jnp.float32),
            "m": jnp.full((batch, H), -1e30, jnp.float32)}


def mlstm_step(cfg: ModelConfig, p: Dict[str, Any], x: jax.Array,
               state: Dict[str, jax.Array]):
    """Single decode step. x: (B, 1, D)."""
    B = x.shape[0]
    H, dh = cfg.n_heads, cfg.dh
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v, it, ft = _mlstm_gates(cfg, p, h)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]         # (B, H, dh)
    it, ft = it[:, 0], ft[:, 0]                  # (B, H)
    lf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(lf + state["m"], it)
    fd = jnp.exp(lf + state["m"] - m_new)[..., None]
    iw = jnp.exp(it - m_new)[..., None]
    C = state["C"] * fd[..., None] + (iw[..., None] *
                                      k.astype(jnp.float32)[..., :, None] *
                                      v.astype(jnp.float32)[..., None, :])
    n = state["n"] * fd + iw * k.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32),
                                         n))[..., None], 1.0)
    y = ((num / den).astype(x.dtype)).reshape(B, 1, H * dh) @ p["wo"]
    u, g = jnp.split(h @ p["up"], 2, axis=-1)
    y = y + (jax.nn.silu(g) * u) @ p["down"]
    return x + y, {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_block(cfg: ModelConfig, p: Dict[str, Any], x: jax.Array,
                return_state: bool = False):
    """sLSTM residual block, scanned over time (sequential recurrence)."""
    B, S, D = x.shape
    H = cfg.n_heads
    dh = D // H
    hin = rms_norm(x, p["ln1"], cfg.norm_eps)
    # input projections hoisted out of the time loop
    zx = (hin @ p["wz"]).reshape(B, S, H, dh)
    ix = (hin @ p["wi"]).astype(jnp.float32).reshape(B, S, H, dh)
    fx = (hin @ p["wf"]).astype(jnp.float32).reshape(B, S, H, dh)
    ox = (hin @ p["wo_gate"]).reshape(B, S, H, dh)

    def step(carry, xs):
        c_prev, h_prev, m_prev = carry
        zt, itl, ftl, otl = xs
        # recurrent contribution (block-diagonal per head)
        zr = jnp.einsum("bhd,hde->bhe", h_prev, p["rz"])
        z = jnp.tanh(zt + zr)
        i_t = itl
        f_t = ftl
        m_t = jnp.maximum(f_t + m_prev, i_t)
        ig = jnp.exp(i_t - m_t)
        fg = jnp.exp(f_t + m_prev - m_t)
        c_t = fg * c_prev + ig * z.astype(jnp.float32)
        o_t = jax.nn.sigmoid(otl.astype(jnp.float32))
        h_t = (o_t * jnp.tanh(c_t)).astype(x.dtype)
        return (c_t, h_t, m_t), h_t

    c0 = jnp.zeros((B, H, dh), jnp.float32)
    h0 = jnp.zeros((B, H, dh), x.dtype)
    m0 = jnp.zeros((B, H, dh), jnp.float32)
    xs = (jnp.moveaxis(zx, 1, 0), jnp.moveaxis(ix, 1, 0),
          jnp.moveaxis(fx, 1, 0), jnp.moveaxis(ox, 1, 0))
    (cf, hf, mf), hs = maybe_scan(step, (c0, h0, m0), xs, length=S)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, D)
    y = h @ p["wo"]
    u, g = jnp.split(hin @ p["up"], 2, axis=-1)
    y = y + (jax.nn.silu(g) * u) @ p["down"]
    out = x + y
    return (out, {"c": cf, "h": hf, "m": mf}) if return_state else out


def slstm_init_state(cfg: ModelConfig, batch: int):
    H = cfg.n_heads
    dh = cfg.d_model // H
    return {"c": jnp.zeros((batch, H, dh), jnp.float32),
            "h": jnp.zeros((batch, H, dh), jnp.dtype(cfg.dtype)),
            "m": jnp.zeros((batch, H, dh), jnp.float32)}


def slstm_step(cfg: ModelConfig, p: Dict[str, Any], x: jax.Array,
               state: Dict[str, jax.Array]):
    B = x.shape[0]
    H = cfg.n_heads
    dh = cfg.d_model // H
    hin = rms_norm(x, p["ln1"], cfg.norm_eps)
    h1 = hin[:, 0]
    zt = (h1 @ p["wz"]).reshape(B, H, dh)
    it = (h1 @ p["wi"]).astype(jnp.float32).reshape(B, H, dh)
    ft = (h1 @ p["wf"]).astype(jnp.float32).reshape(B, H, dh)
    ot = (h1 @ p["wo_gate"]).reshape(B, H, dh)
    zr = jnp.einsum("bhd,hde->bhe", state["h"], p["rz"])
    z = jnp.tanh(zt + zr)
    m_t = jnp.maximum(ft + state["m"], it)
    ig = jnp.exp(it - m_t)
    fg = jnp.exp(ft + state["m"] - m_t)
    c_t = fg * state["c"] + ig * z.astype(jnp.float32)
    h_t = (jax.nn.sigmoid(ot.astype(jnp.float32)) * jnp.tanh(c_t)).astype(x.dtype)
    y = h_t.reshape(B, 1, cfg.d_model) @ p["wo"]
    u, g = jnp.split(hin @ p["up"], 2, axis=-1)
    y = y + (jax.nn.silu(g) * u) @ p["down"]
    return x + y, {"c": c_t, "h": h_t, "m": m_t}


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma)
# ---------------------------------------------------------------------------
_RGLRU_C = 8.0


def rglru_block(cfg: ModelConfig, p: Dict[str, Any], x: jax.Array,
                return_state: bool = False):
    """RG-LRU residual block: in-proj → conv1d(4) → gated linear recurrence
    (associative scan) → out-proj."""
    B, S, D = x.shape
    F = p["conv"].shape[1]          # lru width (= d_model in RecurrentGemma)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    u, gate = jnp.split(h @ p["w_in"], 2, axis=-1)            # (B, S, F) ×2
    # short causal conv1d (kernel 4) over time
    uc = _causal_conv4(u, p["conv"])
    # gates
    r = jax.nn.sigmoid((uc @ p["wa"]).astype(jnp.float32))     # recurrence gate
    i = jax.nn.sigmoid((uc @ p["wx"]).astype(jnp.float32))     # input gate
    log_a = -_RGLRU_C * jax.nn.softplus(p["lam"]) * r          # (B, S, F)
    a = jnp.exp(log_a)
    gated_x = uc.astype(jnp.float32) * i
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    xin = gated_x * beta

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, y = jax.lax.associative_scan(combine, (a, xin), axis=1)
    out_gated = (y * jax.nn.gelu(gate.astype(jnp.float32))).astype(x.dtype)
    out = x + out_gated @ p["w_out"]
    if not return_state:
        return out
    # decode state: last recurrence value + last 3 raw conv inputs
    hist = u[:, -3:, :] if S >= 3 else jnp.pad(u, ((0, 0), (3 - S, 0), (0, 0)))
    return out, {"y": y[:, -1], "conv": hist}


def _causal_conv4(u: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv, kernel 4. u: (B, S, F); w: (4, F)."""
    out = u * w[3]
    for i in range(1, 4):
        shifted = jnp.pad(u, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[3 - i]
    return out


def rglru_init_state(cfg: ModelConfig, batch: int):
    W = cfg.d_model
    return {"y": jnp.zeros((batch, W), jnp.float32),
            "conv": jnp.zeros((batch, 3, W), jnp.dtype(cfg.dtype))}


def rglru_step(cfg: ModelConfig, p: Dict[str, Any], x: jax.Array,
               state: Dict[str, jax.Array]):
    B = x.shape[0]
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    u, gate = jnp.split(h[:, 0] @ p["w_in"], 2, axis=-1)       # (B, F)
    hist = state["conv"]                                        # (B, 3, F)
    uc = (u * p["conv"][3] + hist[:, 2] * p["conv"][2] +
          hist[:, 1] * p["conv"][1] + hist[:, 0] * p["conv"][0])
    new_hist = jnp.concatenate([hist[:, 1:], u[:, None]], axis=1)
    r = jax.nn.sigmoid((uc @ p["wa"]).astype(jnp.float32))
    i = jax.nn.sigmoid((uc @ p["wx"]).astype(jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    y = state["y"] * a + uc.astype(jnp.float32) * i * beta
    out = (y * jax.nn.gelu(gate.astype(jnp.float32))).astype(x.dtype)
    return x + (out @ p["w_out"])[:, None], {"y": y, "conv": new_hist}
