"""ShardedService: K independent uBFT groups as one partitioned kvstore.

Scale-out for the service plane: one 2f+1 group caps out around 1 Mops, so
the keyspace is hash-partitioned (:class:`~repro.service.router.ShardRouter`)
across K groups attached to *one* shared substrate (``<name>/s0..s<K-1>``) —
group count is a free variable on fixed infrastructure, exactly what the
PR 4 substrate was built for.

Cross-shard MSET runs as two-phase commit where **each phase is itself a
BFT-committed slot** (DESIGN_SHARDING.md):

* PREPARE — an ordinary consensus request per participant shard
  (:func:`~repro.apps.kvstore.tprep_req`): the shard's replicated state
  machine locks the keys, records the intent, and votes.  The vote is a
  product of the shard's log, so all 2f+1 replicas agree on it.
* DECIDE — one consensus request on the **coordinator shard** (the lowest
  participating shard index): the first DECIDE in its totally-ordered log
  fixes the outcome; every later DECIDE — including a recovery probe
  proposing abort — reads that record back.  The outcome is therefore
  unique and replicated *without any separate BFT coordinator group*.
  A *commit* outcome is additionally **owner-bound**: the txid embeds a
  collision-resistant tag of the submitting client's pid
  (:func:`~repro.apps.kvstore.make_txid`) and the state machine only
  records ``C`` when the authenticated caller of the DECIDE slot matches
  that tag — a Byzantine client can neither pre-commit nor (thanks to the
  txid's unguessable nonce) even name another client's transaction, so
  it cannot tear an honest MSET.  Abort stays open to anyone: recovery
  probes must be able to presume-abort, and an abort is always atomic.
* FINISH — a consensus request per participant shard applying or
  discarding the intent and releasing its locks.

The *client* driving the phases is untrusted for safety (a client lying
about the outcome could only tear its own transaction, which is
indistinguishable from it issuing legal single-key SETs) but is relied on
for progress — so every replica arms a **presumed-abort recovery timer**
when it executes a PREPARE (:class:`_TxRecovery`): if the intent is still
pending past its deadline, the replica itself probes the coordinator
shard, which records DECIDE(abort) if nothing was decided yet and answers
with *signed outcome statements*; f+1 matching signatures (so the answer
comes from the replicated record, not from any single — possibly
Byzantine — replica, and at least one signer is honest) form an outcome
certificate that rides the resulting recovery FINISH into this replica's
own shard as a deterministic ``("svc", ...)`` slot all replicas'
concurrent submissions dedupe into.  A transaction whose client vanished
after a committed DECIDE is thus *finished forward*; one abandoned before
DECIDE is aborted.

The recovery fleet survives membership epoch switches: every
``Cluster.replace_replica`` fires the cluster's ``replace_hooks``, which
attach a fresh :class:`_TxRecovery` to the joiner, and the joiner arms
probes for every pending intent it adopted via snapshot once it activates
(``on_activate_hooks``) — so locks are released even when every replica
that originally executed the PREPARE has been replaced.
"""

from __future__ import annotations

import hashlib
import random
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.apps.kvstore import (TXID_LEN, VOTE_OK, ShardKVApp, get_req,
                                make_txid, mset_req, parse_rfinish,
                                parse_tprep, rfinish_req, set_req,
                                tdecide_req, tfinish_req, tprep_req)
from repro.core import crypto
from repro.core.consensus import App, ConsensusConfig, UbftReplica
from repro.core.registers import POOL_MEMORY_BUDGET
from repro.core.smr import Cluster
from repro.core.substrate import Substrate
from repro.service.router import ShardRouter


class ServiceClient:
    """Routes operations to shards; runs cross-shard MSETs as 2PC.

    Operations are structured tuples (the router needs the key *before*
    the wire encoding picks a shard):

    * ``("get", key)`` / ``("set", key, value)`` — routed to one shard
    * ``("mset", pairs)`` — single-shard: one plain MSET slot;
      cross-shard: PREPARE / DECIDE / FINISH as described in the module
      docstring.  Completes ``cb(b"OK" | b"ABORTED", latency)``.

    One underlying uBFT :class:`~repro.core.smr.Client` per shard, created
    via ``Cluster.new_client`` — so a membership epoch switch on any shard
    (``replace_replica``) updates this client's destination pids in place
    and nothing here ever caches a replica address.
    """

    #: test knobs simulating a coordinator-client crash mid-2PC: drop the
    #: protocol on the floor after PREPARE (before DECIDE) / after DECIDE
    #: (before FINISH) — recovery must then abort / finish-forward
    drop_decide = False
    drop_finish = False

    def __init__(self, service: "ShardedService", pid: str):
        self.service = service
        self.pid = pid
        self.sim = service.sim
        self.router = service.router
        self.shard_clients = [c.new_client() for c in service.shards]
        self._txseq = 0
        # per-client nonce stream for txids.  Models a CSPRNG: the seed is
        # derived from the service-wide tx_secret + pid so runs stay
        # reproducible, while within the model no other client can predict
        # the draws (a real deployment would use os.urandom)
        self._tx_rng = random.Random(int.from_bytes(
            hashlib.sha256(
                f"{service.tx_secret}|{pid}".encode()).digest()[:8],
            "little"))
        self.latencies: List[float] = []

    # ------------------------------------------------------------ routing
    def request(self, op: tuple,
                cb: Optional[Callable[[bytes, float], None]] = None) -> None:
        kind = op[0]
        if kind == "get":
            return self._one(self.router.shard_of(op[1]), get_req(op[1]), cb)
        if kind == "set":
            return self._one(self.router.shard_of(op[1]),
                             set_req(op[1], op[2]), cb)
        if kind == "mset":
            by_shard = self.router.split(list(op[1]))
            if len(by_shard) == 1:
                ((s, pairs),) = by_shard.items()
                return self._one(s, mset_req(pairs), cb)
            return self._mset_2pc(by_shard, cb)
        raise ValueError(f"unknown service op {kind!r}")

    def _one(self, shard: int, payload: bytes,
             cb: Optional[Callable[[bytes, float], None]]) -> None:
        def done(result: bytes, lat: float) -> None:
            self.latencies.append(lat)
            if cb is not None:
                cb(result, lat)
        self.shard_clients[shard].request(payload, done)

    # -------------------------------------------------------- 2PC phases
    def _mset_2pc(self, by_shard: Dict[int, list],
                  cb: Optional[Callable[[bytes, float], None]]) -> None:
        t0 = self.sim.now
        shards = sorted(by_shard)
        coord = shards[0]
        # the txid's owner tag names the principal that submits the DECIDE:
        # this client's per-coordinator-shard uBFT client.  The consensus
        # layer authenticates that pid on every request (rid/client/sender
        # binding), so only *this* client can ever record a commit
        owner = self.shard_clients[coord].pid
        txid = make_txid(owner, self._txseq, self._tx_rng.getrandbits(64))
        self._txseq += 1
        deadline = t0 + self.service.tx_timeout_us
        votes: Dict[int, bytes] = {}

        def vote(s: int):
            def done(result: bytes, _lat: float) -> None:
                votes[s] = result
                if len(votes) == len(shards):
                    self._decide(txid, shards, coord, votes, cb, t0)
            return done

        for s in shards:
            self.shard_clients[s].request(
                tprep_req(txid, deadline, coord, by_shard[s]), vote(s))

    def _decide(self, txid: bytes, shards: List[int], coord: int,
                votes: Dict[int, bytes],
                cb: Optional[Callable[[bytes, float], None]],
                t0: float) -> None:
        if self.drop_decide:
            return      # "crashed" between PREPARE and DECIDE
        proposed = b"C" if all(v == VOTE_OK for v in votes.values()) else b"A"

        def decided(result: bytes, _lat: float) -> None:
            # the reply's recorded outcome is authoritative — a recovery
            # timer may have beaten a slow commit DECIDE to the log
            outcome = result[-1:] if result[:3] == b"OUT" else b"A"
            self._finish(txid, shards, outcome, cb, t0)

        self.shard_clients[coord].request(tdecide_req(txid, proposed),
                                          decided)

    def _finish(self, txid: bytes, shards: List[int], outcome: bytes,
                cb: Optional[Callable[[bytes, float], None]],
                t0: float) -> None:
        if self.drop_finish:
            return      # "crashed" between DECIDE and FINISH
        left = {"n": len(shards)}

        def done(_result: bytes, _lat: float) -> None:
            left["n"] -= 1
            if left["n"] == 0:
                lat = self.sim.now - t0
                self.latencies.append(lat)
                if cb is not None:
                    cb(b"OK" if outcome == b"C" else b"ABORTED", lat)

        for s in shards:
            self.shard_clients[s].request(tfinish_req(txid, outcome), done)


class _TxRecovery:
    """Per-replica presumed-abort recovery for abandoned transactions.

    Watches the replica's own execution stream (``on_execute_hooks``): a
    PREPARE that voted OK arms a timer at the transaction deadline (plus a
    per-replica stagger so recoverers probe in sequence rather than in a
    thundering herd).  If the intent is still pending when the timer fires,
    the replica **probes** the coordinator shard with a TXDEC message.
    Each coordinator replica answers with a *signed outcome statement*
    ``("txout", txid, outcome)``: if no outcome is recorded yet it first
    routes DECIDE(abort) through its own shard's consensus as the
    deterministic slot ``("svc", "tdec", txid)`` — presumed abort — and
    signs once the record exists.  (The owner-binding in
    :meth:`~repro.apps.kvstore.ShardKVApp` guarantees an internal DECIDE
    can only ever record ``A``, so these slots need no cross-shard
    verification.)

    f+1 matching statements form an **outcome certificate**; the recoverer
    then proposes a recovery FINISH carrying that certificate
    (:func:`~repro.apps.kvstore.rfinish_req`) into its own shard under the
    deterministic rid ``("svc", "tfin", txid, outcome)`` — concurrent
    recoverers collapse into one slot.  The certificate is the crux: a
    ``("svc", "tfin", ...)`` slot is only endorsed/certified
    (:attr:`~repro.core.consensus.UbftReplica.svc_validators`) when its
    payload's certificate verifies against the coordinator shard's
    membership, so *any* honest replica can vote for a legitimate recovery
    FINISH immediately — no local probe state needed, hence no circular
    wait between slot execution and probe completion — while a Byzantine
    leader's forged FINISH (no valid certificate) never collects an honest
    quorum and merely costs that leader its view.

    Probes re-arm until the intent resolves (a coordinator-shard view
    change mid-probe only delays recovery) and each re-probe replaces the
    previous one's reply bookkeeping, keyed by txid — the table stays
    bounded by the number of live abandoned transactions.

    Instances attach at service-attach time *and* — via
    ``Cluster.replace_hooks`` — to every joiner a membership epoch switch
    installs; a joiner arms probes for the pending intents it adopted via
    snapshot as soon as it activates (``on_activate_hooks``).
    """

    def __init__(self, service: "ShardedService", shard_idx: int,
                 replica: UbftReplica, stagger_us: float):
        self.service = service
        self.shard_idx = shard_idx
        self.replica = replica
        self.stagger_us = stagger_us
        #: recoverer role: txid -> signature collection for the live probe
        #: (re-probes replace their predecessor's entry: bounded by the
        #: number of still-pending abandoned transactions)
        self._sigwait: Dict[bytes, dict] = {}
        #: coordinator role: txid -> requester pids awaiting the outcome
        self._want_outcome: Dict[bytes, set] = {}
        #: txids with a live timer chain (dedupe execute- vs adopt-arming)
        self._armed: set = set()
        replica.on_execute_hooks.append(self._on_execute)
        replica.on_activate_hooks.append(self._arm_adopted)
        replica.svc_validators["tfin"] = self._tfin_certifiable
        replica.svc_validators["tdec"] = self._tdec_certifiable
        replica.handle("TXDEC", self._on_txdec)
        replica.handle("TXOUT", self._on_txout)

    def _on_execute(self, _slot: int, _rid: tuple, payload: bytes,
                    result: bytes) -> None:
        if payload[:1] == b"D" and result[:3] == b"OUT":
            # coordinator role: an outcome just became part of the record —
            # answer every probe that was waiting for it
            self._answer_outcome(payload[1:1 + TXID_LEN], result[-1:])
        if payload[:1] != b"P" or result != VOTE_OK:
            return
        parsed = parse_tprep(payload)
        if parsed is None:
            return
        txid, deadline, coord, _pairs = parsed
        self._arm(txid, deadline, coord)

    def _arm_adopted(self) -> None:
        """Joiner activation: the snapshot may carry pending intents whose
        PREPAREs executed before this replica existed — arm their timers
        now, or a shard whose original replicas are all gone would hold
        those locks forever."""
        for txid, (deadline, coord, _pairs) in \
                list(self.replica.app.pending.items()):
            self._arm(txid, deadline, coord)

    def _arm(self, txid: bytes, deadline: float, coord: int) -> None:
        if txid in self._armed:
            return
        self._armed.add(txid)
        delay = max(deadline - self.replica.sim.now, 0.0) + self.stagger_us
        self.replica.timer(delay, lambda: self._probe(txid, coord))

    def _probe(self, txid: bytes, coord: int) -> None:
        r = self.replica
        # a re-probe supersedes the previous one — drop its bookkeeping so
        # probes that never reached quorum cannot accumulate
        self._sigwait.pop(txid, None)
        if r.crashed:
            return
        if r.joining:
            # not yet a voting member: keep the timer chain alive and try
            # again once activated (activation also arms adopted intents)
            r.timer(self.service.tx_timeout_us,
                    lambda: self._probe(txid, coord))
            return
        if txid not in r.app.pending:
            self._armed.discard(txid)
            return
        if not 0 <= coord < len(self.service.shards):
            return      # malformed coordinator index: nothing to consult
        coord_cluster = self.service.shards[coord]
        self._sigwait[txid] = {
            "coord": coord, "by_outcome": {},
            "need": coord_cluster.replicas[0].f + 1,
        }
        body = (txid,)
        size = crypto.wire_size_shallow(body) + 19
        for pid in coord_cluster.replica_pids:   # resolved live: epoch-aware
            r.send(pid, "TXDEC", body, size=size)
        # re-probe until resolved (coordinator shard may be mid-view-change)
        r.timer(self.service.tx_timeout_us, lambda: self._probe(txid, coord))

    # --------------------------------------- coordinator role: TXDEC/TXOUT
    def _on_txdec(self, src: str, body: Any) -> None:
        """A recoverer asks this coordinator-shard replica for a signed
        outcome statement.  Recorded outcome → sign and answer.  None yet →
        route DECIDE(abort) through this shard's consensus (presumed abort)
        and answer once the record exists (``_on_execute``)."""
        r = self.replica
        if r.crashed or r.joining:
            return
        (txid,) = body
        if not (isinstance(txid, bytes) and len(txid) == TXID_LEN):
            return
        out = r.app.outcomes.get(txid)
        if out is not None:
            self._send_txout({src}, txid, out)
            return
        self._want_outcome.setdefault(txid, set()).add(src)
        r.propose_internal(("svc", "tdec", txid), tdecide_req(txid, b"A"))

    def _answer_outcome(self, txid: bytes, outcome: bytes) -> None:
        waiting = self._want_outcome.pop(txid, None)
        if waiting:
            self._send_txout(waiting, txid, outcome)

    def _send_txout(self, requesters: set, txid: bytes,
                    outcome: bytes) -> None:
        r = self.replica

        def signed(sig: bytes) -> None:
            body = (txid, outcome, sig)
            size = crypto.wire_size_shallow(body) + 19
            for pid in requesters:
                r.send(pid, "TXOUT", body, size=size)

        r.async_sign(("txout", txid, outcome), signed)

    # ------------------------------------------ recoverer role: collection
    def _on_txout(self, src: str, body: Any) -> None:
        txid, outcome, sig = body
        st = self._sigwait.get(txid)
        if st is None or outcome not in (b"C", b"A"):
            return

        def verified(ok: bool) -> None:
            cur = self._sigwait.get(txid)
            if not ok or cur is not st:
                return      # forged statement, or probe superseded meanwhile
            by = st["by_outcome"].setdefault(outcome, {})
            by[src] = sig
            if len(by) < st["need"]:
                return
            del self._sigwait[txid]
            cert = tuple(sorted(by.items()))
            self.replica.propose_internal(
                ("svc", "tfin", txid, outcome),
                rfinish_req(txid, outcome, cert))

        self.replica.async_verify(src, ("txout", txid, outcome), sig,
                                  verified)

    # ------------------------------------------------- svc slot validation
    def _tdec_certifiable(self, rid: tuple, payload: bytes) -> bool:
        """An internal DECIDE slot is endorsable iff it is exactly a
        well-formed presumed-abort proposal: the state machine's
        owner-binding already makes any internal commit unrecordable, so
        abort-only framing is the whole check."""
        if len(rid) != 3:
            return False
        txid = rid[2]
        return (isinstance(txid, bytes) and len(txid) == TXID_LEN
                and payload == tdecide_req(txid, b"A"))

    def _tfin_certifiable(self, rid: tuple, payload: bytes) -> bool:
        """May this replica endorse/certify a ``("svc","tfin",...)`` slot?

        Malformed FINISH slots are never certified.  A FINISH for a
        transaction this shard no longer holds pending is harmless
        (``_finish_tx`` just records the outcome) and must be endorsed, or
        a replica whose intent already resolved would block the slot.  For
        a *still-pending* intent the outcome matters — C applies the
        pairs — so the payload must carry an outcome certificate: f+1
        signatures over ``("txout", txid, outcome)`` from current members
        of the transaction's coordinator shard.  f+1 guarantees at least
        one honest signer, and an honest coordinator replica only signs
        its shard's replicated outcome record.
        """
        if len(rid) != 4:
            return False
        _svc, _kind, txid, outcome = rid
        if not (isinstance(txid, bytes) and len(txid) == TXID_LEN
                and outcome in (b"C", b"A")):
            return False
        if not isinstance(payload, bytes):
            return False
        parsed = parse_rfinish(payload)
        if parsed is None or parsed[0] != txid or parsed[1] != outcome:
            return False
        entry = self.replica.app.pending.get(txid)
        if entry is None:
            return True
        coord = entry[1]
        if not 0 <= coord < len(self.service.shards):
            return False
        coord_cluster = self.service.shards[coord]
        members = set(coord_cluster.replica_pids)
        need = coord_cluster.replicas[0].f + 1
        good = {pid for pid, sig in parsed[2]
                if pid in members and self.replica.registry.verify(
                    pid, ("txout", txid, outcome), sig)}
        return len(good) >= need


class ShardedService:
    """K uBFT groups over one substrate, presented as one keyspace."""

    def __init__(self, substrate: Substrate, name: str,
                 shards: List[Cluster], router: ShardRouter,
                 tx_timeout_us: float, tx_secret: int = 0):
        self.substrate = substrate
        self.name = name
        self.shards = shards
        self.router = router
        self.tx_timeout_us = tx_timeout_us
        #: seeds each client's txid-nonce stream (stands in for per-client
        #: CSPRNG state; vary it to vary the nonces across runs)
        self.tx_secret = tx_secret
        self.clients: List[ServiceClient] = []
        #: every live recovery instance (originals + joiners), for
        #: observability and bounded-state assertions in tests
        self.recoveries: List[_TxRecovery] = []

    @classmethod
    def attach(cls, substrate: Substrate, n_shards: int, name: str = "kv",
               cfg: Optional[Any] = None,
               app: Callable[[], App] = ShardKVApp,
               budget: int = POOL_MEMORY_BUDGET,
               tx_timeout_us: float = 20_000.0,
               tx_secret: int = 0,
               pools: Optional[Any] = None) -> "ShardedService":
        """Attach ``n_shards`` groups (``<name>/s<i>``) to the substrate.

        ``cfg`` is one :class:`ConsensusConfig` shared by every shard
        (fixed per-shard config — the benchmark's scaling axis) or a
        callable ``cfg(i)`` for heterogeneous shards.  ``app`` must build
        a 2PC-capable store (:class:`~repro.apps.kvstore.ShardKVApp` or a
        subclass) when cross-shard MSETs will be issued.
        """
        if name in substrate.services:
            raise ValueError(f"service {name!r} already attached")
        router = ShardRouter(n_shards)
        shards: List[Cluster] = []
        for i in range(n_shards):
            kw: Dict[str, Any] = {}
            if pools is not None:
                kw["pools"] = pools
            shards.append(Cluster.attach(
                substrate, app, name=f"{name}/s{i}",
                cfg=(cfg(i) if callable(cfg) else cfg), budget=budget, **kw))
        svc = cls(substrate, name, shards, router, tx_timeout_us,
                  tx_secret=tx_secret)
        for i, cluster in enumerate(shards):
            for idx, r in enumerate(cluster.replicas):
                svc.recoveries.append(
                    _TxRecovery(svc, i, r, stagger_us=200.0 + 150.0 * idx))
            # membership epoch switches must not shrink the recovery
            # fleet: every joiner gets its own recovery instance, which
            # arms probes for snapshot-adopted intents on activation
            cluster.replace_hooks.append(
                lambda _old, joiner, _i=i, _c=cluster:
                svc.recoveries.append(_TxRecovery(
                    svc, _i, joiner,
                    stagger_us=200.0 + 150.0 * _c.replicas.index(joiner))))
        substrate.services[name] = svc
        return svc

    # --------------------------------------------- Cluster-like interface
    @property
    def sim(self):
        return self.substrate.sim

    def new_client(self, pid: Optional[str] = None) -> ServiceClient:
        if pid is None:
            pid = f"{self.name}/c{len(self.clients)}"
        c = ServiceClient(self, pid)
        self.clients.append(c)
        return c

    def run_op(self, client: ServiceClient, op: tuple,
               timeout: float = 1_000_000.0) -> Tuple[bytes, float]:
        """Issue one operation and run the simulation to completion."""
        box: dict = {}

        def done(result: bytes, lat: float) -> None:
            box["result"] = result
            box["lat"] = lat

        client.request(op, done)
        ok = self.sim.run_until(lambda: "result" in box, timeout=timeout)
        if not ok:
            raise TimeoutError(f"service op {op[0]!r} did not complete "
                               f"within {timeout} µs (t={self.sim.now})")
        return box["result"], box["lat"]
