"""ShardedService: K independent uBFT groups as one partitioned kvstore.

Scale-out for the service plane: one 2f+1 group caps out around 1 Mops, so
the keyspace is hash-partitioned (:class:`~repro.service.router.ShardRouter`)
across K groups attached to *one* shared substrate (``<name>/s0..s<K-1>``) —
group count is a free variable on fixed infrastructure, exactly what the
PR 4 substrate was built for.

Cross-shard MSET runs as two-phase commit where **each phase is itself a
BFT-committed slot** (DESIGN_SHARDING.md):

* PREPARE — an ordinary consensus request per participant shard
  (:func:`~repro.apps.kvstore.tprep_req`): the shard's replicated state
  machine locks the keys, records the intent, and votes.  The vote is a
  product of the shard's log, so all 2f+1 replicas agree on it.
* DECIDE — one consensus request on the **coordinator shard** (the lowest
  participating shard index): the first DECIDE in its totally-ordered log
  fixes the outcome; every later DECIDE — including a recovery probe
  proposing abort — reads that record back.  The outcome is therefore
  unique and replicated *without any separate BFT coordinator group*.
  A *commit* outcome is additionally **owner-bound**: the txid embeds a
  collision-resistant tag of the submitting client's pid
  (:func:`~repro.apps.kvstore.make_txid`) and the state machine only
  records ``C`` when the authenticated caller of the DECIDE slot matches
  that tag — a Byzantine client can neither pre-commit nor (thanks to the
  txid's unguessable nonce) even name another client's transaction, so
  it cannot tear an honest MSET.  Abort stays open to anyone: recovery
  probes must be able to presume-abort, and an abort is always atomic.
* FINISH — a consensus request per participant shard applying or
  discarding the intent and releasing its locks.

The *client* driving the phases is untrusted for safety (a client lying
about the outcome could only tear its own transaction, which is
indistinguishable from it issuing legal single-key SETs) but is relied on
for progress — so every replica arms a **presumed-abort recovery timer**
when it executes a PREPARE (:class:`_TxRecovery`): if the intent is still
pending past its deadline, the replica itself probes the coordinator
shard, which records DECIDE(abort) if nothing was decided yet and answers
with *signed outcome statements*; f+1 matching signatures (so the answer
comes from the replicated record, not from any single — possibly
Byzantine — replica, and at least one signer is honest) form an outcome
certificate that rides the resulting recovery FINISH into this replica's
own shard as a deterministic ``("svc", ...)`` slot all replicas'
concurrent submissions dedupe into.  A transaction whose client vanished
after a committed DECIDE is thus *finished forward*; one abandoned before
DECIDE is aborted.

The recovery fleet survives membership epoch switches: every
``Cluster.replace_replica`` fires the cluster's ``replace_hooks``, which
attach a fresh :class:`_TxRecovery` to the joiner, and the joiner arms
probes for every pending intent it adopted via snapshot once it activates
(``on_activate_hooks``) — so locks are released even when every replica
that originally executed the PREPARE has been replaced.
"""

from __future__ import annotations

import hashlib
import random
import struct
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.apps.kvstore import (TXID_LEN, VOTE_OK, ShardKVApp, adopt_req,
                                capture_req, cut_req, freeze_req, get_req,
                                key_in_range, make_txid, mset_req,
                                parse_adopt, parse_cut, parse_rfinish,
                                parse_tprep, range_fp, rfinish_req, set_req,
                                tdecide_req, tfinish_req, tprep_req)
from repro.core import crypto
from repro.core.consensus import App, ConsensusConfig, UbftReplica
from repro.core.registers import POOL_MEMORY_BUDGET
from repro.core.smr import Cluster
from repro.core.substrate import Substrate
from repro.service.router import ShardRouter


class ServiceClient:
    """Routes operations to shards; runs cross-shard MSETs as 2PC.

    Operations are structured tuples (the router needs the key *before*
    the wire encoding picks a shard):

    * ``("get", key)`` / ``("set", key, value)`` — routed to one shard
    * ``("mset", pairs)`` — single-shard: one plain MSET slot;
      cross-shard: PREPARE / DECIDE / FINISH as described in the module
      docstring.  Completes ``cb(b"OK" | b"ABORTED", latency)``.

    One underlying uBFT :class:`~repro.core.smr.Client` per shard, created
    via ``Cluster.new_client`` — so a membership epoch switch on any shard
    (``replace_replica``) updates this client's destination pids in place
    and nothing here ever caches a replica address.
    """

    #: test knobs simulating a coordinator-client crash mid-2PC: drop the
    #: protocol on the floor after PREPARE (before DECIDE) / after DECIDE
    #: (before FINISH) — recovery must then abort / finish-forward
    drop_decide = False
    drop_finish = False
    #: routing-bounce handling (live split/merge): how many FROZEN/MOVED
    #: bounces one op survives before the bounce is surfaced to the
    #: caller, and how long to wait before re-routing.  FROZEN retries
    #: back off exponentially (capped): a freeze lasts as long as the
    #: range transfer, and every premature retry is a full consensus slot
    #: on the very shard the transfer is trying to drain — flat retries
    #: turn a hot-shard split into a self-inflicted retry storm
    max_route_retries = 64
    retry_delay_us = 150.0
    retry_backoff_max = 5          # cap the FROZEN delay at 150µs · 2^5

    def __init__(self, service: "ShardedService", pid: str):
        self.service = service
        self.pid = pid
        self.sim = service.sim
        self.router = service.router
        self.shard_clients = [c.new_client() for c in service.shards]
        self._txseq = 0
        # per-client nonce stream for txids.  Models a CSPRNG: the seed is
        # derived from the service-wide tx_secret + pid so runs stay
        # reproducible, while within the model no other client can predict
        # the draws (a real deployment would use os.urandom)
        self._tx_rng = random.Random(int.from_bytes(
            hashlib.sha256(
                f"{service.tx_secret}|{pid}".encode()).digest()[:8],
            "little"))
        self.latencies: List[float] = []

    # ------------------------------------------------------------ routing
    def _client_for(self, shard: int):
        """Per-shard uBFT client, grown lazily — a split minting shard
        index K+1 after this ServiceClient was created must still be
        reachable without re-creating the client."""
        while shard >= len(self.shard_clients):
            self.shard_clients.append(
                self.service.shards[len(self.shard_clients)].new_client())
        return self.shard_clients[shard]

    def request(self, op: tuple,
                cb: Optional[Callable[[bytes, float], None]] = None,
                _attempt: int = 0, _t0: Optional[float] = None) -> None:
        kind = op[0]
        if kind == "get":
            return self._one(self.router.shard_of(op[1]), get_req(op[1]), cb,
                             op=op, attempt=_attempt, t0=_t0)
        if kind == "set":
            return self._one(self.router.shard_of(op[1]),
                             set_req(op[1], op[2]), cb,
                             op=op, attempt=_attempt, t0=_t0)
        if kind == "mset":
            by_shard = self.router.split(list(op[1]))
            if len(by_shard) == 1:
                ((s, pairs),) = by_shard.items()
                return self._one(s, mset_req(pairs), cb,
                                 op=op, attempt=_attempt, t0=_t0)
            return self._mset_2pc(by_shard, cb)
        raise ValueError(f"unknown service op {kind!r}")

    def _one(self, shard: int, payload: bytes,
             cb: Optional[Callable[[bytes, float], None]],
             op: Optional[tuple] = None, attempt: int = 0,
             t0: Optional[float] = None) -> None:
        if t0 is None:
            t0 = self.sim.now

        def done(result: bytes, lat: float) -> None:
            # live split/merge bounces: a shard that froze or handed off
            # the key's range answers deterministically; re-route rather
            # than surface the bounce (until the retry budget runs out)
            if op is not None and attempt < self.max_route_retries:
                if result == b"FROZEN":
                    # The range still lives at this shard but is
                    # write-locked for transfer.  Do NOT poll: every
                    # premature retry costs the *source* shard a consensus
                    # slot, and on a hot shard that surge is what pushes
                    # the queue past the §5.4 direct-copy horizon (where
                    # every slot decays to the slow-path kick).  Wait for
                    # the router-epoch bump that ends the transfer, with a
                    # capped-exponential timer as the fallback for a
                    # crashed control plane.
                    fired: dict = {}

                    def go() -> None:
                        if fired:
                            return
                        fired["x"] = 1
                        self.request(op, cb, _attempt=attempt + 1, _t0=t0)

                    delay = self.retry_delay_us * (
                        2 ** min(attempt, self.retry_backoff_max))
                    self.service._epoch_waiters.append(go)
                    self.sim.after(delay, go)
                    return
                if result[:5] == b"MOVED" and len(result) == 7:
                    (tgt,) = struct.unpack("<H", result[5:])
                    if op[0] in ("get", "set"):
                        # the reply names the adopting shard: chase it
                        # directly — the shared routing table may not have
                        # committed the epoch bump yet
                        self._one(tgt, payload, cb, op=op,
                                  attempt=attempt + 1, t0=t0)
                    else:
                        # multi-key op: re-split via the routing table
                        self.sim.after(
                            self.retry_delay_us,
                            lambda: self.request(op, cb,
                                                 _attempt=attempt + 1,
                                                 _t0=t0))
                    return
            end_lat = lat if attempt == 0 else self.sim.now - t0
            self.latencies.append(end_lat)
            if cb is not None:
                cb(result, end_lat)
        self._client_for(shard).request(payload, done)

    # -------------------------------------------------------- 2PC phases
    def _mset_2pc(self, by_shard: Dict[int, list],
                  cb: Optional[Callable[[bytes, float], None]]) -> None:
        t0 = self.sim.now
        shards = sorted(by_shard)
        coord = shards[0]
        # the txid's owner tag names the principal that submits the DECIDE:
        # this client's per-coordinator-shard uBFT client.  The consensus
        # layer authenticates that pid on every request (rid/client/sender
        # binding), so only *this* client can ever record a commit
        owner = self._client_for(coord).pid
        txid = make_txid(owner, self._txseq, self._tx_rng.getrandbits(64))
        self._txseq += 1
        deadline = t0 + self.service.tx_timeout_us
        votes: Dict[int, bytes] = {}

        def vote(s: int):
            def done(result: bytes, _lat: float) -> None:
                votes[s] = result
                if len(votes) == len(shards):
                    self._decide(txid, shards, coord, votes, cb, t0)
            return done

        for s in shards:
            self._client_for(s).request(
                tprep_req(txid, deadline, coord, by_shard[s]), vote(s))

    def _decide(self, txid: bytes, shards: List[int], coord: int,
                votes: Dict[int, bytes],
                cb: Optional[Callable[[bytes, float], None]],
                t0: float) -> None:
        if self.drop_decide:
            return      # "crashed" between PREPARE and DECIDE
        proposed = b"C" if all(v == VOTE_OK for v in votes.values()) else b"A"

        def decided(result: bytes, _lat: float) -> None:
            # the reply's recorded outcome is authoritative — a recovery
            # timer may have beaten a slow commit DECIDE to the log
            outcome = result[-1:] if result[:3] == b"OUT" else b"A"
            self._finish(txid, shards, outcome, cb, t0)

        self._client_for(coord).request(tdecide_req(txid, proposed),
                                         decided)

    def _finish(self, txid: bytes, shards: List[int], outcome: bytes,
                cb: Optional[Callable[[bytes, float], None]],
                t0: float) -> None:
        if self.drop_finish:
            return      # "crashed" between DECIDE and FINISH
        left = {"n": len(shards)}

        def done(_result: bytes, _lat: float) -> None:
            left["n"] -= 1
            if left["n"] == 0:
                lat = self.sim.now - t0
                self.latencies.append(lat)
                if cb is not None:
                    cb(b"OK" if outcome == b"C" else b"ABORTED", lat)

        for s in shards:
            self._client_for(s).request(tfinish_req(txid, outcome), done)


class _TxRecovery:
    """Per-replica presumed-abort recovery for abandoned transactions.

    Watches the replica's own execution stream (``on_execute_hooks``): a
    PREPARE that voted OK arms a timer at the transaction deadline (plus a
    per-replica stagger so recoverers probe in sequence rather than in a
    thundering herd).  If the intent is still pending when the timer fires,
    the replica **probes** the coordinator shard with a TXDEC message.
    Each coordinator replica answers with a *signed outcome statement*
    ``("txout", txid, outcome)``: if no outcome is recorded yet it first
    routes DECIDE(abort) through its own shard's consensus as the
    deterministic slot ``("svc", "tdec", txid)`` — presumed abort — and
    signs once the record exists.  (The owner-binding in
    :meth:`~repro.apps.kvstore.ShardKVApp` guarantees an internal DECIDE
    can only ever record ``A``, so these slots need no cross-shard
    verification.)

    f+1 matching statements form an **outcome certificate**; the recoverer
    then proposes a recovery FINISH carrying that certificate
    (:func:`~repro.apps.kvstore.rfinish_req`) into its own shard under the
    deterministic rid ``("svc", "tfin", txid, outcome)`` — concurrent
    recoverers collapse into one slot.  The certificate is the crux: a
    ``("svc", "tfin", ...)`` slot is only endorsed/certified
    (:attr:`~repro.core.consensus.UbftReplica.svc_validators`) when its
    payload's certificate verifies against the coordinator shard's
    membership, so *any* honest replica can vote for a legitimate recovery
    FINISH immediately — no local probe state needed, hence no circular
    wait between slot execution and probe completion — while a Byzantine
    leader's forged FINISH (no valid certificate) never collects an honest
    quorum and merely costs that leader its view.

    Probes re-arm until the intent resolves (a coordinator-shard view
    change mid-probe only delays recovery) and each re-probe replaces the
    previous one's reply bookkeeping, keyed by txid — the table stays
    bounded by the number of live abandoned transactions.

    Instances attach at service-attach time *and* — via
    ``Cluster.replace_hooks`` — to every joiner a membership epoch switch
    installs; a joiner arms probes for the pending intents it adopted via
    snapshot as soon as it activates (``on_activate_hooks``).
    """

    def __init__(self, service: "ShardedService", shard_idx: int,
                 replica: UbftReplica, stagger_us: float):
        self.service = service
        self.shard_idx = shard_idx
        self.replica = replica
        self.stagger_us = stagger_us
        #: recoverer role: txid -> signature collection for the live probe
        #: (re-probes replace their predecessor's entry: bounded by the
        #: number of still-pending abandoned transactions)
        self._sigwait: Dict[bytes, dict] = {}
        #: coordinator role: txid -> requester pids awaiting the outcome
        self._want_outcome: Dict[bytes, set] = {}
        #: txids with a live timer chain (dedupe execute- vs adopt-arming)
        self._armed: set = set()
        replica.on_execute_hooks.append(self._on_execute)
        replica.on_activate_hooks.append(self._arm_adopted)
        replica.svc_validators["tfin"] = self._tfin_certifiable
        replica.svc_validators["tdec"] = self._tdec_certifiable
        replica.handle("TXDEC", self._on_txdec)
        replica.handle("TXOUT", self._on_txout)

    def _on_execute(self, _slot: int, _rid: tuple, payload: bytes,
                    result: bytes) -> None:
        if payload[:1] == b"D" and result[:3] == b"OUT":
            # coordinator role: an outcome just became part of the record —
            # answer every probe that was waiting for it
            self._answer_outcome(payload[1:1 + TXID_LEN], result[-1:])
        if payload[:1] != b"P" or result != VOTE_OK:
            return
        parsed = parse_tprep(payload)
        if parsed is None:
            return
        txid, deadline, coord, _pairs = parsed
        self._arm(txid, deadline, coord)

    def _arm_adopted(self) -> None:
        """Joiner activation: the snapshot may carry pending intents whose
        PREPAREs executed before this replica existed — arm their timers
        now, or a shard whose original replicas are all gone would hold
        those locks forever."""
        for txid, (deadline, coord, _pairs) in \
                list(self.replica.app.pending.items()):
            self._arm(txid, deadline, coord)

    def _arm(self, txid: bytes, deadline: float, coord: int) -> None:
        if txid in self._armed:
            return
        self._armed.add(txid)
        delay = max(deadline - self.replica.sim.now, 0.0) + self.stagger_us
        self.replica.timer(delay, lambda: self._probe(txid, coord))

    def _probe(self, txid: bytes, coord: int) -> None:
        r = self.replica
        # a re-probe supersedes the previous one — drop its bookkeeping so
        # probes that never reached quorum cannot accumulate
        self._sigwait.pop(txid, None)
        if r.crashed:
            return
        if r.joining:
            # not yet a voting member: keep the timer chain alive and try
            # again once activated (activation also arms adopted intents)
            r.timer(self.service.tx_timeout_us,
                    lambda: self._probe(txid, coord))
            return
        if txid not in r.app.pending:
            self._armed.discard(txid)
            return
        if not 0 <= coord < len(self.service.shards):
            return      # malformed coordinator index: nothing to consult
        coord_cluster = self.service.shards[coord]
        self._sigwait[txid] = {
            "coord": coord, "by_outcome": {},
            "need": coord_cluster.replicas[0].f + 1,
        }
        body = (txid,)
        size = crypto.wire_size_shallow(body) + 19
        for pid in coord_cluster.replica_pids:   # resolved live: epoch-aware
            r.send(pid, "TXDEC", body, size=size)
        # re-probe until resolved (coordinator shard may be mid-view-change)
        r.timer(self.service.tx_timeout_us, lambda: self._probe(txid, coord))

    # --------------------------------------- coordinator role: TXDEC/TXOUT
    def _on_txdec(self, src: str, body: Any) -> None:
        """A recoverer asks this coordinator-shard replica for a signed
        outcome statement.  Recorded outcome → sign and answer.  None yet →
        route DECIDE(abort) through this shard's consensus (presumed abort)
        and answer once the record exists (``_on_execute``)."""
        r = self.replica
        if r.crashed or r.joining:
            return
        (txid,) = body
        if not (isinstance(txid, bytes) and len(txid) == TXID_LEN):
            return
        out = r.app.outcomes.get(txid)
        if out is not None:
            self._send_txout({src}, txid, out)
            return
        self._want_outcome.setdefault(txid, set()).add(src)
        r.propose_internal(("svc", "tdec", txid), tdecide_req(txid, b"A"))

    def _answer_outcome(self, txid: bytes, outcome: bytes) -> None:
        waiting = self._want_outcome.pop(txid, None)
        if waiting:
            self._send_txout(waiting, txid, outcome)

    def _send_txout(self, requesters: set, txid: bytes,
                    outcome: bytes) -> None:
        r = self.replica

        def signed(sig: bytes) -> None:
            body = (txid, outcome, sig)
            size = crypto.wire_size_shallow(body) + 19
            for pid in requesters:
                r.send(pid, "TXOUT", body, size=size)

        r.async_sign(("txout", txid, outcome), signed)

    # ------------------------------------------ recoverer role: collection
    def _on_txout(self, src: str, body: Any) -> None:
        txid, outcome, sig = body
        st = self._sigwait.get(txid)
        if st is None or outcome not in (b"C", b"A"):
            return

        def verified(ok: bool) -> None:
            cur = self._sigwait.get(txid)
            if not ok or cur is not st:
                return      # forged statement, or probe superseded meanwhile
            by = st["by_outcome"].setdefault(outcome, {})
            by[src] = sig
            if len(by) < st["need"]:
                return
            del self._sigwait[txid]
            cert = tuple(sorted(by.items()))
            self.replica.propose_internal(
                ("svc", "tfin", txid, outcome),
                rfinish_req(txid, outcome, cert))

        self.replica.async_verify(src, ("txout", txid, outcome), sig,
                                  verified)

    # ------------------------------------------------- svc slot validation
    def _tdec_certifiable(self, rid: tuple, payload: bytes) -> bool:
        """An internal DECIDE slot is endorsable iff it is exactly a
        well-formed presumed-abort proposal: the state machine's
        owner-binding already makes any internal commit unrecordable, so
        abort-only framing is the whole check."""
        if len(rid) != 3:
            return False
        txid = rid[2]
        return (isinstance(txid, bytes) and len(txid) == TXID_LEN
                and payload == tdecide_req(txid, b"A"))

    def _tfin_certifiable(self, rid: tuple, payload: bytes) -> bool:
        """May this replica endorse/certify a ``("svc","tfin",...)`` slot?

        Malformed FINISH slots are never certified.  A FINISH for a
        transaction this shard no longer holds pending is harmless
        (``_finish_tx`` just records the outcome) and must be endorsed, or
        a replica whose intent already resolved would block the slot.  For
        a *still-pending* intent the outcome matters — C applies the
        pairs — so the payload must carry an outcome certificate: f+1
        signatures over ``("txout", txid, outcome)`` from current members
        of the transaction's coordinator shard.  f+1 guarantees at least
        one honest signer, and an honest coordinator replica only signs
        its shard's replicated outcome record.
        """
        if len(rid) != 4:
            return False
        _svc, _kind, txid, outcome = rid
        if not (isinstance(txid, bytes) and len(txid) == TXID_LEN
                and outcome in (b"C", b"A")):
            return False
        if not isinstance(payload, bytes):
            return False
        parsed = parse_rfinish(payload)
        if parsed is None or parsed[0] != txid or parsed[1] != outcome:
            return False
        entry = self.replica.app.pending.get(txid)
        if entry is None:
            return True
        coord = entry[1]
        if not 0 <= coord < len(self.service.shards):
            return False
        coord_cluster = self.service.shards[coord]
        members = set(coord_cluster.replica_pids)
        need = coord_cluster.replicas[0].f + 1
        good = {pid for pid, sig in parsed[2]
                if pid in members and self.replica.registry.verify(
                    pid, ("txout", txid, outcome), sig)}
        return len(good) >= need


class ShardedService:
    """K uBFT groups over one substrate, presented as one keyspace."""

    def __init__(self, substrate: Substrate, name: str,
                 shards: List[Cluster], router: ShardRouter,
                 tx_timeout_us: float, tx_secret: int = 0):
        self.substrate = substrate
        self.name = name
        self.shards = shards
        self.router = router
        self.tx_timeout_us = tx_timeout_us
        #: seeds each client's txid-nonce stream (stands in for per-client
        #: CSPRNG state; vary it to vary the nonces across runs)
        self.tx_secret = tx_secret
        self.clients: List[ServiceClient] = []
        #: every live recovery instance (originals + joiners), for
        #: observability and bounded-state assertions in tests
        self.recoveries: List[_TxRecovery] = []
        #: shard indices retired by a merge — still attached (in-flight
        #: 2PC outcome records must stay probeable) but unroutable
        self.retired: set = set()
        #: (sim time, kind, src_idx, dst_idx, ranges, router_epoch) per
        #: completed reshard operation
        self.reshards: List[tuple] = []
        #: (sim time, phase) per transfer state transition of the current
        #: reshard — where a split spends its time under load
        self.reshard_trace: List[tuple] = []
        #: clients parked on a FROZEN bounce, woken when the router epoch
        #: bumps (instead of polling the frozen shard with retry slots)
        self._epoch_waiters: List[Callable[[], None]] = []
        #: one reshard in flight at a time (the control plane serialises
        #: epoch bumps; concurrent splits would race on the router table)
        self.resharding = False
        # retained so split_shard can attach new groups with the same
        # shape as the original fleet (set by attach())
        self._app_factory: Callable[[], App] = ShardKVApp
        self._cfg: Optional[Any] = None
        self._budget: int = POOL_MEMORY_BUDGET
        self._pools: Optional[Any] = None
        #: self-heal every shard group (True / HealthConfig / dict); split-
        #: born shards inherit it because _wire_shard applies it
        self._self_heal: Optional[Any] = None

    @classmethod
    def attach(cls, substrate: Substrate, n_shards: int, name: str = "kv",
               cfg: Optional[Any] = None,
               app: Callable[[], App] = ShardKVApp,
               budget: int = POOL_MEMORY_BUDGET,
               tx_timeout_us: float = 20_000.0,
               tx_secret: int = 0,
               pools: Optional[Any] = None,
               self_heal: Optional[Any] = None) -> "ShardedService":
        """Attach ``n_shards`` groups (``<name>/s<i>``) to the substrate.

        ``cfg`` is one :class:`ConsensusConfig` shared by every shard
        (fixed per-shard config — the benchmark's scaling axis) or a
        callable ``cfg(i)`` for heterogeneous shards.  ``app`` must build
        a 2PC-capable store (:class:`~repro.apps.kvstore.ShardKVApp` or a
        subclass) when cross-shard MSETs will be issued.
        """
        if name in substrate.services:
            raise ValueError(f"service {name!r} already attached")
        router = ShardRouter(n_shards)
        shards: List[Cluster] = []
        for i in range(n_shards):
            kw: Dict[str, Any] = {}
            if pools is not None:
                kw["pools"] = pools
            shards.append(Cluster.attach(
                substrate, app, name=f"{name}/s{i}",
                cfg=(cfg(i) if callable(cfg) else cfg), budget=budget, **kw))
        svc = cls(substrate, name, shards, router, tx_timeout_us,
                  tx_secret=tx_secret)
        svc._app_factory = app
        svc._cfg = cfg
        svc._budget = budget
        svc._pools = pools
        svc._self_heal = self_heal
        for i, cluster in enumerate(shards):
            svc._wire_shard(i, cluster)
        substrate.services[name] = svc
        return svc

    def _wire_shard(self, idx: int, cluster: Cluster) -> None:
        """Attach the service-layer per-replica machinery to one shard:
        2PC recovery timers and the reshard-slot endorsement validators."""
        for ridx, r in enumerate(cluster.replicas):
            self.recoveries.append(
                _TxRecovery(self, idx, r, stagger_us=200.0 + 150.0 * ridx))
            self._install_reshard_validators(r)
        # membership epoch switches must not shrink the recovery
        # fleet: every joiner gets its own recovery instance, which
        # arms probes for snapshot-adopted intents on activation
        def on_replace(_old, joiner, _i=idx, _c=cluster):
            self.recoveries.append(_TxRecovery(
                self, _i, joiner,
                stagger_us=200.0 + 150.0 * _c.replicas.index(joiner)))
            self._install_reshard_validators(joiner)
        cluster.replace_hooks.append(on_replace)
        if self._self_heal:
            cluster.enable_self_healing(self._self_heal)

    # ------------------------------------------- reshard slot endorsement
    def _install_reshard_validators(self, replica: UbftReplica) -> None:
        v = replica.svc_validators
        v["sfreeze"] = self._freeze_certifiable
        v["scap"] = self._capture_certifiable
        v["scut"] = (lambda rid, payload, _r=replica:
                     self._cut_certifiable(rid, payload, _r))
        v["radopt"] = (lambda rid, payload, _r=replica:
                       self._adopt_certifiable(rid, payload, _r))

    @staticmethod
    def _range_rid_ok(fields) -> bool:
        """Shared well-formedness guard: a Byzantine leader controls rid
        contents, so every field is type- and bounds-checked before it
        reaches a struct.pack."""
        return (all(isinstance(x, int) and not isinstance(x, bool)
                    for x in fields)
                and 1 <= fields[0] < 2 ** 32          # modulus
                and 0 <= fields[1] < fields[0])        # residue

    @classmethod
    def _freeze_certifiable(cls, rid: tuple, payload: Any) -> bool:
        """``("svc","sfreeze", mod, res, target, repoch)`` — exact
        payload match.  A forged freeze is a pure liveness attack (writes
        to the range bounce until an operator intervenes, costing the
        Byzantine leader its view); it can never lose or plant data, so
        framing is the whole check."""
        if len(rid) != 6 or not cls._range_rid_ok(rid[2:]):
            return False
        mod, res, target, repoch = rid[2:]
        if not (0 <= target < 2 ** 16 and 0 <= repoch < 2 ** 32):
            return False
        return payload == freeze_req(mod, res, target, repoch)

    @classmethod
    def _capture_certifiable(cls, rid: tuple, payload: Any) -> bool:
        """``("svc","scap", mod, res, repoch)`` — exact payload match;
        the state machine refuses a capture without a prior freeze."""
        if len(rid) != 5 or not cls._range_rid_ok(rid[2:4]):
            return False
        mod, res, repoch = rid[2:]
        if not (isinstance(repoch, int) and 0 <= repoch < 2 ** 32):
            return False
        return payload == capture_req(mod, res)

    def _cut_certifiable(self, rid: tuple, payload: Any,
                         replica: UbftReplica) -> bool:
        """``("svc","scut", mod, res, target, repoch)``: endorsed only
        with f+1 target-shard signatures over ``("adopted", ...)`` in the
        payload — the cut deletes the range at the source, so it must be
        provably preceded by a committed adoption, or a Byzantine leader
        could destroy data with a forged freeze/capture/cut sequence."""
        if len(rid) != 6 or not self._range_rid_ok(rid[2:]):
            return False
        mod, res, target, repoch = rid[2:]
        if not (0 <= target < 2 ** 16 and 0 <= repoch < 2 ** 32):
            return False
        if not isinstance(payload, bytes):
            return False
        parsed = parse_cut(payload)
        if parsed is None or parsed[:4] != (mod, res, target, repoch):
            return False
        if not 0 <= target < len(self.shards):
            return False
        tgt = self.shards[target]
        members = set(tgt.replica_pids)
        need = tgt.replicas[0].f + 1
        good = {pid for pid, sig in parsed[4]
                if pid in members and replica.registry.verify(
                    pid, ("adopted", mod, res, repoch), sig)}
        return len(good) >= need

    def _adopt_certifiable(self, rid: tuple, payload: Any,
                           replica: UbftReplica) -> bool:
        """``("svc","radopt", src_idx, mod, res, repoch)``: endorsed only
        when the payload's pairs match its fingerprint and f+1 *source*
        shard members signed ``("resh", mod, res, repoch, fp)`` — so a
        Byzantine leader of the adopting shard cannot plant forged keys
        via a fabricated adopt slot (mirrors the recovery FINISH's
        outcome certificate)."""
        if len(rid) != 6:
            return False
        src_idx, mod, res, repoch = rid[2:]
        if not all(isinstance(x, int) and not isinstance(x, bool)
                   for x in rid[2:]):
            return False
        if not isinstance(payload, bytes):
            return False
        parsed = parse_adopt(payload)
        if parsed is None or parsed[:4] != (mod, res, src_idx, repoch):
            return False
        if not 0 <= src_idx < len(self.shards):
            return False
        pairs, cert = parsed[4], parsed[5]
        fp = range_fp(mod, res, repoch, pairs)
        src = self.shards[src_idx]
        members = set(src.replica_pids)
        need = src.replicas[0].f + 1
        good = {pid for pid, sig in cert
                if pid in members and replica.registry.verify(
                    pid, ("resh", mod, res, repoch, fp), sig)}
        return len(good) >= need

    # ------------------------------------------------------- split / merge
    #: control-plane poll cadence (µs) while a reshard is in flight.  The
    #: cadence bounds the freeze window: while a range is frozen its
    #: writes bounce, and every bounce costs the *source* shard a
    #: consensus slot — on a hot shard a leisurely control plane lets
    #: that surge push the queue past the §5.4 direct-copy horizon, where
    #: every slot decays to the slow-path kick.  Microsecond polls keep
    #: the whole transfer well under that cliff.
    _POLL_US = 25.0
    #: register re-read cadence (µs) while a target replica waits for f+1
    #: matching published ranges
    _PULL_RETRY_US = 150.0

    def split_shard(self, idx: int,
                    when_done: Optional[Callable[[], None]] = None) -> int:
        """Split shard ``idx``: attach a fresh 2f+1 group as shard
        ``len(shards)`` and hand it the upper child of ``idx``'s coarsest
        key range (``router.peek_split``).  Returns the new shard index
        immediately; the transfer runs asynchronously (freeze → drain →
        capture → publish via the shared pools → adopt → cut → router
        epoch bump) — drive the simulator and watch ``reshards`` or pass
        ``when_done``."""
        if self.resharding:
            raise RuntimeError("a reshard operation is already in flight")
        if not 0 <= idx < len(self.shards) or idx in self.retired:
            raise ValueError(f"cannot split shard {idx}")
        self.resharding = True
        rng = self.router.peek_split(idx)
        new_idx = len(self.shards)
        kw: Dict[str, Any] = {}
        if self._pools is not None:
            kw["pools"] = self._pools
        cluster = Cluster.attach(
            self.substrate, self._app_factory,
            name=f"{self.name}/s{new_idx}",
            cfg=(self._cfg(new_idx) if callable(self._cfg) else self._cfg),
            budget=self._budget, **kw)
        self.shards.append(cluster)
        self._wire_shard(new_idx, cluster)
        repoch = self.router.epoch + 1

        def commit() -> None:
            moved = self.router.commit_split(idx, new_idx)
            assert moved == rng and self.router.epoch == repoch
            self.reshards.append(
                (self.sim.now, "split", idx, new_idx, (rng,), repoch))

        self._move_ranges(idx, new_idx, [rng], repoch, commit, when_done)
        return new_idx

    def merge_shards(self, src_idx: int, dst_idx: int,
                     when_done: Optional[Callable[[], None]] = None) -> None:
        """Merge shard ``src_idx`` into ``dst_idx``: every range of the
        source moves (same freeze/transfer/cut pipeline as a split, over
        all of its ranges), then the source index is retired.  The
        retired group stays attached — its 2PC outcome records must
        remain probeable by recovery — but is unroutable from the table
        on."""
        if self.resharding:
            raise RuntimeError("a reshard operation is already in flight")
        if src_idx == dst_idx:
            raise ValueError("merge needs two distinct shards")
        for i in (src_idx, dst_idx):
            if not 0 <= i < len(self.shards) or i in self.retired:
                raise ValueError(f"cannot merge shard {i}")
        self.resharding = True
        ranges = self.router.ranges_of(src_idx)
        repoch = self.router.epoch + 1

        def commit() -> None:
            self.router.commit_merge(src_idx, dst_idx)
            assert self.router.epoch == repoch
            self.retired.add(src_idx)
            self.shards[src_idx].retired = True
            self.reshards.append(
                (self.sim.now, "merge", src_idx, dst_idx, tuple(ranges),
                 repoch))

        self._move_ranges(src_idx, dst_idx, ranges, repoch, commit,
                          when_done)

    # ------------------------------------------------ transfer state machine
    def _live(self, cluster: Cluster) -> List[UbftReplica]:
        return [r for r in cluster.replicas
                if not r.crashed and not r.joining]

    def _quorum(self, cluster: Cluster, pred) -> bool:
        need = cluster.replicas[0].f + 1
        return sum(1 for r in self._live(cluster) if pred(r)) >= need

    def _poll(self, cond, then, tick=None) -> None:
        def probe() -> None:
            if tick is not None:
                tick()
            if cond():
                then()
            else:
                self.sim.after(self._POLL_US, probe)
        probe()

    def _move_ranges(self, src_idx: int, dst_idx: int,
                     ranges: List[Tuple[int, int]], repoch: int,
                     commit: Callable[[], None],
                     when_done: Optional[Callable[[], None]]) -> None:
        """Drive one set of key ranges from ``src_idx`` to ``dst_idx``.

        Every state transition is either a BFT slot in an affected
        shard's log (freeze, capture, adopt, cut) or a write/read of the
        shared register pools (the captured range travels the same
        disaggregated-memory path as a membership state transfer); the
        control plane itself only *observes* replica state and submits
        the next slot — it holds no authority any replica trusts, so a
        crashed control plane strands no shard in an unsafe state (a
        frozen range is an availability, not a safety, condition)."""
        src, dst = self.shards[src_idx], self.shards[dst_idx]
        ranges = [tuple(rng) for rng in ranges]
        self.reshard_trace = [(self.sim.now, "start")]
        trace = lambda ph: self.reshard_trace.append((self.sim.now, ph))

        def frozen(rep) -> bool:
            return all((m, r) in rep.app.moving or (m, r) in rep.app.handoff
                       for (m, r) in ranges)

        def drained(rep) -> bool:
            return not any(key_in_range(k, m, r)
                           for (m, r) in ranges for k in rep.app.locks)

        def captured(rep) -> bool:
            return all((m, r) in rep.app.outbound
                       or (m, r) in rep.app.handoff
                       for (m, r) in ranges)

        def adopted(rep) -> bool:
            return all(rep.app.adopted.get((m, r)) == repoch
                       for (m, r) in ranges)

        def cut_done(rep) -> bool:
            return all((m, r) in rep.app.handoff for (m, r) in ranges)

        # 1. FREEZE every moving range: from that log position on, writes
        #    and new PREPAREs bounce; reads are still served at the source
        for (m, r) in ranges:
            src.submit_internal(("svc", "sfreeze", m, r, dst_idx, repoch),
                                freeze_req(m, r, dst_idx, repoch))

        # 2. drain in-flight 2PC: transactions prepared under the old
        #    epoch hold in-range locks and must finish at the source (the
        #    freeze stops new in-range locks, recovery timers bound the
        #    wait), then CAPTURE fixes the outbound snapshot in the log
        def capture() -> None:
            trace("drained")
            for (m, r) in ranges:
                src.submit_internal(("svc", "scap", m, r, repoch),
                                    capture_req(m, r))
            self._poll(lambda: self._quorum(src, captured), publish)

        # 3. PUBLISH: each live source replica signs its (deterministic)
        #    captured range and writes it into its own resh/ register —
        #    the transfer rides the shared pools, not replica-to-replica
        #    messages, exactly like a membership state transfer
        published: set = set()

        def publish_tick() -> None:
            for rep in self._live(src):
                for (m, r) in ranges:
                    if ((rep.pid, m, r) in published
                            or (m, r) not in rep.app.outbound):
                        continue
                    published.add((rep.pid, m, r))
                    self._publish_range(rep, m, r, repoch)

        def publish() -> None:
            trace("captured")
            publish_tick()
            # 4. ADOPT: every live target replica pulls the range from
            #    f+1 matching registers and proposes the adopt slot
            for d in self._live(dst):
                for (m, r) in ranges:
                    self._pull_range(d, src, src_idx, m, r, repoch)
            self._poll(lambda: self._quorum(dst, adopted), cut_phase,
                       tick=publish_tick)

        # 5. CUT: only after the adoption has provably committed at the
        #    target (f+1 signatures ride the cut slot's certificate) does
        #    the source drop the range and start answering MOVED
        def cut_phase() -> None:
            trace("adopted")
            for (m, r) in ranges:
                self._collect_adoption_cert(
                    dst, m, r, repoch,
                    lambda cert, m=m, r=r: src.submit_internal(
                        ("svc", "scut", m, r, dst_idx, repoch),
                        cut_req(m, r, dst_idx, repoch, cert)))
            self._poll(lambda: self._quorum(src, cut_done), finish)

        # 6. the router-table mutation commits last: every client routing
        #    on the old table in the meantime was answered FROZEN/MOVED,
        #    never with stale data
        def finish() -> None:
            trace("cut")
            commit()
            self.resharding = False
            waiters, self._epoch_waiters = self._epoch_waiters, []
            for w in waiters:
                self.sim.after(0.0, w)
            if when_done is not None:
                when_done()

        self._poll(lambda: self._quorum(src, frozen),
                   lambda: (trace("frozen"),
                            self._poll(lambda: self._quorum(src, drained),
                                       capture))[-1])

    def _publish_range(self, rep: UbftReplica, m: int, r: int,
                       repoch: int) -> None:
        pairs = rep.app.outbound[(m, r)]
        fp = range_fp(m, r, repoch, pairs)

        def signed(sig: bytes) -> None:
            rep.regs.write(f"resh/{repoch}/{m}/{r}",
                           crypto.encode((pairs, sig)), lambda: None)

        rep.async_sign(("resh", m, r, repoch, fp), signed)

    def _pull_range(self, d: UbftReplica, src_cluster: Cluster,
                    src_idx: int, m: int, r: int, repoch: int) -> None:
        """One target replica pulls a published range: read every source
        replica's ``resh/`` register (routed under the *source* cluster's
        namespace), verify each signature against the advertised pairs,
        and propose the adopt slot once f+1 registers agree on one
        fingerprint.  Retries on a timer until the adoption executes —
        registers survive source-replica crashes, so f+1 completed
        publishes are durable."""
        reg = f"resh/{repoch}/{m}/{r}"
        need = src_cluster.replicas[0].f + 1

        def attempt() -> None:
            if (d.crashed or d.joining
                    or d.app.adopted.get((m, r)) == repoch):
                return
            got: Dict[str, tuple] = {}

            def mk(pid: str):
                def cb(val, _byz) -> None:
                    if val is None or d.app.adopted.get((m, r)) == repoch:
                        return
                    try:
                        pairs, sig = crypto.decode(val[1])
                    except Exception:
                        return      # torn/garbage blob: ignore this reader
                    pairs = tuple((bytes(k), bytes(v)) for (k, v) in pairs)
                    fp = range_fp(m, r, repoch, pairs)
                    if not d.registry.verify(
                            pid, ("resh", m, r, repoch, fp), sig):
                        return
                    got[pid] = (fp, pairs, sig)
                    by_fp: Dict[bytes, list] = {}
                    for p, (f_, pr, s) in got.items():
                        by_fp.setdefault(f_, []).append((p, pr, s))
                    for entries in by_fp.values():
                        if len(entries) >= need:
                            cert = tuple(sorted(
                                (p, s) for p, _pr, s in entries))
                            d.propose_internal(
                                ("svc", "radopt", src_idx, m, r, repoch),
                                adopt_req(m, r, src_idx, repoch,
                                          entries[0][1], cert))
                            return
                return cb

            for pid in src_cluster.replica_pids:
                d.regs.read(pid, reg, mk(pid), namespace=src_cluster.name)
            d.timer(self._PULL_RETRY_US, attempt)

        attempt()

    def _collect_adoption_cert(self, dst: Cluster, m: int, r: int,
                               repoch: int, cb) -> None:
        """Gather f+1 target-replica signatures over
        ``("adopted", m, r, repoch)`` — the evidence the cut slot carries."""
        need = dst.replicas[0].f + 1
        sigs: Dict[str, Optional[bytes]] = {}
        state = {"done": False}

        def tick() -> None:
            if state["done"]:
                return
            for d in self._live(dst):
                if d.pid in sigs or d.app.adopted.get((m, r)) != repoch:
                    continue
                sigs[d.pid] = None      # signature in flight

                def signed(sig: bytes, pid: str = d.pid) -> None:
                    if state["done"]:
                        return
                    sigs[pid] = sig
                    good = {p: s for p, s in sigs.items() if s is not None}
                    if len(good) >= need:
                        state["done"] = True
                        cb(tuple(sorted(good.items())[:need]))

                d.async_sign(("adopted", m, r, repoch), signed)
            if not state["done"]:
                self.sim.after(self._POLL_US, tick)

        tick()

    # --------------------------------------------- Cluster-like interface
    @property
    def sim(self):
        return self.substrate.sim

    def new_client(self, pid: Optional[str] = None) -> ServiceClient:
        if pid is None:
            pid = f"{self.name}/c{len(self.clients)}"
        c = ServiceClient(self, pid)
        self.clients.append(c)
        return c

    def run_op(self, client: ServiceClient, op: tuple,
               timeout: float = 1_000_000.0) -> Tuple[bytes, float]:
        """Issue one operation and run the simulation to completion."""
        box: dict = {}

        def done(result: bytes, lat: float) -> None:
            box["result"] = result
            box["lat"] = lat

        client.request(op, done)
        ok = self.sim.run_until(lambda: "result" in box, timeout=timeout)
        if not ok:
            raise TimeoutError(f"service op {op[0]!r} did not complete "
                               f"within {timeout} µs (t={self.sim.now})")
        return box["result"], box["lat"]
