"""ShardedService: K independent uBFT groups as one partitioned kvstore.

Scale-out for the service plane: one 2f+1 group caps out around 1 Mops, so
the keyspace is hash-partitioned (:class:`~repro.service.router.ShardRouter`)
across K groups attached to *one* shared substrate (``<name>/s0..s<K-1>``) —
group count is a free variable on fixed infrastructure, exactly what the
PR 4 substrate was built for.

Cross-shard MSET runs as two-phase commit where **each phase is itself a
BFT-committed slot** (DESIGN_SHARDING.md):

* PREPARE — an ordinary consensus request per participant shard
  (:func:`~repro.apps.kvstore.tprep_req`): the shard's replicated state
  machine locks the keys, records the intent, and votes.  The vote is a
  product of the shard's log, so all 2f+1 replicas agree on it.
* DECIDE — one consensus request on the **coordinator shard** (the lowest
  participating shard index): the first DECIDE in its totally-ordered log
  fixes the outcome; every later DECIDE — including a recovery probe
  proposing abort — reads that record back.  The outcome is therefore
  unique and replicated *without any separate BFT coordinator group*.
* FINISH — a consensus request per participant shard applying or
  discarding the intent and releasing its locks.

The *client* driving the phases is untrusted for safety (a client lying
about the outcome could only tear its own transaction, which is
indistinguishable from it issuing legal single-key SETs) but is relied on
for progress — so every replica arms a **presumed-abort recovery timer**
when it executes a PREPARE (:class:`_TxRecovery`): if the intent is still
pending past its deadline, the replica itself sends DECIDE(abort) to the
coordinator shard, collects f+1 matching replies (so the answer comes from
the replicated record, not from any single — possibly Byzantine — replica),
and routes the resulting FINISH into its own shard as a deterministic
``("svc", ...)`` slot that all replicas' concurrent submissions dedupe
into.  A transaction whose client vanished after a committed DECIDE is
thus *finished forward*; one abandoned before DECIDE is aborted.
"""

from __future__ import annotations

import struct
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.apps.kvstore import (VOTE_OK, ShardKVApp, get_req, mset_req,
                                parse_tprep, set_req, tdecide_req,
                                tfinish_req, tprep_req)
from repro.core import crypto
from repro.core.consensus import App, ConsensusConfig, UbftReplica
from repro.core.registers import POOL_MEMORY_BUDGET
from repro.core.smr import Cluster
from repro.core.substrate import Substrate
from repro.service.router import ShardRouter


class ServiceClient:
    """Routes operations to shards; runs cross-shard MSETs as 2PC.

    Operations are structured tuples (the router needs the key *before*
    the wire encoding picks a shard):

    * ``("get", key)`` / ``("set", key, value)`` — routed to one shard
    * ``("mset", pairs)`` — single-shard: one plain MSET slot;
      cross-shard: PREPARE / DECIDE / FINISH as described in the module
      docstring.  Completes ``cb(b"OK" | b"ABORTED", latency)``.

    One underlying uBFT :class:`~repro.core.smr.Client` per shard, created
    via ``Cluster.new_client`` — so a membership epoch switch on any shard
    (``replace_replica``) updates this client's destination pids in place
    and nothing here ever caches a replica address.
    """

    #: test knobs simulating a coordinator-client crash mid-2PC: drop the
    #: protocol on the floor after PREPARE (before DECIDE) / after DECIDE
    #: (before FINISH) — recovery must then abort / finish-forward
    drop_decide = False
    drop_finish = False

    def __init__(self, service: "ShardedService", pid: str):
        self.service = service
        self.pid = pid
        self.sim = service.sim
        self.router = service.router
        self.shard_clients = [c.new_client() for c in service.shards]
        self._txseq = 0
        self._tx_salt = zlib.crc32(pid.encode())
        self.latencies: List[float] = []

    # ------------------------------------------------------------ routing
    def request(self, op: tuple,
                cb: Optional[Callable[[bytes, float], None]] = None) -> None:
        kind = op[0]
        if kind == "get":
            return self._one(self.router.shard_of(op[1]), get_req(op[1]), cb)
        if kind == "set":
            return self._one(self.router.shard_of(op[1]),
                             set_req(op[1], op[2]), cb)
        if kind == "mset":
            by_shard = self.router.split(list(op[1]))
            if len(by_shard) == 1:
                ((s, pairs),) = by_shard.items()
                return self._one(s, mset_req(pairs), cb)
            return self._mset_2pc(by_shard, cb)
        raise ValueError(f"unknown service op {kind!r}")

    def _one(self, shard: int, payload: bytes,
             cb: Optional[Callable[[bytes, float], None]]) -> None:
        def done(result: bytes, lat: float) -> None:
            self.latencies.append(lat)
            if cb is not None:
                cb(result, lat)
        self.shard_clients[shard].request(payload, done)

    # -------------------------------------------------------- 2PC phases
    def _mset_2pc(self, by_shard: Dict[int, list],
                  cb: Optional[Callable[[bytes, float], None]]) -> None:
        t0 = self.sim.now
        txid = struct.pack("<II", self._tx_salt, self._txseq)
        self._txseq += 1
        shards = sorted(by_shard)
        coord = shards[0]
        deadline = t0 + self.service.tx_timeout_us
        votes: Dict[int, bytes] = {}

        def vote(s: int):
            def done(result: bytes, _lat: float) -> None:
                votes[s] = result
                if len(votes) == len(shards):
                    self._decide(txid, shards, coord, votes, cb, t0)
            return done

        for s in shards:
            self.shard_clients[s].request(
                tprep_req(txid, deadline, coord, by_shard[s]), vote(s))

    def _decide(self, txid: bytes, shards: List[int], coord: int,
                votes: Dict[int, bytes],
                cb: Optional[Callable[[bytes, float], None]],
                t0: float) -> None:
        if self.drop_decide:
            return      # "crashed" between PREPARE and DECIDE
        proposed = b"C" if all(v == VOTE_OK for v in votes.values()) else b"A"

        def decided(result: bytes, _lat: float) -> None:
            # the reply's recorded outcome is authoritative — a recovery
            # timer may have beaten a slow commit DECIDE to the log
            outcome = result[-1:] if result[:3] == b"OUT" else b"A"
            self._finish(txid, shards, outcome, cb, t0)

        self.shard_clients[coord].request(tdecide_req(txid, proposed),
                                          decided)

    def _finish(self, txid: bytes, shards: List[int], outcome: bytes,
                cb: Optional[Callable[[bytes, float], None]],
                t0: float) -> None:
        if self.drop_finish:
            return      # "crashed" between DECIDE and FINISH
        left = {"n": len(shards)}

        def done(_result: bytes, _lat: float) -> None:
            left["n"] -= 1
            if left["n"] == 0:
                lat = self.sim.now - t0
                self.latencies.append(lat)
                if cb is not None:
                    cb(b"OK" if outcome == b"C" else b"ABORTED", lat)

        for s in shards:
            self.shard_clients[s].request(tfinish_req(txid, outcome), done)


class _TxRecovery:
    """Per-replica presumed-abort recovery for abandoned transactions.

    Watches the replica's own execution stream (``on_execute_hooks``): a
    PREPARE that voted OK arms a timer at the transaction deadline (plus a
    per-replica stagger so recoverers probe in sequence rather than in a
    thundering herd).  If the intent is still pending when the timer fires,
    the replica acts as a client of the coordinator shard: it sends
    DECIDE(abort) — which the coordinator's log either adopts (first
    DECIDE wins → abort) or answers with the already-recorded outcome
    (→ finish forward) — waits for f+1 matching replies, then proposes
    FINISH into its own shard under the deterministic rid
    ``("svc", "tfin", txid, outcome)`` so concurrent recoverers collapse
    into one slot.  Probes re-arm until the intent resolves, so a
    coordinator-shard view change mid-probe only delays recovery.
    """

    def __init__(self, service: "ShardedService", shard_idx: int,
                 replica: UbftReplica, stagger_us: float):
        self.service = service
        self.shard_idx = shard_idx
        self.replica = replica
        self.stagger_us = stagger_us
        self._seq = 0
        self._outstanding: Dict[tuple, dict] = {}
        replica.on_execute_hooks.append(self._on_execute)
        replica.handle("REP", self._on_rep)   # replicas never receive REP

    def _on_execute(self, _slot: int, _rid: tuple, payload: bytes,
                    result: bytes) -> None:
        if payload[:1] != b"P" or result != VOTE_OK:
            return
        parsed = parse_tprep(payload)
        if parsed is None:
            return
        txid, deadline, coord, _pairs = parsed
        delay = max(deadline - self.replica.sim.now, 0.0) + self.stagger_us
        self.replica.timer(delay, lambda: self._probe(txid, coord))

    def _probe(self, txid: bytes, coord: int) -> None:
        r = self.replica
        if r.crashed or r.joining or txid not in r.app.pending:
            return
        if not 0 <= coord < len(self.service.shards):
            return      # malformed coordinator index: nothing to consult
        rid = (r.pid, "tx", self._seq)
        self._seq += 1
        coord_cluster = self.service.shards[coord]
        self._outstanding[rid] = {
            "txid": txid, "replies": {},
            "need": coord_cluster.replicas[0].f + 1, "done": False,
        }
        body = (rid, tdecide_req(txid, b"A"))
        size = crypto.wire_size_shallow(body) + 19
        for pid in coord_cluster.replica_pids:   # resolved live: epoch-aware
            r.send(pid, "REQ", body, size=size)
        # re-probe until resolved (coordinator shard may be mid-view-change)
        r.timer(self.service.tx_timeout_us, lambda: self._probe(txid, coord))

    def _on_rep(self, src: str, body: Any) -> None:
        rid, result = body
        st = self._outstanding.get(rid)
        if st is None or st["done"]:
            return
        who = st["replies"].setdefault(bytes(result), set())
        who.add(src)
        if len(who) < st["need"]:
            return
        st["done"] = True
        del self._outstanding[rid]
        if result[:3] != b"OUT":
            return      # coordinator shard answered ERR: leave to re-probe
        outcome, txid = result[-1:], st["txid"]
        self.replica.propose_internal(("svc", "tfin", txid, outcome),
                                      tfinish_req(txid, outcome))


class ShardedService:
    """K uBFT groups over one substrate, presented as one keyspace."""

    def __init__(self, substrate: Substrate, name: str,
                 shards: List[Cluster], router: ShardRouter,
                 tx_timeout_us: float):
        self.substrate = substrate
        self.name = name
        self.shards = shards
        self.router = router
        self.tx_timeout_us = tx_timeout_us
        self.clients: List[ServiceClient] = []

    @classmethod
    def attach(cls, substrate: Substrate, n_shards: int, name: str = "kv",
               cfg: Optional[Any] = None,
               app: Callable[[], App] = ShardKVApp,
               budget: int = POOL_MEMORY_BUDGET,
               tx_timeout_us: float = 20_000.0,
               pools: Optional[Any] = None) -> "ShardedService":
        """Attach ``n_shards`` groups (``<name>/s<i>``) to the substrate.

        ``cfg`` is one :class:`ConsensusConfig` shared by every shard
        (fixed per-shard config — the benchmark's scaling axis) or a
        callable ``cfg(i)`` for heterogeneous shards.  ``app`` must build
        a 2PC-capable store (:class:`~repro.apps.kvstore.ShardKVApp` or a
        subclass) when cross-shard MSETs will be issued.
        """
        if name in substrate.services:
            raise ValueError(f"service {name!r} already attached")
        router = ShardRouter(n_shards)
        shards: List[Cluster] = []
        for i in range(n_shards):
            kw: Dict[str, Any] = {}
            if pools is not None:
                kw["pools"] = pools
            shards.append(Cluster.attach(
                substrate, app, name=f"{name}/s{i}",
                cfg=(cfg(i) if callable(cfg) else cfg), budget=budget, **kw))
        svc = cls(substrate, name, shards, router, tx_timeout_us)
        for i, cluster in enumerate(shards):
            for idx, r in enumerate(cluster.replicas):
                _TxRecovery(svc, i, r, stagger_us=200.0 + 150.0 * idx)
        substrate.services[name] = svc
        return svc

    # --------------------------------------------- Cluster-like interface
    @property
    def sim(self):
        return self.substrate.sim

    def new_client(self, pid: Optional[str] = None) -> ServiceClient:
        if pid is None:
            pid = f"{self.name}/c{len(self.clients)}"
        c = ServiceClient(self, pid)
        self.clients.append(c)
        return c

    def run_op(self, client: ServiceClient, op: tuple,
               timeout: float = 1_000_000.0) -> Tuple[bytes, float]:
        """Issue one operation and run the simulation to completion."""
        box: dict = {}

        def done(result: bytes, lat: float) -> None:
            box["result"] = result
            box["lat"] = lat

        client.request(op, done)
        ok = self.sim.run_until(lambda: "result" in box, timeout=timeout)
        if not ok:
            raise TimeoutError(f"service op {op[0]!r} did not complete "
                               f"within {timeout} µs (t={self.sim.now})")
        return box["result"], box["lat"]
