"""Sharded service plane: a fleet of uBFT groups over one substrate.

:class:`~repro.service.router.ShardRouter` hash-partitions the keyspace,
:class:`~repro.service.sharded.ShardedService` attaches K independent 2f+1
groups to a shared :class:`~repro.core.substrate.Substrate` and runs
cross-shard multi-key operations as two-phase commit where *each phase is
itself a BFT-committed slot* (DESIGN_SHARDING.md).
"""

from repro.service.router import ShardRouter
from repro.service.sharded import ServiceClient, ShardedService

__all__ = ["ShardRouter", "ServiceClient", "ShardedService"]
