"""Deterministic key→shard routing for the sharded service plane.

The routing function is ``crc32(key) % n_shards`` — a pure function of the
key bytes and the shard count, so every client (and every replica running
2PC recovery) maps a key to the same shard with no coordination and no
routing table to replicate.

Epoch-awareness: the router maps keys to *shard indices*, never to replica
pids.  Replica pids are resolved live from each shard's
:attr:`~repro.core.smr.Cluster.replica_pids` at send time, and clients
created via :meth:`Cluster.new_client` have their destination list updated
in place by :meth:`Cluster.replace_replica` — so a PR 5 membership epoch
switch on any shard re-routes in-flight and future traffic without the
router changing at all.  (Shard *split/merge* — changing ``n_shards`` live —
is the remaining ROADMAP work and is out of scope here.)
"""

from __future__ import annotations

import zlib
from typing import Dict, List, Tuple


class ShardRouter:
    """Stateless hash partitioner over ``n_shards`` uBFT groups."""

    def __init__(self, n_shards: int):
        if n_shards < 1:
            raise ValueError("a service needs at least one shard")
        self.n_shards = n_shards

    def shard_of(self, key: bytes) -> int:
        return zlib.crc32(key) % self.n_shards

    def split(self, pairs: List[Tuple[bytes, bytes]]
              ) -> Dict[int, List[Tuple[bytes, bytes]]]:
        """Partition an MSET's pairs by destination shard (insertion order
        within each shard preserved — last write per key wins, as in the
        unsharded app)."""
        by_shard: Dict[int, List[Tuple[bytes, bytes]]] = {}
        for k, v in pairs:
            by_shard.setdefault(self.shard_of(k), []).append((k, v))
        return by_shard
