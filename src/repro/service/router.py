"""Deterministic key→shard routing for the sharded service plane.

The routing function started as ``crc32(key) % K``; with live split/merge
(ISSUE 7) it is an **epoch-versioned routing table** whose entries form a
disjoint binary partition of the crc32 space:

    table[(modulus, residue)] = shard index

Initially ``{(K, r): r for r in range(K)}`` — exactly the old hash
partitioner.  A *split* refines one entry by doubling its modulus: the
entry ``(m, r) -> a`` becomes ``(2m, r) -> a`` and ``(2m, r+m) -> b``, so
exactly the keys with ``crc32(key) % 2m == r+m`` move to the new shard
``b`` and every other key keeps its old home.  A *merge* re-points all of
one shard's entries at another and coalesces sibling entries back to the
coarser modulus.  Both bump ``epoch``.

Shard **indices are append-only**: a split mints a fresh index and a merge
retires one, but indices are never renumbered — the coordinator-shard
index recorded inside an in-flight 2PC PREPARE stays valid across any
sequence of resharding operations (DESIGN_SHARDING.md).

The table itself is *not* the source of truth for data placement — the
shards' replicated state machines are (freeze/cut/adopt slots, committed
in each affected shard's log).  A client routing on a stale table is
answered deterministically with ``FROZEN``/``MOVED`` bounces and retries;
the table is advisory fast-path state, updated by the control plane once
the cut slot has committed.  Replica pids are still resolved live from
each shard's :attr:`~repro.core.smr.Cluster.replica_pids` at send time, so
membership epoch switches (PR 5) remain invisible here.
"""

from __future__ import annotations

import zlib
from typing import Dict, List, Tuple


class ShardRouter:
    """Epoch-versioned binary-refinement partitioner over uBFT groups."""

    def __init__(self, n_shards: int):
        if n_shards < 1:
            raise ValueError("a service needs at least one shard")
        #: bumped by every committed split/merge; mirrors the router-epoch
        #: value the reshard slots record in the affected shards' logs
        self.epoch = 0
        #: (modulus, residue) -> shard index; disjoint cover of crc32 space
        self.table: Dict[Tuple[int, int], int] = {
            (n_shards, r): r for r in range(n_shards)}
        self._moduli: List[int] = [n_shards]

    # ------------------------------------------------------------- lookup
    @property
    def n_shards(self) -> int:
        """Number of *live* (routable) shard indices."""
        return len(set(self.table.values()))

    def shard_of(self, key: bytes) -> int:
        h = zlib.crc32(key)
        for m in self._moduli:
            idx = self.table.get((m, h % m))
            if idx is not None:
                return idx
        raise AssertionError("routing table does not cover the key space")

    def split(self, pairs: List[Tuple[bytes, bytes]]
              ) -> Dict[int, List[Tuple[bytes, bytes]]]:
        """Partition an MSET's pairs by destination shard (insertion order
        within each shard preserved — last write per key wins, as in the
        unsharded app)."""
        by_shard: Dict[int, List[Tuple[bytes, bytes]]] = {}
        for k, v in pairs:
            by_shard.setdefault(self.shard_of(k), []).append((k, v))
        return by_shard

    def ranges_of(self, idx: int) -> List[Tuple[int, int]]:
        """The (modulus, residue) entries currently routed to ``idx``,
        coarsest-first — deterministic across every observer."""
        return sorted((m, r) for (m, r), i in self.table.items() if i == idx)

    # ---------------------------------------------------------- resharding
    def peek_split(self, idx: int) -> Tuple[int, int]:
        """The range a split of ``idx`` would hand off, without mutating:
        the coarsest entry ``(m, r)`` of ``idx`` is refined and its upper
        child ``(2m, r+m)`` moves.  Pure, so the control plane can freeze
        and transfer exactly this range *before* committing the table."""
        owned = self.ranges_of(idx)
        if not owned:
            raise ValueError(f"shard {idx} owns no key range")
        m, r = owned[0]
        return (2 * m, r + m)

    def commit_split(self, idx: int, new_idx: int) -> Tuple[int, int]:
        """Refine ``idx``'s coarsest entry, routing the upper child to
        ``new_idx``.  Returns the moved range; bumps the epoch."""
        owned = self.ranges_of(idx)
        if not owned:
            raise ValueError(f"shard {idx} owns no key range")
        m, r = owned[0]
        del self.table[(m, r)]
        self.table[(2 * m, r)] = idx
        self.table[(2 * m, r + m)] = new_idx
        self._reindex()
        self.epoch += 1
        return (2 * m, r + m)

    def commit_merge(self, src_idx: int, dst_idx: int
                     ) -> List[Tuple[int, int]]:
        """Route every range of ``src_idx`` to ``dst_idx`` (retiring
        ``src_idx``), coalescing sibling entries back to the coarser
        modulus where possible.  Returns the moved ranges; bumps the
        epoch."""
        moved = self.ranges_of(src_idx)
        if not moved:
            raise ValueError(f"shard {src_idx} owns no key range")
        for rng in moved:
            self.table[rng] = dst_idx
        # coalesce: whenever both children (2m, r) and (2m, r+m) route to
        # the same shard, replace them with their parent (m, r)
        changed = True
        while changed:
            changed = False
            for (m, r), i in sorted(self.table.items()):
                if m % 2 != 0 or r >= m // 2:
                    continue
                sib = (m, r + m // 2)
                if self.table.get(sib) == i and self.table.get((m, r)) == i:
                    del self.table[(m, r)]
                    del self.table[sib]
                    self.table[(m // 2, r)] = i
                    changed = True
                    break
        self._reindex()
        self.epoch += 1
        return moved

    def _reindex(self) -> None:
        self._moduli = sorted({m for (m, _r) in self.table})
