"""Session-based LLM serving traffic (multi-turn conversations).

Models a population of users holding multi-turn conversations with a
replicated token server (:class:`repro.runtime.server.TokenServerApp`):
sessions arrive by a (possibly inhomogeneous) Poisson process, each
session runs a geometric number of turns, and every turn submits a
``{"session", "prompt", "n"}`` request — first-turn prompts are long
(the user pastes context), follow-ups short, decode lengths lognormal.
Turn gaps are think times, so a flash crowd of *arrivals* compounds
into sustained request pressure as the sessions it admitted keep
talking.

Everything is drawn from one seeded generator in a documented order —
the same seed reproduces the same trace bit-for-bit.
"""

from __future__ import annotations

import json
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.workloads.arrivals import poisson_times


def llm_session_trace(
    seed: int,
    duration_us: float,
    session_rate_rps: Optional[float] = None,
    session_times: Optional[Sequence[float]] = None,
    mean_turns: float = 3.0,
    think_us: float = 2_000.0,
    first_prompt_tokens: int = 48,
    next_prompt_tokens: int = 12,
    decode_tokens: int = 8,
    vocab: int = 50_257,
    session_prefix: str = "u",
) -> List[Tuple[float, bytes]]:
    """Build a ``(t_us, payload)`` request trace over a session population.

    Exactly one of ``session_rate_rps`` (homogeneous arrivals) and
    ``session_times`` (precomputed, e.g. a flash-crowd curve from
    :mod:`repro.workloads.arrivals`) selects the arrival process.  Per
    session, draws follow in a fixed order: turn count (geometric with
    mean ``mean_turns``), then per turn the think gap (exponential),
    prompt length (Poisson around the per-turn mean, ≥1) and decode
    length (Poisson around ``decode_tokens``, ≥1), then the prompt token
    ids themselves.  Requests past ``duration_us`` are dropped — a turn
    the window never reaches.
    """
    rng = np.random.default_rng(seed)
    if (session_rate_rps is None) == (session_times is None):
        raise ValueError(
            "exactly one of session_rate_rps / session_times is required")
    if session_times is None:
        starts = poisson_times(rng, session_rate_rps, duration_us)
    else:
        starts = np.asarray(session_times, dtype=float)
    trace: List[Tuple[float, bytes]] = []
    p_stop = 1.0 / max(mean_turns, 1.0)
    for i, t0 in enumerate(starts):
        sid = f"{session_prefix}{i}"
        n_turns = int(rng.geometric(p_stop))
        t = float(t0)
        for turn in range(n_turns):
            if turn > 0:
                t += float(rng.exponential(think_us))
            if t >= duration_us:
                break
            mean_prompt = first_prompt_tokens if turn == 0 else next_prompt_tokens
            n_prompt = max(1, int(rng.poisson(mean_prompt)))
            n_decode = max(1, int(rng.poisson(decode_tokens)))
            prompt = rng.integers(0, vocab, size=n_prompt).tolist()
            payload = json.dumps({"session": sid, "prompt": prompt,
                                  "n": n_decode}).encode()
            trace.append((t, payload))
    trace.sort(key=lambda e: e[0])
    return trace
