"""Seeded, deterministic traffic generators (the workload library).

Every generator is a pure function of a ``numpy.random.Generator`` (or a
seed) — same seed, same trace, bit-for-bit — and produces either raw
arrival times in µs (``arrivals``) or full ``(t_us, payload)`` request
traces ready to drive a :class:`repro.scenario.Workload` of kind
``"trace"``:

* :mod:`repro.workloads.arrivals` — inhomogeneous-Poisson arrival
  processes: linear ramps (the sharded benchmark's "rush"), flash-crowd
  trapezoids, diurnal sinusoids, and a general Lewis-Shedler thinning
  driver for arbitrary rate curves;
* :mod:`repro.workloads.matching` — a matching-engine trading day for
  :class:`repro.apps.matching.MatchingEngineApp`: open/close auction
  spikes over a midday baseline, seeded order flow around a drifting
  mid price;
* :mod:`repro.workloads.llm` — session-based LLM serving traffic for
  :class:`repro.runtime.server.TokenServerApp`: a population of
  multi-turn conversations with seeded prompt/decode-length
  distributions and think-time gaps.
"""

from repro.workloads.arrivals import (diurnal_times, flash_crowd_times,
                                      poisson_times, ramp_times,
                                      thinned_times)
from repro.workloads.llm import llm_session_trace
from repro.workloads.matching import auction_day_trace

__all__ = [
    "poisson_times",
    "ramp_times",
    "thinned_times",
    "flash_crowd_times",
    "diurnal_times",
    "auction_day_trace",
    "llm_session_trace",
]
