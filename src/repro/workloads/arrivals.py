"""Inhomogeneous-Poisson arrival processes (seeded, deterministic).

All times are simulated µs from the start of the window; all rates are
requests per *second* (rps), matching :class:`repro.scenario.Workload`.
Every function takes an explicit ``numpy.random.Generator`` and draws
from it in a documented order, so callers can interleave further draws
(key choices, payload sizes) on the same stream reproducibly.
"""

from __future__ import annotations

from typing import Callable, List

import numpy as np


def poisson_times(rng: np.random.Generator, rate_rps: float,
                  duration_us: float) -> np.ndarray:
    """Homogeneous Poisson arrivals: cumulative exponential gaps."""
    r = rate_rps / 1e6
    lam_total = r * duration_us
    gaps = rng.exponential(1.0 / r, size=int(lam_total * 1.1) + 100)
    times = np.cumsum(gaps)
    return times[times <= duration_us]


def ramp_times(rng: np.random.Generator, rate0_rps: float, rate1_rps: float,
               duration_us: float) -> np.ndarray:
    """Linearly ramping Poisson process rate0 → rate1 over the window,
    by inversion of the cumulative intensity Λ(t) = r0·t + slope·t²/2.

    Draw-for-draw identical to the hand-rolled "rush" this generalizes
    (``benchmarks/sharded.py``): exactly one ``rng.exponential`` call of
    size ``int(Λ_total·1.1)+100``, leaving the stream positioned for the
    caller's next draw — the sharded split gate asserts the resulting
    schedule byte-for-byte.
    """
    r0 = rate0_rps / 1e6          # ops per µs at t=0
    r1 = rate1_rps / 1e6
    slope = (r1 - r0) / duration_us
    lam_total = (r0 + r1) / 2.0 * duration_us
    lam = np.cumsum(rng.exponential(1.0, size=int(lam_total * 1.1) + 100))
    lam = lam[lam <= lam_total]
    if slope == 0.0:
        return lam / r0
    # invert Λ(t) = r0·t + slope·t²/2 for each arrival
    return (np.sqrt(r0 * r0 + 2.0 * slope * lam) - r0) / slope


def thinned_times(rng: np.random.Generator,
                  rate_fn: Callable[[float], float], peak_rps: float,
                  duration_us: float) -> np.ndarray:
    """General inhomogeneous Poisson via Lewis-Shedler thinning.

    ``rate_fn(t_us) -> rps`` must be bounded by ``peak_rps``.  Two draws
    per candidate arrival (gap, acceptance), in arrival order.
    """
    peak = peak_rps / 1e6
    out: List[float] = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / peak)
        if t >= duration_us:
            break
        if rng.random() * peak_rps <= rate_fn(t):
            out.append(t)
    return np.asarray(out, dtype=float)


def flash_crowd_rate(base_rps: float, peak_rps: float, t_start_us: float,
                     ramp_us: float, hold_us: float,
                     decay_us: float) -> Callable[[float], float]:
    """Trapezoid spike on a flat baseline: base → (ramp) → peak →
    (hold) → (decay) → base.  Returns the rate curve ``t_us -> rps``."""
    def rate(t: float) -> float:
        if t < t_start_us:
            return base_rps
        dt = t - t_start_us
        if dt < ramp_us:
            return base_rps + (peak_rps - base_rps) * (dt / ramp_us)
        dt -= ramp_us
        if dt < hold_us:
            return peak_rps
        dt -= hold_us
        if dt < decay_us:
            return peak_rps + (base_rps - peak_rps) * (dt / decay_us)
        return base_rps
    return rate


def flash_crowd_times(rng: np.random.Generator, base_rps: float,
                      peak_rps: float, t_start_us: float, ramp_us: float,
                      hold_us: float, decay_us: float,
                      duration_us: float) -> np.ndarray:
    """Flash-crowd arrivals: a trapezoid spike over a flat baseline."""
    rate = flash_crowd_rate(base_rps, peak_rps, t_start_us, ramp_us,
                            hold_us, decay_us)
    return thinned_times(rng, rate, max(base_rps, peak_rps), duration_us)


def diurnal_times(rng: np.random.Generator, mean_rps: float,
                  amplitude: float, period_us: float, duration_us: float,
                  phase: float = 0.0) -> np.ndarray:
    """Diurnal load curve: sinusoidal rate around ``mean_rps`` with
    relative ``amplitude`` in [0, 1) and the given period (a compressed
    "day").  Peak-to-trough ratio is (1+a)/(1-a)."""
    if not 0.0 <= amplitude < 1.0:
        raise ValueError(f"amplitude must be in [0, 1): {amplitude}")
    two_pi = 2.0 * np.pi

    def rate(t: float) -> float:
        return mean_rps * (1.0 + amplitude *
                           np.sin(two_pi * t / period_us + phase))

    return thinned_times(rng, rate, mean_rps * (1.0 + amplitude),
                         duration_us)
