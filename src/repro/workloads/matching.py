"""Matching-engine trading day: open/close auction spikes.

Produces a ``(t_us, payload)`` trace of :func:`repro.apps.matching
.order_req` orders shaped like an exchange session: an opening-auction
spike, a midday baseline, and a closing-auction spike — the classic
U-shaped intraday volume curve, compressed into a simulated window.
Order flow is seeded: sides alternate by Bernoulli draw, limit prices
random-walk around a drifting mid, quantities are geometric.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.apps.matching import order_req
from repro.workloads.arrivals import thinned_times


def auction_day_rate(base_rps: float, open_peak_rps: float,
                     close_peak_rps: float, duration_us: float,
                     auction_frac: float = 0.12):
    """U-shaped intraday rate: exponential decay from the open spike,
    exponential climb into the close spike, flat baseline between."""
    span = auction_frac * duration_us

    def rate(t: float) -> float:
        r = base_rps
        if span > 0:
            r += (open_peak_rps - base_rps) * np.exp(-3.0 * t / span)
            r += (close_peak_rps - base_rps) * np.exp(
                -3.0 * (duration_us - t) / span)
        return r
    return rate


def auction_day_trace(seed: int, duration_us: float, base_rps: float,
                      open_peak_rps: float, close_peak_rps: float,
                      mid_price: int = 10_000, tick: int = 5,
                      auction_frac: float = 0.12,
                      ) -> List[Tuple[float, bytes]]:
    """Seeded order-flow trace for ``MatchingEngineApp``.

    Draw order: arrival times (thinning), then per-order (side, price
    offset, quantity) vectors.  The mid price random-walks one tick per
    order; buys quote below / sells above the mid by a geometric number
    of ticks, so the book stays crossed often enough to generate fills.
    """
    rng = np.random.default_rng(seed)
    rate = auction_day_rate(base_rps, open_peak_rps, close_peak_rps,
                            duration_us, auction_frac)
    peak = base_rps + max(open_peak_rps, close_peak_rps)
    times = thinned_times(rng, rate, peak, duration_us)
    n = len(times)
    buys = rng.random(n) < 0.5
    drift = np.cumsum(rng.integers(-1, 2, size=n)) * tick
    depth = rng.geometric(0.45, size=n) * tick       # ticks off the mid
    qty = rng.geometric(0.2, size=n)
    cross = rng.random(n) < 0.35                     # aggressive orders
    trace: List[Tuple[float, bytes]] = []
    for i, t in enumerate(times):
        mid = mid_price + int(drift[i])
        off = int(depth[i])
        if buys[i]:
            price = mid + off if cross[i] else mid - off
            side = "buy"
        else:
            price = mid - off if cross[i] else mid + off
            side = "sell"
        trace.append((float(t), order_req(side, i + 1, max(tick, price),
                                          int(qty[i]))))
    return trace
