"""MinBFT [Veronese et al., IEEE TC'13] baseline — 2f+1 BFT SMR using an SGX
trusted counter (USIG), as deployed in the paper's comparison (§7.2).

Protocol structure (failure-free path):
  1. client sends a request to all replicas — *vanilla*: signed with
     public-key crypto; *hmac* variant: authenticated through the client's
     enclave (the paper's modified configuration);
  2. the leader assigns the next counter value inside its enclave (createUI)
     and multicasts PREPARE;
  3. each follower verifies the client's credential and the leader's UI
     (enclave access), creates its own UI, and multicasts COMMIT;
  4. replicas execute after f+1 matching COMMITs and reply; the client
     accepts f+1 matching replies.

Cost model: enclave access 8 µs (paper: 7–12.5 µs), sign 15 µs / verify
30 µs, plus a per-hop per-byte cost 3.5× uBFT's (MinBFT is not
RDMA-optimized; the paper ran it over a VMA kernel-bypass TCP stack — we
calibrate ``impl_overhead_us`` so the vanilla configuration lands on the
paper's measured 566 µs minimum; everything else is then predicted).
"""

from __future__ import annotations

from typing import Callable, List, Optional

from repro.core import crypto
from repro.core.consensus import App
from repro.core.node import Node
from repro.sim.events import Simulator
from repro.sim.net import NetParams, NetworkModel

#: calibration to the paper's measured floor (566 µs, §7.2) — covers the
#: VMA/TCP stack, MinBFT's event loop and marshaling, spread over the
#: protocol's five message stages.
IMPL_OVERHEAD_US = 160.0
#: per-byte cost multiplier vs the RDMA fabric (copies in the TCP-ish stack)
BYTE_FACTOR = 3.5


class MinBftReplica(Node):
    handling_cost = 0.6  # heavier event loop than the RDMA systems

    def __init__(self, sim, net, registry, pid: str, replicas: List[str],
                 app: App, f: int = 1, client_mode: str = "vanilla"):
        super().__init__(sim, net, registry, pid)
        self.replicas = replicas
        self.f = f
        self.app = app
        self.client_mode = client_mode
        self.is_leader = pid == replicas[0]
        self._commits = {}
        self._reqs = {}
        self._executed = set()
        self.handle("REQ", self._on_req)
        self.handle("PREPARE", self._on_prepare)
        self.handle("COMMIT", self._on_commit)

    # -- stage latencies -------------------------------------------------
    def _stage(self, fn, *, enclaves: int = 0, verifies: int = 0,
               signs: int = 0) -> None:
        lat = IMPL_OVERHEAD_US
        lat += enclaves * self.netp.enclave_access_us
        lat += verifies * self.netp.verify_us
        lat += signs * self.netp.sign_us
        done = self.occupy(self.netp.crypto_dispatch_us)
        self.sim.at(done + lat, lambda: None if self.crashed else fn())

    def _bsend(self, dst: str, kind: str, body, size_hint: int) -> None:
        size = crypto.wire_size_cached(body) + size_hint
        extra = int(size * (BYTE_FACTOR - 1.0))
        self.send(dst, kind, body, extra_bytes=extra)

    # -- protocol ----------------------------------------------------------
    def _on_req(self, src: str, body) -> None:
        rid, payload, cred = body
        self._reqs[rid] = (src, payload)
        if not self.is_leader:
            return
        # verify client credential + createUI in the enclave
        verifies = 1 if self.client_mode == "vanilla" else 0
        enclaves = 1 + (1 if self.client_mode == "hmac" else 0)

        def go() -> None:
            for r in self.replicas:
                if r != self.pid:
                    self._bsend(r, "PREPARE", (rid, payload, "UI"), 64)
            self._on_prepare(self.pid, (rid, payload, "UI"), local=True)

        self._stage(go, enclaves=enclaves, verifies=verifies)

    def _on_prepare(self, src: str, body, local: bool = False) -> None:
        rid, payload, ui = body
        verifies = 0 if local else (1 if self.client_mode == "vanilla" else 0)
        enclaves = 0 if local else 2  # verifyUI + own createUI

        def go() -> None:
            for r in self.replicas:
                if r != self.pid:
                    self._bsend(r, "COMMIT", (rid, payload, self.pid, "UI"), 64)
            self._note_commit(rid, payload, self.pid)

        self._stage(go, enclaves=enclaves, verifies=verifies)

    def _on_commit(self, src: str, body) -> None:
        rid, payload, who, ui = body

        def go() -> None:
            self._note_commit(rid, payload, who)

        self._stage(go, enclaves=1)  # verifyUI

    def _note_commit(self, rid, payload, who) -> None:
        s = self._commits.setdefault(rid, set())
        s.add(who)
        if len(s) >= self.f + 1 and rid not in self._executed:
            self._executed.add(rid)
            result = self.app.apply(payload)
            client = rid[0]
            self._bsend(client, "REP", (rid, result), 32)


class MinBftClient(Node):
    def __init__(self, sim, net, registry, pid: str, replicas: List[str],
                 f: int = 1, client_mode: str = "vanilla"):
        super().__init__(sim, net, registry, pid)
        self.replicas = replicas
        self.f = f
        self.client_mode = client_mode
        self._next = 0
        self._pending = {}
        self.latencies: List[float] = []
        self.handle("REP", self._on_rep)

    def request(self, payload: bytes, cb=None):
        rid = (self.pid, self._next)
        self._next += 1
        self._pending[rid] = {"t0": self.sim.now, "cb": cb, "replies": {},
                              "done": False}
        cost = (self.netp.sign_us if self.client_mode == "vanilla"
                else self.netp.enclave_access_us)
        done = self.occupy(cost + self.netp.crypto_dispatch_us)

        def fire() -> None:
            for r in self.replicas:
                body = (rid, payload, "CRED")
                size = crypto.wire_size_cached(body) + 64
                extra = int(size * (BYTE_FACTOR - 1.0))
                self.send(r, "REQ", body, extra_bytes=extra)

        self.sim.at(done, fire)
        return rid

    def _on_rep(self, src, body) -> None:
        rid, result = body
        st = self._pending.get(rid)
        if st is None or st["done"]:
            return
        st["replies"].setdefault(crypto.encode(result), set()).add(src)
        for enc, who in st["replies"].items():
            if len(who) >= self.f + 1:
                st["done"] = True
                lat = self.sim.now - st["t0"]
                self.latencies.append(lat)
                if st["cb"]:
                    st["cb"](result, lat)
                del self._pending[rid]
                return


def build_minbft(app_factory: Callable[[], App], f: int = 1,
                 client_mode: str = "vanilla",
                 params: Optional[NetParams] = None, seed: int = 0):
    sim = Simulator(seed=seed)
    net = NetworkModel(sim, params)
    registry = crypto.KeyRegistry()
    replicas = [f"r{i}" for i in range(2 * f + 1)]
    for r in replicas:
        MinBftReplica(sim, net, registry, r, replicas, app_factory(), f,
                      client_mode)
    client = MinBftClient(sim, net, registry, "c0", replicas, f, client_mode)
    return sim, client
