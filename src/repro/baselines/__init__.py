"""Every system the paper compares against (§7.2/§7.4), on the same
simulator substrate and latency model as uBFT:

  unreplicated — plain RPC to a single server (Fig 8 "Unrepl.")
  mu           — Mu [OSDI'20]: crash-tolerant SMR, leader RDMA-writes to
                 followers' memory without receiver CPU involvement
  minbft       — MinBFT [TC'13]: 2f+1 BFT SMR with an SGX trusted counter
                 (vanilla: clients use public-key crypto; hmac variant:
                 clients use enclave HMACs)
  sgx_counter  — SGX trusted-counter non-equivocation mechanism (Fig 10)
"""

from repro.baselines.unreplicated import UnreplicatedServer, UnreplicatedClient, build_unreplicated
from repro.baselines.mu import build_mu
from repro.baselines.minbft import build_minbft

__all__ = ["UnreplicatedServer", "UnreplicatedClient", "build_unreplicated",
           "build_mu", "build_minbft"]
