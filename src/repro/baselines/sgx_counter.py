"""SGX trusted-counter non-equivocation baseline (Fig 10, §7.4) and the
standalone CTBcast harness it is compared against.

The SGX mechanism: before sending, the sender's enclave binds the message to
a monotonic counter (HMAC_secret(msg‖counter‖pid)); each receiver verifies
the HMAC inside its own enclave.  Latency = enclave access at the sender +
broadcast + enclave access at each receiver (enclave access ≈ 8 µs,
paper: 7–12.5 µs).
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional

from repro.core import crypto
from repro.core.ctbcast import CTBcast
from repro.core.node import Node
from repro.core.registers import MemoryNode, RegisterClient
from repro.core.tbcast import TBcastService
from repro.sim.events import Simulator
from repro.sim.net import NetParams, NetworkModel


# ---------------------------------------------------------------------------
# SGX trusted-counter broadcast
# ---------------------------------------------------------------------------
class SgxSender(Node):
    def __init__(self, sim, net, registry, pid: str, receivers: List[str]):
        super().__init__(sim, net, registry, pid)
        self.receivers = receivers
        self.counter = 0

    def broadcast(self, payload: bytes) -> None:
        self.counter += 1
        ctr = self.counter
        # enclave access: createUI(msg, counter)
        done = self.occupy(self.netp.enclave_access_us +
                           self.netp.hmac_us * (1 + len(payload) / 64))

        def fire() -> None:
            for r in self.receivers:
                self.send(r, "SGX_MSG", (ctr, payload, "UI"))

        self.sim.at(done, fire)


class SgxReceiver(Node):
    def __init__(self, sim, net, registry, pid: str,
                 on_deliver: Callable[[str, int, bytes], None]):
        super().__init__(sim, net, registry, pid)
        self.on_deliver = on_deliver
        self.handle("SGX_MSG", self._on_msg)

    def _on_msg(self, src: str, body) -> None:
        ctr, payload, ui = body
        # enclave access: verifyUI
        done = self.occupy(self.netp.enclave_access_us +
                           self.netp.hmac_us * (1 + len(payload) / 64))
        self.sim.at(done, lambda: self.on_deliver(src, ctr, payload))


def build_sgx_broadcast(n_receivers: int = 2,
                        params: Optional[NetParams] = None, seed: int = 0):
    sim = Simulator(seed=seed)
    net = NetworkModel(sim, params)
    registry = crypto.KeyRegistry()
    delivered: Dict[int, List[float]] = {}

    def on_deliver(receiver_pid):
        def cb(src, ctr, payload):
            delivered.setdefault(ctr, []).append(sim.now)
        return cb

    receivers = []
    for i in range(n_receivers):
        pid = f"q{i}"
        r = SgxReceiver(sim, net, registry, pid, None)
        r.on_deliver = on_deliver(pid)
        receivers.append(pid)
    sender = SgxSender(sim, net, registry, "p0", receivers)
    return sim, sender, delivered


# ---------------------------------------------------------------------------
# Standalone CTBcast deployment (one broadcaster, n receivers, memory nodes)
# ---------------------------------------------------------------------------
class CtbNode(Node):
    """A process participating in a single CTBcast instance."""

    def __init__(self, sim, net, registry, pid: str, group: List[str],
                 mem_nodes: List[str], t: int, broadcaster: str,
                 deliveries: Dict, fast: bool = True, f_m: int = 1,
                 auto_slow_after_us: Optional[float] = None):
        super().__init__(sim, net, registry, pid)
        self.tb = TBcastService(self, t=t, max_msg_bytes=16384)
        self.regs = RegisterClient(self, mem_nodes, f_m)
        self.deliveries = deliveries

        def deliver(k, m):
            self.deliveries.setdefault(k, {})[pid] = sim.now

        self.ctb = CTBcast(self, self.tb, self.regs, broadcaster, group, t,
                           deliver, fast_enabled=fast,
                           auto_slow_after_us=auto_slow_after_us,
                           on_summary_needed=self._summary
                           if pid == broadcaster else None)
        self._pending_summaries: List[int] = []

    def _summary(self, seg: int) -> None:
        # standalone summary provider: self-certification after one network
        # round + f+1 signatures (matches the consensus-level machinery's
        # cost without pulling in consensus state)
        cost = self.netp.sign_us + 2 * self.netp.base_us
        self.timer(cost, lambda: self.ctb.summary_certified(seg))


def build_ctbcast(n: int = 3, t: int = 128, fast: bool = True, f_m: int = 1,
                  params: Optional[NetParams] = None, seed: int = 0,
                  auto_slow_after_us: Optional[float] = None):
    """One CTBcast instance: p0 broadcasts, everyone delivers."""
    sim = Simulator(seed=seed)
    net = NetworkModel(sim, params)
    registry = crypto.KeyRegistry()
    group = [f"p{i}" for i in range(n)]
    mems = [f"m{i}" for i in range(2 * f_m + 1)]
    for m in mems:
        MemoryNode(sim, net, registry, m)
    deliveries: Dict[int, Dict[str, float]] = {}
    nodes = [CtbNode(sim, net, registry, pid, group, mems, t, "p0",
                     deliveries, fast=fast, f_m=f_m,
                     auto_slow_after_us=auto_slow_after_us)
             for pid in group]
    return sim, nodes, deliveries
