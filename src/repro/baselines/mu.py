"""Mu [Aguilera et al., OSDI'20] baseline — crash-only SMR, the fastest
SMR system the paper compares against (§7.1/§7.2).

Model (faithful to Mu's failure-free critical path): the client sends its
request to the leader; the leader RDMA-writes the log entry into a majority
of followers' memory.  Followers' CPUs are *not* on the critical path — the
write completes when the NIC acknowledges it (one network RTT), after which
the leader executes and replies.  Followers lazily apply entries in the
background (modeled, but off the critical path).
"""

from __future__ import annotations

from typing import Callable, List, Optional

from repro.core import crypto
from repro.core.consensus import App
from repro.core.node import Node
from repro.sim.events import Simulator
from repro.sim.net import NetParams, NetworkModel

#: Mu's replication writes complete at NIC level — no receiver dispatch, no
#: host copies: cheaper base and per-byte than the RPC path (calibrated to
#: the paper's +64% small / +26% 8 KiB overhead over unreplicated).
MU_WRITE_BASE_US = 0.6
MU_WRITE_PER_BYTE_US = 0.00035


class MuLeader(Node):
    def __init__(self, sim, net, registry, pid: str, followers: List[str],
                 app: App):
        super().__init__(sim, net, registry, pid)
        self.followers = followers
        self.majority = (len(followers) + 1) // 2 + 1  # incl. self
        self.app = app
        self._pending = {}
        self.handle("REQ", self._on_req)

    def _on_req(self, src: str, body) -> None:
        rid, payload = body
        size = crypto.wire_size_cached(body) + 32
        st = {"acks": 1, "done": False}  # self counts
        self._pending[rid] = st

        def nic_ack(rid=rid, src=src, payload=payload) -> None:
            st = self._pending.get(rid)
            if st is None or st["done"]:
                return
            st["acks"] += 1
            if st["acks"] >= self.majority:
                st["done"] = True
                del self._pending[rid]
                result = self.app.apply(payload)
                self.send(src, "REP", (rid, result))

        for fo in self.followers:
            # RDMA write + NIC-level completion: one RTT, no follower CPU,
            # no host copies (see MU_WRITE_* calibration above)
            # draw through the network model's pre-drawn block so the
            # seeded stream is consumed in the same order as scalar draws
            jit = self.net.jitter()
            rtt = 2 * MU_WRITE_BASE_US * jit + size * MU_WRITE_PER_BYTE_US
            self.sim.after(rtt, nic_ack)
            # background apply at the follower (off critical path)
            self.net.send(self.pid, fo, ("MU_APPLY", (rid, payload)), size)


class MuFollower(Node):
    def __init__(self, sim, net, registry, pid: str, app: App):
        super().__init__(sim, net, registry, pid)
        self.app = app
        self.handle("MU_APPLY", self._on_apply)

    def _on_apply(self, src: str, body) -> None:
        _rid, payload = body
        self.app.apply(payload)


def build_mu(app_factory: Callable[[], App], n_followers: int = 2,
             params: Optional[NetParams] = None, seed: int = 0):
    from repro.baselines.unreplicated import UnreplicatedClient
    sim = Simulator(seed=seed)
    net = NetworkModel(sim, params)
    registry = crypto.KeyRegistry()
    followers = [f"f{i}" for i in range(n_followers)]
    for f in followers:
        MuFollower(sim, net, registry, f, app_factory())
    MuLeader(sim, net, registry, "l0", followers, app_factory())
    client = UnreplicatedClient(sim, net, registry, "c0", "l0")
    return sim, client
