"""Unreplicated RPC baseline (Fig 8 "Unrepl.").

Client sends the request to one server over the same point-to-point
primitive; the server executes and replies.  This is the latency floor that
calibrates the network model (2.2 µs at 32 B → 20 µs at 8 KiB).
"""

from __future__ import annotations

from typing import Callable, List, Optional, Tuple

from repro.core import crypto
from repro.core.consensus import App
from repro.core.node import Node
from repro.sim.events import Simulator
from repro.sim.net import NetParams, NetworkModel


class UnreplicatedServer(Node):
    """Single server, same network primitive.  Apps with a non-zero
    ``App.cost_us`` get the same serial service model as the replicated
    deferred execution engine — one decode engine, FIFO — so replicated
    vs unreplicated comparisons isolate the consensus overhead instead
    of handing the baseline an infinitely parallel app."""

    def __init__(self, sim, net, registry, pid: str, app: App):
        super().__init__(sim, net, registry, pid)
        self.app = app
        self._app_has_cost = type(app).cost_us is not App.cost_us
        self._busy_until = 0.0
        self.handle("REQ", self._on_req)

    def _on_req(self, src: str, body) -> None:
        rid, payload = body
        if self._app_has_cost:
            cost = self.app.cost_us(payload)
            if cost > 0.0:
                start = max(self.sim.now, self._busy_until)
                self._busy_until = start + cost

                def _finish() -> None:
                    result = self.app.apply(payload)
                    self.send(src, "REP", (rid, result))

                self.sim.at(self._busy_until, _finish,
                            note="unrepl.service")
                return
        result = self.app.apply(payload)
        self.send(src, "REP", (rid, result))


class UnreplicatedClient(Node):
    def __init__(self, sim, net, registry, pid: str, server: str):
        super().__init__(sim, net, registry, pid)
        self.server = server
        self._next = 0
        self._cbs = {}
        self.latencies: List[float] = []
        self.handle("REP", self._on_rep)

    def request(self, payload: bytes, cb=None):
        rid = (self.pid, self._next)
        self._next += 1
        self._cbs[rid] = (self.sim.now, cb)
        self.send(self.server, "REQ", (rid, payload))
        return rid

    def _on_rep(self, src, body) -> None:
        rid, result = body
        ent = self._cbs.pop(rid, None)
        if ent is None:
            return
        t0, cb = ent
        lat = self.sim.now - t0
        self.latencies.append(lat)
        if cb:
            cb(result, lat)


def build_unreplicated(app_factory: Callable[[], App],
                       params: Optional[NetParams] = None, seed: int = 0):
    sim = Simulator(seed=seed)
    net = NetworkModel(sim, params)
    registry = crypto.KeyRegistry()
    server = UnreplicatedServer(sim, net, registry, "s0", app_factory())
    client = UnreplicatedClient(sim, net, registry, "c0", "s0")
    return sim, server, client


def run_closed_loop(sim: Simulator, client, payload: bytes, n: int,
                    timeout: float = 10_000_000.0) -> List[float]:
    """Issue ``n`` requests back-to-back (closed loop); return latencies."""
    state = {"left": n}

    def fire(*_args) -> None:
        state["left"] -= 1
        if state["left"] > 0:
            client.request(payload, fire)

    client.request(payload, fire)
    sim.run_until(lambda: state["left"] <= 0, timeout=timeout)
    return list(client.latencies)
