"""Sharded AdamW with mixed-precision state and optional gradient
compression.

At the assigned scales (235B params on 256 chips) optimizer memory is the
binding constraint, so the defaults are: bf16 first/second moments + fp32
master weights, all sharded with the same PartitionSpecs as the parameters
(the FSDP 'data' axis carries most of it).

``compress="int8"`` quantizes gradients to int8 blockwise before they cross
the network (the all-reduce happens on the int8 representation under GSPMD
when the quantize/dequantize brackets the psum boundary) — a standard
distributed-optimization trick for pod-interconnect-bound training; exposed
as a flag and validated in tests for accuracy impact.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "bfloat16"
    master_dtype: str = "float32"
    grad_clip: float = 1.0
    compress: Optional[str] = None   # None | "int8"


def adamw_init(params: Any, cfg: AdamWConfig) -> Dict[str, Any]:
    mdt = jnp.dtype(cfg.moment_dtype)
    return {
        "mu": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=mdt), params),
        "nu": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=mdt), params),
        "master": jax.tree.map(
            lambda p: p.astype(jnp.dtype(cfg.master_dtype)), params),
        "count": jnp.zeros((), jnp.int32),
    }


def _compress_int8(g: jax.Array) -> jax.Array:
    """Blockwise int8 quantize→dequantize (simulates int8 all-reduce)."""
    if g.ndim == 0 or g.size < 256:
        return g
    scale = jnp.max(jnp.abs(g), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(g.dtype) * scale


def adamw_update(params: Any, grads: Any, state: Dict[str, Any],
                 cfg: AdamWConfig) -> Tuple[Any, Dict[str, Any]]:
    count = state["count"] + 1
    if cfg.compress == "int8":
        grads = jax.tree.map(_compress_int8, grads)
    # global-norm clip (fp32)
    if cfg.grad_clip > 0:
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, mu, nu, master):
        g32 = g.astype(jnp.float32)
        mu32 = mu.astype(jnp.float32) * b1 + g32 * (1 - b1)
        nu32 = nu.astype(jnp.float32) * b2 + g32 * g32 * (1 - b2)
        step = (mu32 / c1) / (jnp.sqrt(nu32 / c2) + cfg.eps)
        m32 = master.astype(jnp.float32)
        m32 = m32 - cfg.lr * (step + cfg.weight_decay * m32)
        return (m32.astype(p.dtype), mu32.astype(mdt), nu32.astype(mdt),
                m32.astype(master.dtype))

    # flatten explicitly: the param tree itself contains tuples/dicts, so a
    # tree.map returning containers would be mis-traversed
    leaves_p, treedef = jax.tree.flatten(params)
    leaves = [upd(p, g, mu, nu, ma) for p, g, mu, nu, ma in zip(
        leaves_p, jax.tree.leaves(grads), jax.tree.leaves(state["mu"]),
        jax.tree.leaves(state["nu"]), jax.tree.leaves(state["master"]))]
    new_params = jax.tree.unflatten(treedef, [t[0] for t in leaves])
    new_state = {
        "mu": jax.tree.unflatten(treedef, [t[1] for t in leaves]),
        "nu": jax.tree.unflatten(treedef, [t[2] for t in leaves]),
        "master": jax.tree.unflatten(treedef, [t[3] for t in leaves]),
        "count": count,
    }
    return new_params, new_state


def opt_pspecs(param_specs: Any) -> Dict[str, Any]:
    """Optimizer-state PartitionSpecs mirroring the parameter specs."""
    from jax.sharding import PartitionSpec as P
    return {
        "mu": param_specs,
        "nu": param_specs,
        "master": param_specs,
        "count": P(),
    }
