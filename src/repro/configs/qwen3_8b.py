"""Qwen3-8B — dense, GQA kv=8, qk-norm.
[hf:Qwen/Qwen3-8B; hf]

Exact assigned configuration (see DESIGN.md §6); ``smoke_config`` is the
reduced same-family config used by the CPU smoke tests.
"""

from repro.models.common import LayerSpec, MoEConfig, ModelConfig, default_blocks


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b", family="dense",
        n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=12288, vocab=151936,
        blocks=default_blocks(36),
        qk_norm=True, rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, blocks=default_blocks(2),
        qk_norm=True, remat="none",
    )
