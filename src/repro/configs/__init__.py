from repro.configs.registry import (ARCHS, LONG_CONTEXT_OK,
                                    get_config, get_smoke_config,
                                    list_archs)

__all__ = ["ARCHS", "LONG_CONTEXT_OK", "get_config",
           "get_smoke_config", "list_archs"]
