"""Qwen3-MoE 235B (22B active) — 128 experts, top-8, GQA kv=4, qk-norm.
[hf:Qwen/Qwen3-30B-A3B; hf]

Exact assigned configuration (see DESIGN.md §6); ``smoke_config`` is the
reduced same-family config used by the CPU smoke tests.
"""

from repro.models.common import LayerSpec, MoEConfig, ModelConfig, default_blocks


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe",
        n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
        d_ff=1536, vocab=151936,
        blocks=default_blocks(94),
        moe=MoEConfig(n_experts=128, top_k=8, d_expert=1536,
                      capacity_factor=1.25),
        qk_norm=True, rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=48, vocab=256,
        blocks=default_blocks(2),
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=48),
        qk_norm=True, remat="none",
    )
