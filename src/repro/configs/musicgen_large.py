"""MusicGen-Large — decoder-only over EnCodec tokens (audio frontend stub).
[arXiv:2306.05284; hf]

Exact assigned configuration (see DESIGN.md §6); ``smoke_config`` is the
reduced same-family config used by the CPU smoke tests.
"""

from repro.models.common import LayerSpec, MoEConfig, ModelConfig, default_blocks


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", family="audio",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
        d_ff=8192, vocab=2048,
        blocks=default_blocks(48),
        frontend="audio",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, blocks=default_blocks(2),
        frontend="audio", remat="none",
    )
