"""Gemma 3 4B — dense, 5:1 local(sliding-1024):global attention, 128k.
[hf:google/gemma-3-1b-pt; unverified]

Exact assigned configuration (see DESIGN.md §6); ``smoke_config`` is the
reduced same-family config used by the CPU smoke tests.
"""

from repro.models.common import LayerSpec, MoEConfig, ModelConfig, default_blocks


_L = LayerSpec("attn", window=1024)
_G = LayerSpec("attn")


def config() -> ModelConfig:
    # 34 layers = 5 x (5 local + 1 global) + 4 local
    return ModelConfig(
        name="gemma3-4b", family="dense",
        n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
        d_ff=10240, vocab=262144,
        blocks=(((_L, _L, _L, _L, _L, _G), 5), ((_L,), 4)),
        rope_theta=1_000_000.0, max_seq=131_072,
    )


def smoke_config() -> ModelConfig:
    sL = LayerSpec("attn", window=16)
    sG = LayerSpec("attn")
    return ModelConfig(
        name="gemma3-4b-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256,
        blocks=(((sL, sL, sG), 1),), remat="none",
    )
