"""Architecture registry: ``--arch <id>`` resolution."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.common import ModelConfig

ARCHS: Dict[str, str] = {
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "musicgen-large": "repro.configs.musicgen_large",
    "chameleon-34b": "repro.configs.chameleon_34b",
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
}

#: archs with a sub-quadratic (or state-based) path for long_500k decode
LONG_CONTEXT_OK = {"gemma3-4b", "gemma3-1b", "xlstm-1.3b", "recurrentgemma-2b"}


def list_archs() -> List[str]:
    return sorted(ARCHS)


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(ARCHS[arch])
    cfg = mod.config()
    cfg.validate()
    return cfg


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(ARCHS[arch])
    cfg = mod.smoke_config()
    cfg.validate()
    return cfg
