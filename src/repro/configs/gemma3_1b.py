"""Gemma 3 1B — dense, 5:1 local(sliding-512):global attention.
[hf:google/gemma-3-1b-pt; unverified]

Exact assigned configuration (see DESIGN.md §6); ``smoke_config`` is the
reduced same-family config used by the CPU smoke tests.
"""

from repro.models.common import LayerSpec, MoEConfig, ModelConfig, default_blocks


_L = LayerSpec("attn", window=512)
_G = LayerSpec("attn")


def config() -> ModelConfig:
    # 26 layers = 4 x (5 local + 1 global) + 2 local
    return ModelConfig(
        name="gemma3-1b", family="dense",
        n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
        d_ff=6912, vocab=262144,
        blocks=(((_L, _L, _L, _L, _L, _G), 4), ((_L,), 2)),
        rope_theta=1_000_000.0, max_seq=131_072,
    )


def smoke_config() -> ModelConfig:
    sL = LayerSpec("attn", window=16)
    sG = LayerSpec("attn")
    return ModelConfig(
        name="gemma3-1b-smoke", family="dense",
        n_layers=3, d_model=48, n_heads=2, n_kv_heads=1, head_dim=24,
        d_ff=96, vocab=256,
        blocks=(((sL, sL, sG), 1),), remat="none",
    )
