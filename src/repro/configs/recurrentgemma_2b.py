"""RecurrentGemma 2B — RG-LRU : local attention at 2:1 (Griffin).
[arXiv:2402.19427; hf]

Exact assigned configuration (see DESIGN.md §6); ``smoke_config`` is the
reduced same-family config used by the CPU smoke tests.
"""

from repro.models.common import LayerSpec, MoEConfig, ModelConfig, default_blocks


_R = LayerSpec("rglru")
_A = LayerSpec("attn", window=2048)


def config() -> ModelConfig:
    # 26 layers = 8 x (rglru, rglru, local-attn) + 2 rglru
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
        d_ff=7680, vocab=256000,
        blocks=(((_R, _R, _A), 8), ((_R, _R), 1)),
        max_seq=1_048_576,
    )


def smoke_config() -> ModelConfig:
    sR = LayerSpec("rglru")
    sA = LayerSpec("attn", window=16)
    return ModelConfig(
        name="recurrentgemma-smoke", family="hybrid",
        n_layers=3, d_model=64, n_heads=2, n_kv_heads=1, head_dim=32,
        d_ff=96, vocab=256,
        blocks=(((sR, sR, sA), 1),), remat="none",
    )
