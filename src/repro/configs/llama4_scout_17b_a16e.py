"""Llama 4 Scout 17B-active / 16 experts — MoE, early fusion VLM.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Exact assigned configuration (see DESIGN.md §6); ``smoke_config`` is the
reduced same-family config used by the CPU smoke tests.
"""

from repro.models.common import LayerSpec, MoEConfig, ModelConfig, default_blocks


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab=202048,
        blocks=default_blocks(48),
        moe=MoEConfig(n_experts=16, top_k=1, d_expert=8192,
                      capacity_factor=2.0),
        rope_theta=500_000.0, frontend="vlm",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab=256,
        blocks=default_blocks(2),
        moe=MoEConfig(n_experts=4, top_k=1, d_expert=96, capacity_factor=2.0),
        frontend="vlm", remat="none",
    )
