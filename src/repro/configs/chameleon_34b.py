"""Chameleon-34B — early-fusion VLM over VQ image tokens (frontend stub).
[arXiv:2405.09818; unverified]

Exact assigned configuration (see DESIGN.md §6); ``smoke_config`` is the
reduced same-family config used by the CPU smoke tests.
"""

from repro.models.common import LayerSpec, MoEConfig, ModelConfig, default_blocks


def config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b", family="vlm",
        n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=22016, vocab=65536,
        blocks=default_blocks(48),
        qk_norm=True,     # Chameleon uses qk-norm for training stability
        frontend="vlm",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=160, vocab=256, blocks=default_blocks(2),
        qk_norm=True, frontend="vlm", remat="none",
    )
