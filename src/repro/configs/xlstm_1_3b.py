"""xLSTM 1.3B — sLSTM + mLSTM blocks (attention-free SSM-class).
[arXiv:2405.04517; unverified]

Exact assigned configuration (see DESIGN.md §6); ``smoke_config`` is the
reduced same-family config used by the CPU smoke tests.
"""

from repro.models.common import LayerSpec, MoEConfig, ModelConfig, default_blocks


_M = LayerSpec("mlstm", has_ffn=False)
_S = LayerSpec("slstm", has_ffn=False)


def config() -> ModelConfig:
    # 48 blocks, mLSTM:sLSTM = 3:1 (paper's 1.3B mixes both)
    return ModelConfig(
        name="xlstm-1.3b", family="ssm",
        n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, head_dim=512,
        d_ff=0, vocab=50304,
        blocks=(((_M, _M, _M, _S), 12),),
        max_seq=1_048_576,
    )


def smoke_config() -> ModelConfig:
    sM = LayerSpec("mlstm", has_ffn=False)
    sS = LayerSpec("slstm", has_ffn=False)
    return ModelConfig(
        name="xlstm-smoke", family="ssm",
        n_layers=4, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=0, vocab=256,
        blocks=(((sM, sM, sM, sS), 1),), remat="none",
    )
