"""ChatGLM3-6B — dense, GQA kv=2, 2d (half-dim) RoPE.
[arXiv:2406.12793; hf]

Exact assigned configuration (see DESIGN.md §6); ``smoke_config`` is the
reduced same-family config used by the CPU smoke tests.
"""

from repro.models.common import LayerSpec, MoEConfig, ModelConfig, default_blocks


def config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b", family="dense",
        n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
        d_ff=13696, vocab=65024,
        blocks=default_blocks(28),
        rope_fraction=0.5,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, blocks=default_blocks(2),
        rope_fraction=0.5, remat="none",
    )
