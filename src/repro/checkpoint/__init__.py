from repro.checkpoint.ckpt import (load_checkpoint, reshard, save_checkpoint)

__all__ = ["save_checkpoint", "load_checkpoint", "reshard"]
