"""Checkpointing: fingerprint-attested pytree save/restore + resharding.

Checkpoint ids are *agreed through uBFT consensus* before being written
(repro.runtime.trainer): a checkpoint is only trusted if f+1 replicas attest
to the same state fingerprint — the distributed analog of the paper's f+1
signed application checkpoints (§5.1).  The fingerprint is stored alongside
the data and re-verified on load, catching silent corruption on disk.

``reshard`` re-lays-out a checkpoint onto a different mesh (elastic scaling:
a job restarted at a different pod count keeps training).
"""

from __future__ import annotations

import json
import os
import pickle
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.runtime.attest import fingerprint_tree


def _tree_fp(tree: Any) -> int:
    return int(fingerprint_tree(jax.tree.map(lambda x: jax.numpy.asarray(x),
                                             tree)))


def save_checkpoint(path: str, step: int, params: Any, opt_state: Any = None,
                    meta: Optional[Dict] = None) -> int:
    """Writes the checkpoint and returns its fingerprint."""
    os.makedirs(path, exist_ok=True)
    state = {"step": step,
             "params": jax.tree.map(np.asarray, params),
             "opt_state": jax.tree.map(np.asarray, opt_state)
             if opt_state is not None else None}
    fp = _tree_fp(state["params"])
    blob = pickle.dumps(state, protocol=4)
    tmp = os.path.join(path, f"ckpt_{step}.tmp")
    final = os.path.join(path, f"ckpt_{step}.pkl")
    with open(tmp, "wb") as f:
        f.write(blob)
    os.replace(tmp, final)     # atomic publish
    manifest = {"step": step, "fingerprint": fp, "meta": meta or {}}
    with open(os.path.join(path, f"ckpt_{step}.json"), "w") as f:
        json.dump(manifest, f)
    return fp


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(f[5:-5]) for f in os.listdir(path)
             if f.startswith("ckpt_") and f.endswith(".json")]
    return max(steps) if steps else None


def load_checkpoint(path: str, step: Optional[int] = None,
                    expect_fp: Optional[int] = None) -> Tuple[int, Any, Any]:
    """Returns (step, params, opt_state); verifies the stored fingerprint."""
    step = step if step is not None else latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {path}")
    with open(os.path.join(path, f"ckpt_{step}.pkl"), "rb") as f:
        state = pickle.load(f)
    manifest = json.load(open(os.path.join(path, f"ckpt_{step}.json")))
    fp = _tree_fp(state["params"])
    if fp != manifest["fingerprint"]:
        raise ValueError(f"checkpoint {step}: fingerprint mismatch "
                         f"(corrupted): {fp} != {manifest['fingerprint']}")
    if expect_fp is not None and fp != expect_fp:
        raise ValueError(f"checkpoint {step}: fingerprint {fp} does not match "
                         f"the consensus-agreed value {expect_fp}")
    return state["step"], state["params"], state["opt_state"]


def reshard(tree: Any, mesh, pspecs: Any) -> Any:
    """Place a host pytree onto ``mesh`` with the given PartitionSpecs."""
    from jax.sharding import NamedSharding

    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(put, tree, pspecs,
                        is_leaf=lambda x: not isinstance(x, (dict, tuple, list)))
