from repro.parallel.sharding import (batch_pspecs, cache_pspecs,
                                     param_pspecs, shard_ctx_for_mesh)

__all__ = ["param_pspecs", "batch_pspecs", "cache_pspecs", "shard_ctx_for_mesh"]
