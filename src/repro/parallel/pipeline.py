"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

For models whose layer count × width exceeds what DP×TP can hold, the layer
stack is split into S stages sharded over a ``stage`` axis; microbatches
flow through the classic (n_micro + S − 1)-tick schedule, with activations
handed between stages by ``jax.lax.ppermute`` (TPU-native neighbor
exchange — no NCCL-style send/recv emulation).

This is substrate for the 1000+-node runnability requirement (DESIGN.md
§8.5); the default configs use DP×TP(×EP), and PP composes with them by
adding the axis to the mesh.  ``pipeline_apply`` is validated against
sequential execution in tests/test_pipeline.py.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def pipeline_apply(stage_fn: Callable[[Any, jax.Array], jax.Array],
                   stage_params: Any, x: jax.Array, mesh: Mesh,
                   stage_axis: str = "stage") -> jax.Array:
    """Run ``x`` through S pipeline stages.

    stage_params: pytree with leading dim S (one slice per stage), sharded
    over ``stage_axis``; x: (n_micro, mb, ...) microbatched input,
    replicated across stages.  Returns (n_micro, mb, ...) outputs.
    """
    S = mesh.shape[stage_axis]
    n_micro = x.shape[0]
    assert n_micro >= S, "need at least one microbatch per stage"
    perm = [(i, (i + 1) % S) for i in range(S)]

    def spmd(params_local, x_all):
        params_local = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(stage_axis)
        ticks = n_micro + S - 1
        buf = jnp.zeros_like(x_all[0])            # inter-stage register
        outs = jnp.zeros_like(x_all)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 feeds microbatch t (when in range); others take buf
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            inp = jnp.where(stage == 0, x_all[mb_idx], buf)
            out = stage_fn(params_local, inp)
            # last stage commits microbatch t-(S-1) (when in range)
            out_idx = jnp.clip(t - (S - 1), 0, n_micro - 1)
            commit = (stage == S - 1) & (t >= S - 1)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(commit, out, outs[out_idx]), out_idx, 0)
            buf = jax.lax.ppermute(out, stage_axis, perm)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # only the last stage holds real outputs; broadcast them
        outs = jax.lax.psum(
            jnp.where(stage == S - 1, outs, jnp.zeros_like(outs)), stage_axis)
        return outs

    fn = shard_map(
        spmd, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(stage_axis), stage_params,
                               is_leaf=lambda a: hasattr(a, "shape")),
                  P()),
        out_specs=P(),
        check_rep=False,
    )
    return fn(stage_params, x)
