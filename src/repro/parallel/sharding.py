"""Sharding rules: parameter / batch / cache PartitionSpecs per mesh.

Axes:
  pod    — data parallelism across pods (multi-pod mesh only)
  data   — data parallelism + FSDP (params' non-model dim sharded here)
  model  — tensor parallelism: heads / FFN / experts / vocab; also the
           sequence axis of decode KV caches (flash-decode style)

The rules are name-based over the parameter tree.  Stacked layer-group
params get a leading ``None`` axis.  These rules are the *baseline*
(paper-faithful DP+TP+EP+FSDP); §Perf hillclimbs deviations per cell.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig
from repro.models.transformer import ShardCtx

FSDP = "data"
TP = "model"


def shard_ctx_for_mesh(mesh: Mesh) -> ShardCtx:
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return ShardCtx(mesh=mesh, dp_axes=dp_axes, tp_axis=TP)


def _rule_for(name: str, shape: Tuple[int, ...], cfg: ModelConfig,
              stacked: bool) -> P:
    """PartitionSpec for one (unstacked-rank) parameter by name."""
    r = len(shape) - (1 if stacked else 0)
    base: Tuple = ()
    if name == "embed":
        base = (TP, FSDP)
    elif name == "lm_head":
        base = (FSDP, TP)
    elif name in ("wq", "wk", "wv", "up", "w_in", "wz", "wi", "wf",
                  "wo_gate"):
        base = (FSDP, TP) if r == 2 else (None,)
    elif name in ("wo", "down"):
        base = (TP, FSDP)
    elif name in ("w_gate", "w_up"):
        base = (TP, FSDP, None) if r == 3 else (FSDP, TP)   # moe vs dense
    elif name == "w_down":
        base = (TP, None, FSDP) if r == 3 else (TP, FSDP)
    elif name == "router":
        base = (FSDP, None)
    elif name in ("wa", "wx", "w_out"):
        base = (TP, FSDP)
    elif name == "conv":
        base = (None, TP)
    elif name == "lam":
        base = (TP,)
    else:   # ln*, norms, biases, rz, bf — replicate
        base = tuple(None for _ in range(r))
    base = tuple(base[:r]) + tuple(None for _ in range(r - len(base)))
    if stacked:
        base = (None,) + base
    return P(*base)


def _divisible(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop sharding on axes the shape does not divide evenly (robustness:
    tiny smoke configs; odd head counts)."""
    fixed = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = int(np.prod([mesh.shape[a] for a in axes]))
        fixed.append(ax if dim % n == 0 else None)
    return P(*fixed)


def weight_compute_spec(name: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Compute-time spec for a weight: the storage rule with the FSDP axis
    dropped (ZeRO-3 style per-layer gather — constraining a weight to this
    spec makes XLA all-gather the small weight over ``data`` instead of
    all-reducing the large activations)."""
    spec = _rule_for(name, shape, None, stacked=False)
    fixed = tuple(None if ax == FSDP else ax for ax in tuple(spec))
    return _divisible(P(*fixed), shape, mesh)


def param_pspecs(cfg: ModelConfig, params: Any, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching ``params``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        name = None
        for entry in reversed(path):
            if hasattr(entry, "key"):
                name = entry.key
                break
        in_groups = any(getattr(e, "key", None) == "groups" for e in path)
        spec = _rule_for(name or "", leaf.shape, cfg, stacked=in_groups)
        specs.append(_divisible(spec, leaf.shape, mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_pspecs(cfg: ModelConfig, mesh: Mesh) -> Dict[str, P]:
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return {"inputs": P(dp), "targets": P(dp)}


def cache_pspecs(cfg: ModelConfig, caches: Any, mesh: Mesh,
                 seq_shard: bool = True) -> Any:
    """Decode caches: batch over dp; KV-cache sequence axis over `model`
    (flash-decode / context-parallel decode) when divisible."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def rule(path, leaf):
        name = None
        for entry in reversed(path):
            if hasattr(entry, "key"):
                name = entry.key
                break
        shape = leaf.shape
        # stacked leading reps dim, then batch
        if name in ("k", "v"):      # (R, B, S, KV, dh)
            spec = P(None, dp, TP if seq_shard else None, None, None)
        elif name == "pos":         # (R, S)
            spec = P(None, TP if seq_shard else None)
        elif name in ("C",):        # (R, B, H, dh, dh)
            spec = P(None, dp, None, None, None)
        elif name in ("n", "c", "h", "m"):   # (R, B, H, dh) / (R, B, H)
            spec = P(*( (None, dp) + (None,) * (len(shape) - 2) ))
        elif name == "y":           # (R, B, W)
            spec = P(None, dp, TP)
        elif name == "conv":        # (R, B, 3, W)
            spec = P(None, dp, None, TP)
        else:
            spec = P(*(None,) * len(shape))
        return _divisible(spec, shape, mesh)

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
    return jax.tree_util.tree_unflatten(
        treedef, [rule(p, l) for p, l in flat])


def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
