"""RG-LRU gated linear recurrence Pallas kernel (RecurrentGemma).

y_t = a_t · y_{t-1} + x_t, elementwise over the feature lanes.  Grid:
(B planes, nT time blocks), time innermost; the carry y (1×W, f32) lives in
VMEM scratch.  Within a block the recurrence runs as a W-lane-vectorized
``fori_loop`` over the block's T_BLK steps (the feature dimension maps to
TPU lanes; the sequential loop is over sublanes — the natural layout for a
diagonal recurrence on the VPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, x_ref, y_ref, carry_ref, *, t_blk: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _reset():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = a_ref[0].astype(jnp.float32)     # (t_blk, W)
    x = x_ref[0].astype(jnp.float32)

    def step(t, y):
        y = a[t] * y + x[t]
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return y

    y0 = carry_ref[0]
    y_final = jax.lax.fori_loop(0, t_blk, step, y0)
    carry_ref[0, :] = y_final


def rglru_pallas(a: jax.Array, x: jax.Array, *, t_blk: int = 128,
                 interpret: bool = True) -> jax.Array:
    """a, x: (B, S, W) — decay and gated input; returns y: (B, S, W) f32."""
    B, S, W = a.shape
    tb = min(t_blk, S)
    assert S % tb == 0
    nt = S // tb
    kernel = functools.partial(_rglru_kernel, t_blk=tb)

    def x_map(b, i):
        return (b, i, 0)

    return pl.pallas_call(
        kernel,
        grid=(B, nt),
        in_specs=[
            pl.BlockSpec((1, tb, W), x_map),
            pl.BlockSpec((1, tb, W), x_map),
        ],
        out_specs=pl.BlockSpec((1, tb, W), x_map),
        out_shape=jax.ShapeDtypeStruct((B, S, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, W), jnp.float32)],
        interpret=interpret,
    )(a, x)
