"""Pure-jnp oracles for every kernel (same signatures as the kernels)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def swa_ref(q: jax.Array, k: jax.Array, v: jax.Array, *, window: int
            ) -> jax.Array:
    """Sliding-window causal attention. q/k/v: (P, S, dh)."""
    P, S, dh = q.shape
    s = jnp.einsum("pqd,pkd->pqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (dh ** -0.5)
    pos = jnp.arange(S)
    delta = pos[:, None] - pos[None, :]
    valid = (delta >= 0) & (delta < window)
    s = jnp.where(valid[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("pqk,pkd->pqd", p, v.astype(jnp.float32)).astype(q.dtype)


def mlstm_ref(q, k, v, it, ft) -> jax.Array:
    """Sequential (step-by-step) mLSTM — the ground truth the chunkwise
    kernel must match. q/k/v: (P, S, dh); it/ft: (P, S, 1)."""
    P, S, dh = q.shape
    q32, k32, v32 = (x.astype(jnp.float32) for x in (q, k, v))
    it32 = it[..., 0].astype(jnp.float32)
    ft32 = ft[..., 0].astype(jnp.float32)

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, i_t, f_t = xs
        lf = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(lf + m, i_t)
        fd = jnp.exp(lf + m - m_new)[:, None]
        iw = jnp.exp(i_t - m_new)[:, None]
        C = C * fd[..., None] + iw[..., None] * kt[..., :, None] * vt[..., None, :]
        n = n * fd + iw * kt
        num = jnp.einsum("pd,pde->pe", qt, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("pd,pd->p", qt, n)), 1.0)
        return (C, n, m_new), num / den[:, None]

    C0 = jnp.zeros((P, dh, dh), jnp.float32)
    n0 = jnp.zeros((P, dh), jnp.float32)
    m0 = jnp.full((P,), NEG_INF, jnp.float32)
    xs = (jnp.moveaxis(q32, 1, 0), jnp.moveaxis(k32, 1, 0),
          jnp.moveaxis(v32, 1, 0), jnp.moveaxis(it32, 1, 0),
          jnp.moveaxis(ft32, 1, 0))
    _, hs = jax.lax.scan(step, (C0, n0, m0), xs)
    return jnp.moveaxis(hs, 0, 1).astype(q.dtype)


def rglru_ref(a: jax.Array, x: jax.Array) -> jax.Array:
    """y_t = a_t · y_{t-1} + x_t via associative scan. a/x: (B, S, W)."""
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, y = jax.lax.associative_scan(
        combine, (a.astype(jnp.float32), x.astype(jnp.float32)), axis=1)
    return y


def fingerprint_ref(words: jax.Array) -> jax.Array:
    """Order-independent digest (matches repro.runtime.attest)."""
    w = words.astype(jnp.uint32)
    w = w * jnp.uint32(0x9E3779B9) ^ (w >> 16)
    return jnp.sum(w, dtype=jnp.uint32)[None]
