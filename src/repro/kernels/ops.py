"""Jitted public wrappers around the Pallas kernels.

On TPU these call the compiled kernels (interpret=False); in this CPU
container they run in interpret mode, which executes the kernel bodies in
Python for correctness validation against ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fingerprint import fingerprint_pallas
from repro.kernels.mlstm import mlstm_pallas
from repro.kernels.rglru import rglru_pallas
from repro.kernels.swa import swa_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("window",))
def sliding_window_attention(q, k, v, *, window: int):
    """GQA sliding-window attention.
    q: (B, S, H, dh); k/v: (B, S, KV, dh) -> (B, S, H, dh)."""
    B, S, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    pad = (-S) % window
    if pad:
        zq = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = jnp.pad(q, zq), jnp.pad(k, zq), jnp.pad(v, zq)
    Sp = S + pad
    qp = q.reshape(B, Sp, KV, G, dh).transpose(0, 2, 3, 1, 4)
    qp = qp.reshape(B * KV * G, Sp, dh)
    kp = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(
        B * KV * G, Sp, dh)
    vp = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(
        B * KV * G, Sp, dh)
    out = swa_pallas(qp, kp, vp, window=window, interpret=not _on_tpu())
    out = out.reshape(B, KV, G, Sp, dh).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, Sp, H, dh)[:, :S]


@functools.partial(jax.jit, static_argnames=("chunk",))
def mlstm_chunkwise(q, k, v, it, ft, *, chunk: int = 256):
    """Chunkwise mLSTM. q/k/v: (B, S, H, dh); it/ft: (B, S, H)."""
    B, S, H, dh = q.shape
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        z4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        z3 = ((0, 0), (0, pad), (0, 0))
        q, k, v = jnp.pad(q, z4), jnp.pad(k, z4), jnp.pad(v, z4)
        it = jnp.pad(it, z3)
        ft = jnp.pad(ft, z3, constant_values=30.0)  # forget≈1 on padding
    Sp = S + pad

    def plane(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, Sp, -1)

    out = mlstm_pallas(plane(q), plane(k), plane(v),
                       plane(it[..., None]), plane(ft[..., None]),
                       chunk=c, interpret=not _on_tpu())
    return out.reshape(B, H, Sp, dh).transpose(0, 2, 1, 3)[:, :S]


@functools.partial(jax.jit, static_argnames=("t_blk",))
def rglru_scan(a, x, *, t_blk: int = 128):
    """Gated linear recurrence y_t = a_t·y_{t-1} + x_t. a/x: (B, S, W)."""
    B, S, W = a.shape
    tb = min(t_blk, S)
    pad = (-S) % tb
    if pad:
        z = ((0, 0), (0, pad), (0, 0))
        a = jnp.pad(a, z)   # a=0 on padding: resets do not leak
        x = jnp.pad(x, z)
    y = rglru_pallas(a, x, t_blk=tb, interpret=not _on_tpu())
    return y[:, :S]


@jax.jit
def fingerprint(x):
    """uint32 digest of any array (bitcast to words first)."""
    if x.dtype in (jnp.bfloat16, jnp.float16):
        w = jax.lax.bitcast_convert_type(x, jnp.uint16).astype(jnp.uint32)
    elif x.dtype == jnp.float32:
        w = jax.lax.bitcast_convert_type(x, jnp.uint32)
    elif x.dtype in (jnp.int32, jnp.uint32):
        w = jax.lax.bitcast_convert_type(x, jnp.uint32)
    else:
        w = x.astype(jnp.uint32)
    return fingerprint_pallas(w.reshape(-1), interpret=not _on_tpu())
