"""Chunkwise mLSTM Pallas kernel (xLSTM's matrix-LSTM training form).

One plane = one (batch, head).  Grid: (P planes, nc chunks); the chunk axis
is innermost and carries the inter-chunk state — matrix memory C (dh×dh),
normalizer n (dh) and stabilizer m (scalar) — in VMEM scratch across grid
steps (state resets when the chunk index wraps to 0).

Per-step VMEM: q/k/v chunks (3·c·dh) + C (dh²·f32) + intra D matrix
(c²·f32).  With c = 256, dh = 512: 3·256·512·2 + 512²·4 + 256²·4 ≈ 2.1 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, i_ref, f_ref, h_ref,
                  C_ref, n_ref, m_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _reset():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)

    q = q_ref[0].astype(jnp.float32)     # (c, dh)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    it = i_ref[0].astype(jnp.float32)    # (c, 1) input gate preact
    ft = f_ref[0].astype(jnp.float32)    # (c, 1) forget gate preact

    lf = jax.nn.log_sigmoid(ft)
    csum = jnp.cumsum(lf, axis=0)        # (c, 1)
    total = csum[-1]                     # (1,)

    m_prev = m_ref[0, 0]
    # intra-chunk log weights a[t,s] = csum_t − csum_s + i_s  (s ≤ t)
    a = csum - csum.T + it.T             # (c, c)
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    a = jnp.where(tri, a, NEG_INF)
    b = csum + m_prev                    # (c, 1) inter-chunk decay
    m_new = jnp.maximum(jnp.max(a, axis=1, keepdims=True), b)
    D = jnp.exp(a - m_new)
    scale_q = jnp.exp(b - m_new)         # (c, 1)

    s_qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    w = s_qk * D                         # (c, c)
    intra = jax.lax.dot(w, v, preferred_element_type=jnp.float32)
    inter = jax.lax.dot(q, C_ref[...],
                        preferred_element_type=jnp.float32) * scale_q
    num = intra + inter
    n_intra = jnp.sum(w, axis=1, keepdims=True)
    n_inter = (q @ n_ref[...].T) * scale_q            # (c, 1)
    denom = jnp.maximum(jnp.abs(n_intra + n_inter), 1.0)
    h_ref[0] = (num / denom).astype(h_ref.dtype)

    # inter-chunk state update
    m_next = jnp.maximum(total[0] + m_prev, jnp.max(total - csum + it))
    dec = jnp.exp(total[0] + m_prev - m_next)
    w_s = jnp.exp(total - csum + it - m_next)         # (c, 1)
    C_ref[...] = C_ref[...] * dec + jax.lax.dot_general(
        k * w_s, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_ref[...] = n_ref[...] * dec + jnp.sum(k * w_s, axis=0, keepdims=True)
    m_ref[0, 0] = m_next


def mlstm_pallas(q: jax.Array, k: jax.Array, v: jax.Array, it: jax.Array,
                 ft: jax.Array, *, chunk: int = 256,
                 interpret: bool = True) -> jax.Array:
    """q/k/v: (P, S, dh) planes; it/ft: (P, S, 1) gate pre-activations.
    S must be a multiple of ``chunk``."""
    P, S, dh = q.shape
    c = min(chunk, S)
    assert S % c == 0
    nc = S // c
    kernel = functools.partial(_mlstm_kernel, chunk=c)

    def x_map(p, i):
        return (p, i, 0)

    return pl.pallas_call(
        kernel,
        grid=(P, nc),
        in_specs=[
            pl.BlockSpec((1, c, dh), x_map),
            pl.BlockSpec((1, c, dh), x_map),
            pl.BlockSpec((1, c, dh), x_map),
            pl.BlockSpec((1, c, 1), x_map),
            pl.BlockSpec((1, c, 1), x_map),
        ],
        out_specs=pl.BlockSpec((1, c, dh), x_map),
        out_shape=jax.ShapeDtypeStruct((P, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),
            pltpu.VMEM((1, dh), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, it, ft)
