"""State-attestation fingerprint Pallas kernel.

The paper's disaggregated-memory checksums (§6.1), adapted to the TPU data
plane (DESIGN.md §3): an order-independent hash-reduce over a parameter/
gradient shard, computed on-device each training step and attested through
uBFT's CTBcast by the replicated training coordinator.  Memory-bound by
design — it reads every word exactly once.

Grid: 1-D over blocks; a (1,1) SMEM accumulator carries the running digest;
the final block writes the result.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MIX = 0x9E3779B9  # golden-ratio Weyl constant (matches runtime.attest)


def _fp_kernel(x_ref, o_ref, acc_ref, *, nblocks: int):
    bi = pl.program_id(0)

    @pl.when(bi == 0)
    def _reset():
        acc_ref[0, 0] = jnp.uint32(0)

    w = x_ref[...].astype(jnp.uint32)
    w = w * jnp.uint32(MIX) ^ (w >> 16)
    acc_ref[0, 0] = acc_ref[0, 0] + jnp.sum(w, dtype=jnp.uint32)

    @pl.when(bi == nblocks - 1)
    def _emit():
        o_ref[0] = acc_ref[0, 0]


def fingerprint_pallas(words: jax.Array, *, block: int = 4096,
                       interpret: bool = True) -> jax.Array:
    """words: (N,) uint32 (bitcast upstream); returns (1,) uint32 digest."""
    n = words.shape[0]
    blk = min(block, n)
    pad = (-n) % blk
    if pad:
        words = jnp.pad(words, (0, pad))
    nblocks = words.shape[0] // blk
    kernel = functools.partial(_fp_kernel, nblocks=nblocks)
    return pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.uint32),
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.uint32)],
        interpret=interpret,
    )(words)
