"""Pallas TPU kernels for the compute hot-spots.

  swa          — sliding-window flash attention (gemma3/recurrentgemma local
                 layers; the long-context path of the assignment)
  mlstm        — chunkwise mLSTM with carried matrix memory (xLSTM)
  rglru        — blocked gated linear recurrence (RecurrentGemma)
  fingerprint  — hash-reduce state attestation (the paper's §6.1 checksum
                 mechanism adapted to the TPU data plane, DESIGN.md §3)

Each kernel ships ``<name>.py`` (pl.pallas_call + explicit BlockSpec VMEM
tiling), a jitted wrapper in ``ops.py``, and a pure-jnp oracle in ``ref.py``.
This container is CPU-only: kernels are validated with ``interpret=True``
(the kernel body executes on CPU); TPU is the lowering target.
"""
