"""Sliding-window flash attention Pallas kernel.

Banded causal attention with window w: query chunk i attends to key chunks
{i-1, i} (chunk size = w).  Grid: (B·KV·G planes, nq query chunks, 2 band
positions); the band axis is innermost so the online-softmax running state
(m, l, acc) carries across the two visits to the same output block in VMEM
scratch.

VMEM working set per step: q block (c×dh) + k/v blocks (c×dh) + acc (c×dh,
f32) + scores (c×c, f32).  With c = w = 1024, dh = 256:
3·(1024·256·2B) + 1024·256·4B + 1024·1024·4B ≈ 6.8 MiB — fits v5e's 16 MiB
VMEM with MXU-aligned (multiple-of-128) tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _swa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                window: int, chunk: int, seq: int):
    qi = pl.program_id(1)      # query chunk index
    j = pl.program_id(2)       # band position: 0 = previous chunk, 1 = own

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # (c, dh)
    k = k_ref[0].astype(jnp.float32)            # (c, dh)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * (q.shape[-1] ** -0.5)               # (c, c)

    qpos = qi * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    kchunk = qi - 1 + j
    kpos = kchunk * chunk + jax.lax.broadcasted_iota(jnp.int32,
                                                     (chunk, chunk), 1)
    delta = qpos - kpos
    valid = (delta >= 0) & (delta < window) & (kpos >= 0) & (kpos < seq)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                          # (c, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def swa_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *, window: int,
               interpret: bool = True) -> jax.Array:
    """q: (P, S, dh) query planes; k/v: (P, S, dh) (GQA planes pre-expanded).

    S must be a multiple of ``window`` (callers pad).  chunk = window.
    """
    P, S, dh = q.shape
    c = window
    assert S % c == 0, "pad sequence to a multiple of the window"
    nq = S // c

    kernel = functools.partial(_swa_kernel, window=window, chunk=c, seq=S)
    grid = (P, nq, 2)

    def q_map(p, i, j):
        return (p, i, 0)

    def kv_map(p, i, j):
        return (p, jnp.maximum(i - 1 + j, 0), 0)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, dh), q_map),
            pl.BlockSpec((1, c, dh), kv_map),
            pl.BlockSpec((1, c, dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, c, dh), q_map),
        out_shape=jax.ShapeDtypeStruct((P, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((c, 1), jnp.float32),
            pltpu.VMEM((c, 1), jnp.float32),
            pltpu.VMEM((c, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out
