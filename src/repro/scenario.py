"""Declarative scenarios: topology + apps + workloads + faults, executed.

A :class:`ScenarioSpec` describes a whole deployment the way the paper's
evaluation sections describe theirs: one shared disaggregated-memory
substrate (f_m, n_pools, network parameters, seed), any number of
replicated applications attached to it (:class:`AppSpec` — app factory,
consensus config, per-pool byte budget), a workload per app
(:class:`Workload` — closed-loop back-to-back clients or an open-loop
seeded Poisson arrival process), and an optional
:class:`~repro.sim.faults.FaultSchedule`.  :func:`run_scenario` builds it,
drives every workload concurrently on the one event loop, audits the
per-app Table 2 budgets, and returns per-app latencies / counters /
memory occupancy.

This replaces the hand-rolled setup previously copied across every
``benchmarks/fig*.py``, ``benchmarks/throughput.py``,
``benchmarks/fault_scenarios.py``, the test fixtures and the examples —
and it is the only way to express the paper's headline deployment: *many*
replicated applications sharing one substrate (§8), since a private
``build_cluster`` per app cannot put two apps on one event loop.

Workload semantics
------------------
* ``closed`` — ``n_clients`` clients re-fire back-to-back.  With
  ``n_requests`` set, the app completes after that many requests total
  (the classic figure workload); with ``duration_us`` set instead, clients
  re-fire until the window closes (the throughput workload).
* ``open`` — arrivals are a seeded Poisson process (``rate_rps`` per
  client over ``duration_us``); requests are injected at their arrival
  times *regardless of completions*, so interference sweeps do not
  self-throttle the way closed loops do.  Arrival draws come from a
  dedicated ``numpy`` RNG (``seed``), never from the simulator's RNG —
  adding an open-loop app cannot perturb the network jitter stream of its
  neighbours.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.consensus import App, ConsensusConfig
from repro.core.registers import POOL_MEMORY_BUDGET
from repro.core.smr import Cluster
from repro.core.substrate import Substrate
from repro.sim.faults import FaultInjector, FaultSchedule
from repro.sim.net import NetParams


# --------------------------------------------------------------------------
# Specs
# --------------------------------------------------------------------------
@dataclass
class Workload:
    """One app's load: closed-loop (count- or duration-bounded) or
    open-loop Poisson arrivals.

    Key popularity: with ``keyspace > 0`` every request draws a key from a
    seeded sampler — uniform when ``zipf_theta == 0``, Zipf(θ) otherwise
    (rank-r key has weight 1/r^θ; the YCSB-style skew knob, θ≈0.99 being
    the classic "hot-key" setting).  ``payload_fn`` is then called as
    ``payload_fn(i, key)`` and turns the drawn key into the request — so
    skewed-traffic sweeps are declarative rather than hand-rolled per
    benchmark.  Key draws come from a dedicated RNG (``key_seed``), never
    the simulator's, and are indexed by request number: request ``i``
    always sees the same key regardless of interleaving.
    """
    kind: str = "closed"               # "closed" | "open" | "trace"
    n_requests: int = 0                # closed: total requests to complete
    duration_us: float = 0.0           # closed: window; open: arrival window
    rate_rps: float = 0.0              # open: Poisson rate per client (req/s)
    payload: bytes = b"x" * 32
    payload_fn: Optional[Callable[..., Any]] = None
    n_clients: int = 1
    seed: int = 0                      # open: arrival-process stream
    timeout_us: float = 60_000_000.0   # drain bound after the window closes
    keyspace: int = 0                  # >0: draw a key per request
    zipf_theta: float = 0.0            # 0 = uniform; >0 = Zipf skew
    key_seed: int = 0                  # key-popularity stream
    #: kind="trace": a precomputed ``[(t_us, payload), ...]`` schedule
    #: (the workload library's generators — repro.workloads — produce
    #: these); arrivals are replayed verbatim, round-robin over clients
    trace: Optional[List[Tuple[float, Any]]] = None

    def __post_init__(self):
        if self.kind not in ("closed", "open", "trace"):
            raise ValueError(f"unknown workload kind {self.kind!r}")
        if self.kind == "trace":
            if not self.trace:
                raise ValueError("trace workload needs a non-empty trace")
            if not self.duration_us:
                self.duration_us = max(t for t, _ in self.trace) + 1.0
        if self.kind == "closed":
            if not (self.n_requests or self.duration_us):
                raise ValueError(
                    "closed workload needs n_requests or duration_us")
            if self.n_requests and self.duration_us:
                raise ValueError(
                    "closed workload takes n_requests OR duration_us, not "
                    "both (a count target cannot be guaranteed inside a "
                    "fixed window)")
        if self.kind == "open" and not (self.rate_rps > 0 and
                                        self.duration_us > 0):
            raise ValueError("open workload needs rate_rps and duration_us")
        if self.keyspace and self.payload_fn is None:
            raise ValueError("a keyed workload (keyspace > 0) needs a "
                             "payload_fn(i, key) to build requests")
        self._keys: List[bytes] = []
        self._key_rng: Any = None
        self._key_cdf: Any = None

    def key_for(self, i: int) -> bytes:
        """The i-th request's key — lazily drawn, cached, index-stable."""
        if self._key_rng is None:
            self._key_rng = np.random.default_rng(self.key_seed)
            if self.zipf_theta > 0.0:
                w = 1.0 / np.arange(1, self.keyspace + 1) ** self.zipf_theta
                self._key_cdf = np.cumsum(w / w.sum())
        rng = self._key_rng
        while len(self._keys) <= i:
            if self._key_cdf is None:
                idx = int(rng.integers(self.keyspace))
            else:
                idx = int(np.searchsorted(self._key_cdf, rng.random()))
            self._keys.append(b"k%07d" % idx)
        return self._keys[i]

    def payload_for(self, i: int) -> Any:
        if self.keyspace:
            return self.payload_fn(i, self.key_for(i))
        return self.payload_fn(i) if self.payload_fn is not None \
            else self.payload


@dataclass
class AppSpec:
    """One replicated application on the shared substrate."""
    name: str
    app: Callable[[], App]
    cfg: Optional[ConsensusConfig] = None
    workload: Optional[Workload] = None
    budget: int = POOL_MEMORY_BUDGET   # per-pool Table 2 byte budget
    replica_cls: Any = None            # default: UbftReplica
    #: pool placement policy: pin this app's register sharding to a pool
    #: subset (indices / names / MemoryPool objects); None = every pool
    pools: Any = None
    #: self-healing membership (``Cluster.enable_self_healing``): True /
    #: a ``HealthConfig`` / a dict of overrides; None|False = off
    self_heal: Any = None


@dataclass
class ServiceSpec:
    """One sharded service on the substrate: K uBFT groups
    (``<name>/s<i>``, each an independent 2f+1 deployment of ``app``)
    behind a :class:`~repro.service.router.ShardRouter`, driven by one
    workload whose ``payload_fn`` returns service *ops* (``("get", k)`` /
    ``("set", k, v)`` / ``("mset", pairs)``) instead of wire bytes —
    typically a keyed workload (``keyspace``/``zipf_theta``) so the hot
    shard emerges from the key distribution, not from hand-routing."""
    name: str
    n_shards: int
    cfg: Optional[ConsensusConfig] = None
    workload: Optional[Workload] = None
    #: app factory per shard; None = repro.apps.kvstore.ShardKVApp
    app: Optional[Callable[[], App]] = None
    budget: int = POOL_MEMORY_BUDGET
    tx_timeout_us: float = 20_000.0
    pools: Any = None
    #: self-heal every shard group (split-born shards included)
    self_heal: Any = None


@dataclass
class ScenarioSpec:
    """Topology + apps + workloads + faults, declaratively."""
    apps: List[AppSpec]
    services: List[ServiceSpec] = field(default_factory=list)
    f_m: int = 1
    n_pools: int = 1
    seed: int = 0
    params: Optional[NetParams] = None
    auto_reconfigure: bool = False
    lease_us: float = 200.0
    #: a FaultSchedule, or a callable ``(substrate) -> FaultSchedule`` for
    #: schedules that need the live pools (FaultSchedule.seeded)
    faults: Any = None
    #: extra settle time after all workloads complete (lets view changes,
    #: reconfigurations and replica convergence finish before assertions)
    drain_us: float = 0.0


# --------------------------------------------------------------------------
# Results
# --------------------------------------------------------------------------
@dataclass
class AppResult:
    name: str
    latencies: List[float]
    issued: int
    completed: int
    #: this app's occupied disaggregated memory per pool (Table 2 per app)
    memory_by_pool: Dict[str, int]

    @property
    def stalled(self) -> int:
        return self.issued - self.completed


@dataclass
class ScenarioResult:
    substrate: Substrate
    clusters: Dict[str, Cluster]
    apps: Dict[str, AppResult]
    injector: Optional[FaultInjector]
    #: per-app budget overruns recorded by the substrate audit
    budget_overruns: List[Tuple[float, str, str, int, int]]
    msgs_sent: int
    bytes_sent: int
    events_processed: int

    def latencies(self, name: str = "") -> List[float]:
        return self.apps[name].latencies


# --------------------------------------------------------------------------
# Workload drivers
# --------------------------------------------------------------------------
class _WorkloadRun:
    """Live state of one app's workload on the event loop."""

    def __init__(self, cluster: Cluster, w: Workload):
        self.cluster = cluster
        self.w = w
        self.lats: List[float] = []
        self.issued = 0
        self.completed = 0
        self._open_seq = -1
        self.t_end = (cluster.sim.now + w.duration_us
                      if w.duration_us else None)
        self.clients = [cluster.new_client() for _ in range(w.n_clients)]
        if w.kind == "closed":
            self._start_closed()
        elif w.kind == "trace":
            self._start_trace()
        else:
            self._start_open()

    # ------------------------------------------------------------- closed
    def _start_closed(self) -> None:
        for cl in self.clients:
            self._fire_closed(cl)

    def _fire_closed(self, cl) -> None:
        w, sim = self.w, self.cluster.sim
        if w.n_requests and self.issued >= w.n_requests:
            return
        if self.t_end is not None and sim.now >= self.t_end:
            return
        i = self.issued
        self.issued += 1

        def done(_res, lat: float) -> None:
            self.completed += 1
            self.lats.append(lat)
            self._fire_closed(cl)

        cl.request(w.payload_for(i), done)

    # --------------------------------------------------------------- open
    def _start_open(self) -> None:
        """Schedule the whole seeded Poisson arrival process up front.

        Inter-arrival gaps are exponential with mean ``1e6 / rate_rps`` µs,
        drawn client-by-client from a dedicated RNG — the schedule is a
        pure function of (seed, rate, duration, n_clients) and is
        independent of everything else in the simulation.
        """
        w, sim = self.w, self.cluster.sim
        rng = np.random.default_rng(w.seed)
        mean_gap = 1e6 / w.rate_rps
        t0 = sim.now
        for cl in self.clients:
            t = t0 + float(rng.exponential(mean_gap))
            while t < t0 + w.duration_us:
                sim.at(t, (lambda cl=cl: self._fire_open(cl)),
                       note="workload.arrival")
                self.issued += 1
                t += float(rng.exponential(mean_gap))

    def _fire_open(self, cl) -> None:
        self._open_seq += 1
        i = self._open_seq

        def done(_res, lat: float) -> None:
            self.completed += 1
            self.lats.append(lat)

        cl.request(self.w.payload_for(i), done)

    # -------------------------------------------------------------- trace
    def _start_trace(self) -> None:
        """Replay a precomputed ``(t_us, payload)`` schedule verbatim
        (open-loop: arrivals fire regardless of completions), round-robin
        over the client pool.  Trace times are relative to now."""
        w, sim = self.w, self.cluster.sim
        t0 = sim.now
        n_cl = len(self.clients)
        for j, (t, payload) in enumerate(w.trace):
            if t >= w.duration_us:
                continue
            cl = self.clients[j % n_cl]
            sim.at(t0 + t, (lambda cl=cl, p=payload: self._fire_trace(cl, p)),
                   note="workload.arrival")
            self.issued += 1

    def _fire_trace(self, cl, payload) -> None:
        def done(_res, lat: float) -> None:
            self.completed += 1
            self.lats.append(lat)

        cl.request(payload, done)

    # ----------------------------------------------------------- progress
    def done(self) -> bool:
        w = self.w
        if w.kind == "closed":
            if w.n_requests:
                return self.completed >= w.n_requests
            # duration-bounded closed loop: the window IS the measurement —
            # in-flight stragglers are not drained (classic throughput
            # window; ``issued - completed`` shows up as ``stalled``)
            return (self.t_end is not None and
                    self.cluster.sim.now >= self.t_end)
        # open loop / trace replay: every arrival issued and completed
        if self.t_end is not None and self.cluster.sim.now < self.t_end:
            return False
        return self.completed >= self.issued


def open_loop(cluster: Cluster, payload_fn: Callable[[int], bytes],
              rate_rps: float, duration_us: float, n_clients: int = 1,
              seed: int = 0, timeout_us: float = 60_000_000.0) -> List[float]:
    """Standalone open-loop driver for one already-built cluster: seeded
    Poisson arrivals at ``rate_rps`` per client over ``duration_us``, then
    drain.  Returns completion latencies (see ``benchmarks/common.py``'s
    ``open_loop_cluster`` wrapper)."""
    run = _WorkloadRun(cluster, Workload(
        kind="open", rate_rps=rate_rps, duration_us=duration_us,
        payload_fn=payload_fn, n_clients=n_clients, seed=seed,
        timeout_us=timeout_us))
    cluster.sim.run(until=cluster.sim.now + duration_us)
    ok = cluster.sim.run_until(run.done, timeout=timeout_us)
    if not ok:
        raise TimeoutError(
            f"open loop stalled: {run.completed}/{run.issued} completed")
    return run.lats


# --------------------------------------------------------------------------
# Execution
# --------------------------------------------------------------------------
def build_deployment(spec: ScenarioSpec
                     ) -> Tuple[Substrate, Dict[str, Cluster]]:
    """Build the substrate and attach every app — no workload driving.
    For benchmarks that need manual control (tracing, warmup) over a
    declaratively-specified topology."""
    substrate = Substrate(f_m=spec.f_m, n_pools=spec.n_pools,
                          params=spec.params, seed=spec.seed,
                          auto_reconfigure=spec.auto_reconfigure,
                          lease_us=spec.lease_us)
    clusters: Dict[str, Cluster] = {}
    for a in spec.apps:
        kw: Dict[str, Any] = {}
        if a.replica_cls is not None:
            kw["replica_cls"] = a.replica_cls
        if a.pools is not None:
            kw["pools"] = a.pools
        clusters[a.name] = Cluster.attach(substrate, a.app, name=a.name,
                                          cfg=a.cfg, budget=a.budget, **kw)
        if a.self_heal:
            clusters[a.name].enable_self_healing(a.self_heal)
    for s in spec.services:
        from repro.service import ShardedService  # avoid a static cycle
        app = s.app
        if app is None:
            from repro.apps.kvstore import ShardKVApp
            app = ShardKVApp
        svc = ShardedService.attach(substrate, s.n_shards, name=s.name,
                                    cfg=s.cfg, app=app, budget=s.budget,
                                    tx_timeout_us=s.tx_timeout_us,
                                    pools=s.pools, self_heal=s.self_heal)
        # shard groups are ordinary attached apps: expose them under their
        # full names so FaultInjector events can target "<svc>/s<i>/r<j>"
        for i, shard in enumerate(svc.shards):
            clusters[shard.name] = shard
    return substrate, clusters


def run_scenario(spec: ScenarioSpec) -> ScenarioResult:
    """Execute a scenario end to end: build, inject faults, drive every
    app's workload concurrently on the shared event loop, drain, audit the
    per-app memory budgets."""
    substrate, clusters = build_deployment(spec)
    sim = substrate.sim

    injector: Optional[FaultInjector] = None
    if spec.faults is not None:
        sched = spec.faults(substrate) if callable(spec.faults) \
            else spec.faults
        if not isinstance(sched, FaultSchedule):
            sched = FaultSchedule(sched)
        injector = FaultInjector(sim, substrate.net, substrate.pools,
                                 clusters=clusters,
                                 services=substrate.services).install(sched)

    runs: Dict[str, _WorkloadRun] = {}
    for a in spec.apps:
        if a.workload is not None:
            runs[a.name] = _WorkloadRun(clusters[a.name], a.workload)
    for s in spec.services:
        if s.workload is not None:
            # a ShardedService quacks like a cluster to the driver (.sim,
            # .new_client, client.request) — ops route through the shards
            runs[s.name] = _WorkloadRun(substrate.services[s.name],
                                        s.workload)

    # Phase 1: run out the longest load window (duration-bounded apps keep
    # injecting/refiring until their own t_end inside this window).
    t_end = max((r.t_end for r in runs.values() if r.t_end is not None),
                default=None)
    if t_end is not None:
        sim.run(until=t_end)
    # Phase 2: drain — count-bounded closed loops finish their totals,
    # open loops complete their in-flight tail.
    if runs:
        timeout = max(r.w.timeout_us for r in runs.values())
        ok = sim.run_until(lambda: all(r.done() for r in runs.values()),
                           timeout=timeout)
        if not ok:
            detail = ", ".join(
                f"{name or '<default>'}: {r.completed}/"
                f"{r.issued if r.issued else r.w.n_requests}"
                for name, r in runs.items() if not r.done())
            raise TimeoutError(f"scenario stalled after {timeout} µs "
                               f"({detail})")
    if spec.drain_us:
        sim.run(until=sim.now + spec.drain_us)

    usage = substrate.memory_by_app()
    overruns = substrate.audit_budgets(usage)

    def mem_of(name: str) -> Dict[str, int]:
        # a service's occupancy is the sum of its shard apps' (each shard
        # is its own app "<svc>/s<i>" in the substrate's accounting)
        svc = substrate.services.get(name)
        if svc is None:
            return dict(usage.get(name, {}))
        agg: Dict[str, int] = {}
        for shard in svc.shards:
            for pool, nbytes in usage.get(shard.name, {}).items():
                agg[pool] = agg.get(pool, 0) + nbytes
        return agg

    apps = {
        name: AppResult(name=name, latencies=r.lats, issued=r.issued,
                        completed=r.completed, memory_by_pool=mem_of(name))
        for name, r in runs.items()
    }
    # apps/services without a workload still get their memory accounting
    for name in ([a.name for a in spec.apps] +
                 [s.name for s in spec.services]):
        if name not in apps:
            apps[name] = AppResult(name=name, latencies=[], issued=0,
                                   completed=0, memory_by_pool=mem_of(name))
    return ScenarioResult(substrate=substrate, clusters=clusters, apps=apps,
                          injector=injector, budget_overruns=overruns,
                          msgs_sent=substrate.net.msgs_sent,
                          bytes_sent=substrate.net.bytes_sent,
                          events_processed=sim.events_processed)
