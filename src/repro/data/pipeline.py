"""Deterministic, shardable synthetic token pipeline.

Every batch is a pure function of (seed, step, shard), so:
* all data-parallel shards independently materialize *their slice* of the
  global batch with no data service in the loop;
* after a checkpoint/restart or an elastic re-shard, replaying from the
  agreed step id reproduces the exact token stream — this is the property
  the uBFT-replicated coordinator relies on: ordering (step → data range)
  through consensus makes the input pipeline a deterministic state machine.

The stream is a stationary Markov-ish mixture (not uniform noise) so that
training-loss curves are meaningfully decreasing in the examples.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.n_shards == 0
        self.per_shard = cfg.global_batch // cfg.n_shards

    def _rng(self, step: int, shard: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, shard]))

    def batch(self, step: int, shard: int = 0) -> Dict[str, np.ndarray]:
        """Returns this shard's slice of the global batch for ``step``."""
        c = self.cfg
        rng = self._rng(step, shard)
        B, S = self.per_shard, c.seq_len
        # structured stream: piecewise-linear token walks + noise → learnable
        base = rng.integers(0, c.vocab, size=(B, 1))
        stride = rng.integers(1, 17, size=(B, 1))
        ramp = (base + stride * np.arange(S + 1)[None, :]) % c.vocab
        noise = rng.integers(0, c.vocab, size=(B, S + 1))
        mask = rng.random((B, S + 1)) < 0.1
        toks = np.where(mask, noise, ramp).astype(np.int32)
        return {"inputs": toks[:, :-1], "targets": toks[:, 1:]}

    def global_batch(self, step: int) -> Dict[str, np.ndarray]:
        shards = [self.batch(step, s) for s in range(self.cfg.n_shards)]
        return {k: np.concatenate([sh[k] for sh in shards], axis=0)
                for k in shards[0]}
