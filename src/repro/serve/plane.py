"""The replicated inference plane: SLOs, admission sizing, and the
"millions of users" scenario glue.

Composes the pieces into one runnable deployment:

* :class:`repro.runtime.server.TokenServerApp` — session/KV metadata as
  the replicated state machine;
* :class:`repro.serve.costmodel.ServingCostModel` — roofline decode
  cost charged per request through ``App.cost_us`` (the consensus
  layer's deferred execution engine);
* :class:`repro.core.consensus.AdmissionConfig` — leader-side shedding
  with agreed deterministic BUSY replies, sized here from the SLO: the
  queue-depth horizon is ``deadline / per-request cost``, the depth at
  which a newly admitted request could still meet its deadline.

``InferencePlane.build`` wires them; ``run_trace`` replays a workload
trace (``repro.workloads``) open-loop and ``slo_report`` reduces the
outcomes to SLO attainment / shed fraction / latency percentiles plus
the cluster's admission telemetry.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.consensus import AdmissionConfig, ConsensusConfig
from repro.runtime.server import ReplicatedServer, TokenServerApp
from repro.serve.costmodel import ServingCostModel


@dataclass(frozen=True)
class SLOSpec:
    """Per-app service-level objective."""
    deadline_us: float            # end-to-end latency target
    target_attainment: float = 0.99


def admission_for(cost_model: ServingCostModel, slo: SLOSpec,
                  typical_prompt: int = 16, typical_decode: int = 8,
                  headroom: float = 1.0,
                  max_shed: int = 8) -> AdmissionConfig:
    """Size admission control from the SLO and the roofline cost: shed
    once the backlog is deep enough that a newly admitted request would
    blow ``deadline_us`` just waiting for the decode engine."""
    per_req = cost_model.request_us(typical_prompt, typical_decode,
                                    ctx=typical_prompt)
    q_high = max(2, int(headroom * slo.deadline_us / max(per_req, 1e-9)))
    return AdmissionConfig(queue_high=q_high,
                           queue_accept=max(1, q_high // 2),
                           max_shed=max_shed)


def greedy_decode_fn(vocab: int = 50_257
                     ) -> Callable[[str, List[int], int], List[int]]:
    """A deterministic stand-in decoder (greedy argmax of a fixed hash):
    replicas produce identical tokens, which is all consensus needs."""
    def decode(sid: str, hist: List[int], n: int) -> List[int]:
        h = len(hist) * 2654435761
        return [(h + 40_503 * k) % vocab for k in range(1, n + 1)]
    return decode


@dataclass
class InferencePlane:
    """One uBFT-replicated token server with SLO-aware admission."""
    server: ReplicatedServer
    cost_model: ServingCostModel
    slo: SLOSpec
    admission: Optional[AdmissionConfig]
    #: per-request outcomes from run_trace: (t_issue_us, latency_us, ok)
    #: where ok=False marks an admission-shed (BUSY) reply
    outcomes: List[Tuple[float, float, bool]] = field(default_factory=list)

    @property
    def cluster(self):
        return self.server.cluster

    @classmethod
    def build(cls, cost_model: ServingCostModel, slo: SLOSpec,
              decode_fn: Optional[Callable] = None, f: int = 1,
              admission: Any = True,
              cfg: Optional[ConsensusConfig] = None,
              substrate=None, name: str = "",
              typical_prompt: int = 16, typical_decode: int = 8,
              ) -> "InferencePlane":
        """``admission=True`` sizes an AdmissionConfig from the SLO and
        cost model; pass an AdmissionConfig to pin it, or False/None for
        a no-admission plane (the collapse baseline)."""
        if admission is True:
            adm = admission_for(cost_model, slo, typical_prompt,
                                typical_decode)
        elif isinstance(admission, AdmissionConfig):
            adm = admission
        else:
            adm = None
        if cfg is None:
            cfg = ConsensusConfig(f=f, max_request_bytes=4096)
        cfg.admission = adm
        server = ReplicatedServer.build(
            decode_fn or greedy_decode_fn(), cfg=cfg, substrate=substrate,
            name=name, cost_model=cost_model)
        return cls(server=server, cost_model=cost_model, slo=slo,
                   admission=adm)

    # ------------------------------------------------------------ driving
    def run_trace(self, trace: List[Tuple[float, bytes]],
                  n_clients: int = 4, drain_us: float = 2_000_000.0,
                  ) -> List[Tuple[float, float, bool]]:
        """Replay a ``(t_us, payload)`` trace open-loop (arrivals fire
        regardless of completions), then drain.  Appends to and returns
        ``outcomes``."""
        cluster = self.cluster
        sim = cluster.sim
        clients = [cluster.new_client() for _ in range(n_clients)]
        t0 = sim.now
        pending = {"n": 0}

        def fire(cl, t: float, payload: bytes) -> None:
            pending["n"] += 1

            def done(res: bytes, lat: float) -> None:
                pending["n"] -= 1
                self.outcomes.append((t, lat, res != b"BUSY"))

            cl.request(payload, done)

        n = 0
        for j, (t, payload) in enumerate(trace):
            cl = clients[j % n_clients]
            sim.at(t0 + t, (lambda cl=cl, t=t, p=payload: fire(cl, t, p)),
                   note="serve.arrival")
            n += 1
        t_end = t0 + (max(t for t, _ in trace) if trace else 0.0)
        sim.run(until=t_end)
        sim.run_until(lambda: pending["n"] == 0 and
                      len(self.outcomes) >= n, timeout=drain_us)
        return self.outcomes

    # ---------------------------------------------------------- reporting
    def slo_report(self) -> Dict[str, Any]:
        """SLO attainment over *all* arrivals: a request counts as
        attained only if it was served (not shed) within the deadline.
        Sheds are the price of keeping the served tail flat — they are
        reported separately, not hidden."""
        outs = self.outcomes
        served = sorted(lat for _, lat, ok in outs if ok)
        shed = sum(1 for _, _, ok in outs if not ok)
        n = len(outs)
        within = sum(1 for _, lat, ok in outs
                     if ok and lat <= self.slo.deadline_us)

        def pct(p: float) -> float:
            if not served:
                return float("nan")
            return served[min(len(served) - 1, int(p * len(served)))]

        report = {
            "issued": n,
            "served": len(served),
            "shed": shed,
            "shed_frac": shed / n if n else 0.0,
            "attainment": within / n if n else 0.0,
            "served_p50_us": pct(0.50),
            "served_p99_us": pct(0.99),
            "deadline_us": self.slo.deadline_us,
        }
        stats = self.cluster.stats()
        if "admission" in stats:
            report["admission"] = stats["admission"]
        return report
