"""Roofline serving-cost model: per-token decode / prefill time in µs.

Bridges the repo's two halves: the JAX serving stack knows what a model
costs per token (``benchmarks/roofline.py``'s three-term roofline over
``configs/registry.py`` architectures), and the consensus plane now has
a deferred execution engine (``App.cost_us``) that charges deterministic
service time per decided request.  This module turns an architecture
into that charge.

The decode roofline (one token for each of B batched streams):

    t_step = max( 2·N_active·B / PEAK_FLOPS,
                  (param_bytes + B·kv_bytes·ctx) / HBM_BW )

Small-batch decode is HBM-bound on reading the weights, so per-token
cost ≈ param_bytes / (HBM_BW·B) — the classic batching amortization.
Prefill is charged as one compute-bound pass over the prompt, amortized
across the same serving batch.  Constants match
``benchmarks/roofline.py`` (TPU-class chip: 197 TFLOP/s bf16, 819 GB/s
HBM).

``from_arch`` derives the parameter/KV byte counts analytically from a
:class:`repro.models.common.ModelConfig` (attention stacks with dense or
MoE FFNs — the gemma3/llama4/qwen3 serving archetypes); it imports the
config registry lazily because ``models/common.py`` imports JAX at
module level.  ``from_counts`` takes the counts directly and needs only
numpy — benchmarks and the fast test tier use it.
"""

from __future__ import annotations

from dataclasses import dataclass

# benchmarks/roofline.py's chip model
PEAK_FLOPS = 197e12
HBM_BW = 819e9


@dataclass(frozen=True)
class ServingCostModel:
    name: str
    param_bytes: float           # HBM-resident weight bytes
    active_params: float         # params touched per token (MoE: top-k only)
    kv_bytes_per_token: float    # KV-cache bytes appended per token, all layers
    batch: int = 32              # serving batch size B (streams per step)
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW

    # ------------------------------------------------------------ decode
    def decode_step_us(self, ctx: int = 0) -> float:
        """One batched decode step (B tokens), roofline max of compute
        and memory terms, in µs.  ``ctx`` is the per-stream context."""
        t_compute = 2.0 * self.active_params * self.batch / self.peak_flops
        t_memory = (self.param_bytes +
                    self.batch * self.kv_bytes_per_token * ctx) / self.hbm_bw
        return 1e6 * max(t_compute, t_memory)

    def decode_us_per_token(self, ctx: int = 0) -> float:
        """Per-request share of one decode step."""
        return self.decode_step_us(ctx) / self.batch

    # ----------------------------------------------------------- prefill
    def prefill_us(self, n_prompt: int) -> float:
        """One prompt pass (compute-bound at length, memory-bound floor
        of one weight read), amortized across the serving batch."""
        t_compute = 2.0 * self.active_params * n_prompt / self.peak_flops
        t_memory = self.param_bytes / self.hbm_bw
        return 1e6 * max(t_compute, t_memory) / self.batch

    def request_us(self, n_prompt: int, n_decode: int, ctx: int = 0) -> float:
        """Total service time of one request: prefill the prompt, then
        decode ``n_decode`` tokens at context ``ctx + n_prompt``."""
        return (self.prefill_us(n_prompt) +
                n_decode * self.decode_us_per_token(ctx + n_prompt))

    # ------------------------------------------------------ constructors
    @classmethod
    def from_counts(cls, name: str, n_params: float,
                    kv_bytes_per_token: float,
                    n_active: float = 0.0, batch: int = 32,
                    dtype_bytes: int = 2) -> "ServingCostModel":
        return cls(name=name, param_bytes=n_params * dtype_bytes,
                   active_params=n_active or n_params,
                   kv_bytes_per_token=kv_bytes_per_token, batch=batch)

    @classmethod
    def from_arch(cls, arch: str, batch: int = 32,
                  dtype_bytes: int = 2) -> "ServingCostModel":
        """Analytic counts from the architecture registry (imports the
        JAX-backed model configs — slow path / slow test tier only)."""
        from repro.configs.registry import get_config
        cfg = get_config(arch)
        D, dh = cfg.d_model, cfg.dh
        H, KV = cfg.n_heads, cfg.n_kv_heads
        n_total = float(cfg.vocab * D)            # embed
        if not cfg.tie_embeddings:
            n_total += cfg.vocab * D              # lm_head
        n_total += D                              # out_norm
        n_moe_inactive = 0.0
        kv_bytes = 0.0
        for spec in cfg.layer_list():
            if spec.kind != "attn":
                raise ValueError(
                    f"{arch}: serving cost model covers attention stacks "
                    f"(got layer kind {spec.kind!r})")
            n_total += D                          # ln1
            n_total += D * H * dh + 2 * D * KV * dh + H * dh * D
            if cfg.qk_norm:
                n_total += 2 * dh
            kv_bytes += 2.0 * KV * dh * dtype_bytes
            if spec.has_ffn:
                n_total += D                      # ln2
                if cfg.moe is not None:
                    m = cfg.moe
                    expert = 3.0 * D * m.d_expert
                    n_total += D * m.n_experts + m.n_experts * expert
                    n_moe_inactive += expert * (m.n_experts - m.top_k)
                else:
                    n_total += 3.0 * D * cfg.d_ff
        return cls(name=arch, param_bytes=n_total * dtype_bytes,
                   active_params=n_total - n_moe_inactive,
                   kv_bytes_per_token=kv_bytes, batch=batch)
