"""Replicated inference serving plane (DESIGN_SERVING.md).

The paper's §8 applications (Memcached, Redis, Liquibook) become
Byzantine-tolerant for ~10 µs of consensus; this package does the same
for the repo's own JAX serving stack.  Session/KV-cache metadata rides
uBFT consensus slots (:class:`repro.runtime.server.TokenServerApp`),
per-token decode cost comes from the roofline model over the registered
architectures (:mod:`repro.serve.costmodel`), and per-app SLOs size
leader-side admission control with agreed deterministic BUSY shedding
(:class:`repro.core.consensus.AdmissionConfig`).
"""

from repro.serve.costmodel import HBM_BW, PEAK_FLOPS, ServingCostModel
from repro.serve.plane import (InferencePlane, SLOSpec, admission_for,
                               greedy_decode_fn)

__all__ = [
    "ServingCostModel",
    "PEAK_FLOPS",
    "HBM_BW",
    "SLOSpec",
    "admission_for",
    "greedy_decode_fn",
    "InferencePlane",
]
