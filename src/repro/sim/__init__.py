"""Discrete-event runtime for the uBFT protocol layer.

The protocol code in ``repro.core`` is written against the abstract
``Process`` / ``Network`` interfaces defined here.  The simulator provides a
microsecond-resolution virtual clock, busy-server process semantics (a process
handles one event at a time; handler cost delays subsequent events), a
calibrated network-latency model, and hooks for failure injection and
Byzantine adversaries.
"""

from repro.sim.events import PeriodicHandle, Process, Simulator
from repro.sim.faults import FaultEvent, FaultInjector, FaultSchedule
from repro.sim.net import NetworkModel, NetParams

__all__ = ["PeriodicHandle", "Process", "Simulator", "NetworkModel",
           "NetParams", "FaultEvent", "FaultInjector", "FaultSchedule"]
