"""Discrete-event simulator with a microsecond virtual clock.

Design notes
------------
* Time is a float, in microseconds.
* Every :class:`Process` is a busy server: it handles one event at a time and
  each handler has a CPU cost; events that arrive while the process is busy
  queue behind ``busy_until``.  This is what produces realistic tail-latency
  distributions (the paper's Figs 7/11 depend on queueing effects).
* Determinism: all randomness flows through ``Simulator.rng`` (seeded); the
  event heap breaks ties with a monotonically increasing sequence number, so
  runs are exactly reproducible.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np


@dataclass(order=True)
class Event:
    time: float
    seq: int
    callback: Callable[[], None] = field(compare=False)
    note: str = field(default="", compare=False)


class Simulator:
    """Virtual-time event loop."""

    def __init__(self, seed: int = 0):
        self.now: float = 0.0
        self._heap: List[Event] = []
        self._seq = itertools.count()
        self.rng = np.random.default_rng(seed)
        self.processes: Dict[str, "Process"] = {}
        self.trace: List[tuple] = []
        self.tracing = False
        # Global stabilization: before ``gst`` the network may apply extra
        # delay (asynchrony); after it, delays are bounded (eventual synchrony).
        self.gst: float = 0.0

    # -- scheduling ------------------------------------------------------
    def at(self, time: float, callback: Callable[[], None], note: str = "") -> Event:
        ev = Event(max(time, self.now), next(self._seq), callback, note)
        heapq.heappush(self._heap, ev)
        return ev

    def after(self, delay: float, callback: Callable[[], None], note: str = "") -> Event:
        return self.at(self.now + delay, callback, note)

    # -- process registry ------------------------------------------------
    def add_process(self, proc: "Process") -> None:
        if proc.pid in self.processes:
            raise ValueError(f"duplicate pid {proc.pid}")
        self.processes[proc.pid] = proc

    # -- main loop -------------------------------------------------------
    def run(self, until: Optional[float] = None, max_events: int = 50_000_000) -> None:
        n = 0
        while self._heap:
            ev = self._heap[0]
            if until is not None and ev.time > until:
                self.now = until
                return
            heapq.heappop(self._heap)
            self.now = ev.time
            ev.callback()
            n += 1
            if n >= max_events:
                raise RuntimeError(f"simulation exceeded {max_events} events at t={self.now}")
        if until is not None:
            self.now = until

    def run_until(self, pred: Callable[[], bool], timeout: float = 10_000_000.0,
                  max_events: int = 50_000_000) -> bool:
        """Run until ``pred()`` is true.  Returns False on timeout."""
        deadline = self.now + timeout
        n = 0
        while self._heap and not pred():
            ev = self._heap[0]
            if ev.time > deadline:
                return pred()
            heapq.heappop(self._heap)
            self.now = ev.time
            ev.callback()
            n += 1
            if n >= max_events:
                raise RuntimeError(f"simulation exceeded {max_events} events at t={self.now}")
        return pred()


class Process:
    """A busy-server process on the simulator.

    Subclasses implement ``on_message(src, msg)``.  Handlers execute with a
    CPU cost (``handling_cost``); while a handler runs, later events queue.
    Crashed processes silently drop everything.  Byzantine subclasses may
    override anything — the simulator does not trust process code, only the
    crypto registry (see repro.core.crypto) prevents forgery.
    """

    #: default CPU cost of handling one message, µs (calibrated; see DESIGN §4)
    handling_cost: float = 0.15

    def __init__(self, sim: Simulator, pid: str):
        self.sim = sim
        self.pid = pid
        self.busy_until: float = 0.0
        self.crashed = False
        sim.add_process(self)

    # -- lifecycle -------------------------------------------------------
    def crash(self) -> None:
        self.crashed = True

    def recover(self) -> None:
        self.crashed = False

    # -- CPU accounting --------------------------------------------------
    def occupy(self, cost: float) -> float:
        """Claim ``cost`` µs of this process's CPU starting no earlier than
        now; returns the completion time."""
        start = max(self.sim.now, self.busy_until)
        self.busy_until = start + cost
        return self.busy_until

    def execute(self, fn: Callable[[], None], cost: Optional[float] = None,
                note: str = "") -> None:
        """Run ``fn`` on this process's CPU, honoring the busy-server model."""
        if self.crashed:
            return
        done = self.occupy(self.handling_cost if cost is None else cost)

        def _run() -> None:
            if not self.crashed:
                fn()

        self.sim.at(done, _run, note=note or f"{self.pid}.exec")

    # -- messaging entry point (called by Network) ------------------------
    def deliver(self, src: str, msg: Any, size: int) -> None:
        if self.crashed:
            return
        self.execute(lambda: self.on_message(src, msg), note=f"{self.pid}<-{src}")

    def on_message(self, src: str, msg: Any) -> None:  # pragma: no cover - abstract
        raise NotImplementedError
