"""Discrete-event simulator with a microsecond virtual clock.

Design notes
------------
* Time is a float, in microseconds.
* Every :class:`Process` is a busy server: it handles one event at a time and
  each handler has a CPU cost; events that arrive while the process is busy
  queue behind ``busy_until``.  This is what produces realistic tail-latency
  distributions (the paper's Figs 7/11 depend on queueing effects).
* Determinism: all randomness flows through ``Simulator.rng`` (seeded); the
  event heap breaks ties with a monotonically increasing sequence number, so
  runs are exactly reproducible.
* The heap holds plain ``(time, seq, callback)`` tuples — tuple comparison
  is C-level and ``seq`` is unique, so callbacks are never compared.  The
  ``note`` argument accepted by the scheduling calls is a debugging label
  and is deliberately *not* stored: labels must cost nothing when tracing
  is off, which also means call sites must not build f-strings for them on
  hot paths.
* Periodic work (lease pings, background quanta) goes through
  :meth:`Simulator.periodic`: subscribers with the same period and phase
  share ONE heap event per tick and run in registration order — exactly the
  times and ordering that per-subscriber timer chains would produce, at a
  fraction of the heap traffic (PR 2's per-pool ``LEASE_PING`` storm).
"""

from __future__ import annotations

import heapq
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np


class _PeriodicBucket:
    """All periodic subscribers sharing (period, phase): one heap event per
    tick, callbacks run in registration order.  Cancelled slots are None."""

    __slots__ = ("sim", "period", "next_fire", "cbs")

    def __init__(self, sim: "Simulator", period: float, next_fire: float):
        self.sim = sim
        self.period = period
        self.next_fire = next_fire
        self.cbs: List[Optional[Callable[[], None]]] = []

    def fire(self) -> None:
        sim = self.sim
        sim._periodic.pop((self.period, self.next_fire), None)
        cbs = [c for c in self.cbs if c is not None]
        if not cbs:
            return  # every subscriber cancelled — bucket dies
        self.cbs = cbs
        # Re-key and reschedule *before* running callbacks so a callback
        # registering a same-phase periodic joins this bucket.
        self.next_fire += self.period
        sim._periodic[(self.period, self.next_fire)] = self
        sim.at(self.next_fire, self.fire)
        for c in cbs:
            if c is not None:   # cancelled by an earlier cb this tick
                c()


class PeriodicHandle:
    """Cancellation handle returned by :meth:`Simulator.periodic`."""

    __slots__ = ("_bucket", "_cb")

    def __init__(self, bucket: _PeriodicBucket, cb: Callable[[], None]):
        self._bucket = bucket
        self._cb = cb

    def cancel(self) -> None:
        cbs = self._bucket.cbs
        for i, c in enumerate(cbs):
            if c is self._cb:
                cbs[i] = None
                return


class Simulator:
    """Virtual-time event loop."""

    def __init__(self, seed: int = 0):
        self.now: float = 0.0
        self._heap: List[Tuple[float, int, Callable[[], None]]] = []
        self._seq = 0
        self.rng = np.random.default_rng(seed)
        self.processes: Dict[str, "Process"] = {}
        self.trace: List[tuple] = []
        self.tracing = False
        #: total events executed by run()/run_until() (perf accounting)
        self.events_processed: int = 0
        self._periodic: Dict[Tuple[float, float], _PeriodicBucket] = {}
        # Global stabilization: before ``gst`` the network may apply extra
        # delay (asynchrony); after it, delays are bounded (eventual synchrony).
        self.gst: float = 0.0

    # -- scheduling ------------------------------------------------------
    def at(self, time: float, callback: Callable[[], None],
           note: str = "") -> None:
        if time < self.now:
            time = self.now
        self._seq += 1
        heapq.heappush(self._heap, (time, self._seq, callback))

    def after(self, delay: float, callback: Callable[[], None],
              note: str = "") -> None:
        # inlined at() — one call frame per event matters at this volume
        time = self.now + delay if delay > 0.0 else self.now
        self._seq += 1
        heapq.heappush(self._heap, (time, self._seq, callback))

    def periodic(self, period: float, callback: Callable[[], None]
                 ) -> PeriodicHandle:
        """Run ``callback`` every ``period`` µs, first at ``now + period``.
        Subscribers registered at the same time with the same period share
        one heap event per tick (coalescing); within a tick they run in
        registration order — identical timing to a per-subscriber timer
        chain.  Returns a handle whose ``cancel()`` stops the callback."""
        if period <= 0:
            raise ValueError("periodic() needs a positive period")
        key = (period, self.now + period)
        bucket = self._periodic.get(key)
        if bucket is None:
            bucket = _PeriodicBucket(self, period, self.now + period)
            self._periodic[key] = bucket
            self.at(bucket.next_fire, bucket.fire)
        bucket.cbs.append(callback)
        return PeriodicHandle(bucket, callback)

    # -- process registry ------------------------------------------------
    def add_process(self, proc: "Process") -> None:
        if proc.pid in self.processes:
            raise ValueError(f"duplicate pid {proc.pid}")
        self.processes[proc.pid] = proc

    # -- batch scheduling -------------------------------------------------
    def push_run(self, time: float, cbs: List[Callable[[], None]]) -> None:
        """Enqueue a contiguous same-timestamp run of callbacks as ONE heap
        entry (batch fan-out; see ``NetworkModel.send_fanout``).  The run
        shares a single sequence number and executes back-to-back in list
        order, which is exactly the ``(time, seq)`` order n individual
        pushes made in the same loop would produce: the pushes would hold
        consecutive seqs with nothing in between, so no other event can
        sort into the middle of the run."""
        if time < self.now:
            time = self.now
        self._seq += 1
        heapq.heappush(self._heap, (time, self._seq, cbs))

    # -- main loop -------------------------------------------------------
    def _drain(self, until: Optional[float], pred: Optional[Callable[[], bool]],
               max_events: int) -> None:
        """The one pop loop behind :meth:`run` and :meth:`run_until`.

        Executes events in ``(time, seq)`` order until the heap drains,
        the next event lies past ``until``, or ``pred()`` turns true
        (sampled between events, exactly like the per-event loops this
        replaced).  A heap entry whose callback slot holds a *list* is a
        coalesced run from :meth:`push_run` — its callbacks execute
        back-to-back under one heap pop, and each counts as one event."""
        heap = self._heap
        pop = heapq.heappop
        n = 0
        try:
            while heap:
                if pred is not None and pred():
                    return
                if until is not None and heap[0][0] > until:
                    return
                time, _seq, cb = pop(heap)
                self.now = time
                if cb.__class__ is list:
                    for c in cb:
                        c()
                    n += len(cb)
                else:
                    cb()
                    n += 1
                if n >= max_events:
                    raise RuntimeError(
                        f"simulation exceeded {max_events} events at t={self.now}")
        finally:
            self.events_processed += n

    def run(self, until: Optional[float] = None, max_events: int = 50_000_000) -> None:
        self._drain(until, None, max_events)
        if until is not None:
            self.now = until

    def run_until(self, pred: Callable[[], bool], timeout: float = 10_000_000.0,
                  max_events: int = 50_000_000) -> bool:
        """Run until ``pred()`` is true.  Returns False on timeout."""
        self._drain(self.now + timeout, pred, max_events)
        return pred()


class Process:
    """A busy-server process on the simulator.

    Subclasses implement ``on_message(src, msg)``.  Handlers execute with a
    CPU cost (``handling_cost``); while a handler runs, later events queue.
    Crashed processes silently drop everything.  Byzantine subclasses may
    override anything — the simulator does not trust process code, only the
    crypto registry (see repro.core.crypto) prevents forgery.
    """

    #: default CPU cost of handling one message, µs (calibrated; see DESIGN §4)
    handling_cost: float = 0.15

    def __init__(self, sim: Simulator, pid: str):
        self.sim = sim
        self.pid = pid
        self.busy_until: float = 0.0
        self.crashed = False
        sim.add_process(self)

    # -- lifecycle -------------------------------------------------------
    def crash(self) -> None:
        self.crashed = True

    def recover(self) -> None:
        self.crashed = False

    # -- CPU accounting --------------------------------------------------
    def occupy(self, cost: float) -> float:
        """Claim ``cost`` µs of this process's CPU starting no earlier than
        now; returns the completion time."""
        start = self.sim.now
        if self.busy_until > start:
            start = self.busy_until
        self.busy_until = start + cost
        return self.busy_until

    def execute(self, fn: Callable[[], None], cost: Optional[float] = None,
                note: str = "") -> None:
        """Run ``fn`` on this process's CPU, honoring the busy-server model."""
        if self.crashed:
            return
        done = self.occupy(self.handling_cost if cost is None else cost)

        def _run() -> None:
            if not self.crashed:
                fn()

        self.sim.at(done, _run)

    # -- messaging entry point (called by Network) ------------------------
    def deliver(self, src: str, msg: Any, size: int) -> None:
        # flattened execute() with occupy() and at() inlined: one closure,
        # one heap push, no intermediate frames — the per-message floor
        if self.crashed:
            return
        sim = self.sim
        start = sim.now
        if self.busy_until > start:
            start = self.busy_until
        done = start + self.handling_cost
        self.busy_until = done

        def _handle() -> None:
            if not self.crashed:
                self.on_message(src, msg)

        sim._seq += 1
        heapq.heappush(sim._heap, (done, sim._seq, _handle))

    def on_message(self, src: str, msg: Any) -> None:  # pragma: no cover - abstract
        raise NotImplementedError
