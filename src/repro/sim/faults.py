"""Deterministic fault-schedule driver: seeded (time, action, target) events.

A :class:`FaultSchedule` is a sorted list of :class:`FaultEvent`\\ s; the
:class:`FaultInjector` installs them on a simulator and applies them at
virtual time, resolving targets against the live process table, the network
model, and the memory pools:

=================  =======================================  =====================
action             target                                   effect
=================  =======================================  =====================
``crash``          process pid                              ``Process.crash()``
``recover``        process pid                              ``Process.recover()``
``partition``      ``(src, dst)`` pid pair                  drop both directions
``heal``           ``(src, dst)`` pair or ``None`` (= all)  restore link(s)
``reconfigure``    pool name / index / ``(pool, dead_pid)``  ``MemoryPool.reconfigure``
``replace_replica`` replica pid (app resolved by prefix)    ``Cluster.replace_replica``
``stale_serve``    memory-node pid or ``(pid, False)``      ``MemoryNode.set_stale_serve``
``slow_replica``   pid / ``(pid, params)`` / ``(pid, False)``  ``NetworkModel.degrade_src``
=================  =======================================  =====================

``slow_replica`` is the *gray* failure mode: the target stays up (keeps
receiving, keeps its state) but every message it sends pays an extra delay
and/or a seeded fraction is silently lost — exactly the partial failure a
clean crash schedule cannot express, and the one the self-healing
suspicion layer (``core/health.py``) exists to catch.

Everything is driven by one seeded RNG, so a schedule is exactly
reproducible from ``(seed, horizon, targets)`` — the property the
fault-matrix tests and the ``benchmarks/fault_scenarios.py`` sweep rely on.
:meth:`FaultSchedule.seeded` generates *sensible* adversaries: it never
crashes more than the supplied fault budgets, recovers or reconfigures what
it crashed, and always heals what it partitions.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Iterable, List, Optional, Sequence, Tuple

import numpy as np

ACTIONS = ("crash", "recover", "partition", "heal", "reconfigure",
           "replace_replica", "stale_serve", "reshard", "slow_replica")


@dataclass(frozen=True)
class FaultEvent:
    time: float
    action: str
    target: Any = None

    def __post_init__(self):
        if self.action not in ACTIONS:
            raise ValueError(f"unknown fault action {self.action!r}")


def _ev_key(ev: FaultEvent) -> Tuple[float, str]:
    return (ev.time, ev.action)


class FaultSchedule:
    """An ordered, deterministic list of fault events."""

    def __init__(self, events: Iterable[FaultEvent] = (),
                 seed: Optional[int] = None):
        self.events: List[FaultEvent] = sorted(events, key=_ev_key)
        self.seed = seed

    def __iter__(self):
        return iter(self.events)

    def __len__(self) -> int:
        return len(self.events)

    def add(self, time: float, action: str, target: Any = None
            ) -> "FaultSchedule":
        self.events.append(FaultEvent(time, action, target))
        self.events.sort(key=_ev_key)
        return self

    @classmethod
    def seeded(cls, seed: int, *, horizon_us: float,
               memory: Sequence[str] = (), pools: Sequence[Any] = (),
               replicas: Sequence[str] = (),
               partitions: Sequence[Tuple[str, str]] = (),
               n_memory_crashes: int = 1, n_replica_crashes: int = 0,
               n_partitions: int = 0, reconfigure: bool = False,
               recover: bool = True, replace_replicas: bool = False,
               stale_serve: Sequence[str] = (),
               n_slow_replicas: int = 0,
               slow_params: Optional[dict] = None,
               slow_recover: bool = False) -> "FaultSchedule":
        """Generate a deterministic schedule inside ``(0.1, 0.8)·horizon``.

        ``memory`` lists crash-eligible memory-node pids (pass at most f_m
        per pool to stay within the fault budget); ``replicas`` likewise
        (at most f).  ``reconfigure`` replaces each crashed memory node via
        its pool (resolved by pid prefix) instead of recovering it.
        ``replace_replicas`` follows each replica crash with a
        ``replace_replica`` event (membership-epoch repair under load).
        ``partitions`` lists candidate pid pairs for ``n_partitions``
        partition+heal episodes.  ``stale_serve`` lists memory-node pids
        that turn into stale-serving Byzantine memory (enabled at a seeded
        time, never disabled — keep it within f_m per pool).

        ``n_slow_replicas`` gray-degrades that many replicas (drawn from
        ``replicas``) at seeded times: delay and drop fraction are drawn
        per target unless pinned via ``slow_params`` (the degradation's
        own drop RNG is always seeded from this schedule's stream, so the
        whole gray episode is a pure function of the seed).
        ``slow_recover`` follows each degradation with a clearing event —
        leave it False when a self-healing control plane is expected to
        replace the sick replica instead.
        """
        rng = np.random.default_rng(seed)
        ev: List[FaultEvent] = []

        def t(lo: float = 0.1, hi: float = 0.8) -> float:
            return float(rng.uniform(lo * horizon_us, hi * horizon_us))

        mem = list(memory)
        for pid in list(rng.permutation(mem))[:n_memory_crashes]:
            t0 = t()
            ev.append(FaultEvent(t0, "crash", str(pid)))
            if reconfigure:
                pool = _pool_of(str(pid), pools)
                ev.append(FaultEvent(t0 + t(0.05, 0.15), "reconfigure",
                                     (pool, str(pid))))
            elif recover:
                ev.append(FaultEvent(t0 + t(0.05, 0.15), "recover", str(pid)))
        for pid in list(rng.permutation(list(replicas)))[:n_replica_crashes]:
            t0 = t()
            ev.append(FaultEvent(t0, "crash", str(pid)))
            if replace_replicas:
                ev.append(FaultEvent(t0 + t(0.05, 0.15), "replace_replica",
                                     str(pid)))
        pairs = list(partitions)
        for i in list(rng.permutation(len(pairs)))[:n_partitions]:
            a, b = pairs[int(i)]
            t0 = t()
            ev.append(FaultEvent(t0, "partition", (a, b)))
            ev.append(FaultEvent(t0 + t(0.05, 0.15), "heal", (a, b)))
        for pid in stale_serve:
            ev.append(FaultEvent(t(), "stale_serve", str(pid)))
        if n_slow_replicas:
            for pid in list(rng.permutation(list(replicas)))[:n_slow_replicas]:
                t0 = t()
                params = dict(slow_params) if slow_params else {
                    "delay_us": float(rng.uniform(300.0, 2000.0)),
                    "drop": float(rng.uniform(0.1, 0.6)),
                }
                params.setdefault("seed", int(rng.integers(2 ** 31)))
                ev.append(FaultEvent(t0, "slow_replica", (str(pid), params)))
                if slow_recover:
                    ev.append(FaultEvent(t0 + t(0.05, 0.15), "slow_replica",
                                         (str(pid), False)))
        return cls(ev, seed=seed)


def _pool_of(pid: str, pools: Sequence[Any]):
    """Pool name owning ``pid``, or None — the injector then resolves the
    pool by the dead pid at apply time (no silent pool-0 fallback)."""
    for p in pools:
        if pid in getattr(p, "members", ()) or pid in getattr(p, "nodes", ()):
            return getattr(p, "name", p)
    return None


class FaultInjector:
    """Applies a :class:`FaultSchedule` to a simulator.

    ``pools`` enables ``reconfigure`` targets and lets ``crash``/``recover``
    hit replacement nodes that only exist inside a pool.  Every applied
    event is recorded in ``log`` as ``(time, action, target)``; events that
    turn out to be no-ops (e.g. a ``reconfigure`` racing a lease-driven one)
    land in ``skipped`` instead, so tests asserting on ``log`` never count
    a fault that did not actually happen.
    """

    def __init__(self, sim, net, pools: Sequence[Any] = (),
                 clusters: Optional[dict] = None,
                 services: Optional[dict] = None):
        self.sim = sim
        self.net = net
        self.pools = list(pools)
        #: app name -> Cluster, for ``replace_replica`` targets (the pid's
        #: ``app/`` prefix selects the cluster; "" is the unnamed app)
        self.clusters = dict(clusters or {})
        #: service name -> ShardedService, for ``reshard`` targets
        self.services = dict(services or {})
        self.log: List[Tuple[float, str, Any]] = []
        self.skipped: List[Tuple[float, str, Any]] = []

    @classmethod
    def for_cluster(cls, cluster, schedule: Optional[FaultSchedule] = None
                    ) -> "FaultInjector":
        inj = cls(cluster.sim, cluster.net, getattr(cluster, "pools", ()),
                  clusters={getattr(cluster, "name", ""): cluster})
        if schedule is not None:
            inj.install(schedule)
        return inj

    def install(self, schedule: FaultSchedule) -> "FaultInjector":
        for ev in schedule:
            self.sim.at(ev.time, lambda ev=ev: self.apply(ev),
                        note=f"fault.{ev.action}")
        return self

    # ------------------------------------------------------------ applying
    def apply(self, ev: FaultEvent) -> None:
        applied = getattr(self, f"_do_{ev.action}")(ev.target)
        rec = (self.sim.now, ev.action, ev.target)
        (self.skipped if applied is False else self.log).append(rec)

    def _process(self, pid: str):
        proc = self.sim.processes.get(pid)
        if proc is None:
            raise KeyError(f"fault target {pid!r} is not a live process")
        return proc

    def _do_crash(self, pid: str) -> None:
        self._process(pid).crash()

    def _do_recover(self, pid: str) -> None:
        self._process(pid).recover()

    def _do_partition(self, target: Tuple[str, str]) -> None:
        a, b = target
        self.net.partition(a, b, forced=True)
        self.net.partition(b, a, forced=True)

    def _do_heal(self, target: Optional[Tuple[str, str]]) -> None:
        if target is None:
            self.net.heal()
            return
        a, b = target
        self.net.heal_link(a, b)
        self.net.heal_link(b, a)

    def _resolve_pool(self, ref: Any, dead: Optional[str]):
        if isinstance(ref, int):
            return self.pools[ref]
        for p in self.pools:
            if getattr(p, "name", None) == ref or p is ref:
                return p
        if ref is None and dead is not None:
            for p in self.pools:
                if dead in getattr(p, "members", ()):
                    return p
        if ref is None and len(self.pools) == 1:
            return self.pools[0]
        raise KeyError(f"cannot resolve pool {ref!r} (dead={dead!r})")

    def _do_reconfigure(self, target: Any) -> bool:
        dead = None
        if isinstance(target, tuple):
            target, dead = target
        pool = self._resolve_pool(target, dead)
        return pool.reconfigure(dead)

    def _do_replace_replica(self, target: Any) -> bool:
        """Replace a replica: target is its pid (``A/r0`` resolves app
        ``A``; bare ``r0`` the unnamed app), or ``(app, pid)``."""
        if isinstance(target, tuple):
            app, pid = target
        else:
            pid = target
            app = pid.rsplit("/", 1)[0] if "/" in pid else ""
        cluster = self.clusters.get(app)
        if cluster is None:
            raise KeyError(f"no cluster {app!r} for replace_replica target "
                           f"{target!r}")
        return cluster.replace_replica(pid) is not None

    def _do_stale_serve(self, target: Any) -> bool:
        """Byzantine memory-side adversary: the node starts serving stale
        (old-but-well-formed) blobs.  ``(pid, False)`` switches it back."""
        on = True
        if isinstance(target, tuple):
            target, on = target
        node = self.sim.processes.get(target)
        if node is None:
            for p in self.pools:
                node = getattr(p, "nodes", {}).get(target)
                if node is not None:
                    break
        if node is None or not hasattr(node, "set_stale_serve"):
            raise KeyError(f"stale_serve target {target!r} is not a "
                           f"memory node")
        if bool(node.stale_serve) == bool(on):
            return False
        node.set_stale_serve(on)
        return True

    def _do_slow_replica(self, target: Any) -> bool:
        """Gray failure: degrade every send *from* a replica that stays up.
        ``pid`` or ``(pid, {"delay_us":…, "drop":…, "seed":…})`` enables
        (dict keys optional); ``(pid, False)`` clears.  Re-degrading an
        already-degraded pid (or clearing a healthy one) is skipped."""
        params: Optional[dict] = None
        on = True
        if isinstance(target, tuple):
            pid, arg = target
            if arg is False:
                on = False
            elif isinstance(arg, dict):
                params = arg
            elif arg is not None and arg is not True:
                raise ValueError(f"bad slow_replica arg {arg!r} — expected "
                                 f"a params dict or False")
        else:
            pid = target
        if not on:
            if pid not in self.net.degraded:
                return False
            self.net.clear_degrade(pid)
            return True
        if pid in self.net.degraded:
            return False
        p = {"delay_us": 500.0, "drop": 0.3, "seed": 1}
        p.update(params or {})
        self.net.degrade_src(pid, **p)
        return True

    def _do_reshard(self, target: Any) -> bool:
        """Live shard split/merge on a sharded service (the shard count
        becomes a mid-run variable, like any other fault-schedule event):
        ``(service, "split", idx)`` or ``(service, "merge", src, dst)``.
        The operation is *initiated* here and completes asynchronously —
        watch ``service.reshards``.  Skipped (returns False) when another
        reshard is still in flight."""
        name, kind = target[0], target[1]
        svc = self.services.get(name)
        if svc is None:
            raise KeyError(f"no sharded service {name!r} for reshard "
                           f"target {target!r}")
        if svc.resharding:
            return False
        if kind == "split":
            svc.split_shard(target[2])
        elif kind == "merge":
            svc.merge_shards(target[2], target[3])
        else:
            raise ValueError(f"unknown reshard kind {kind!r}")
        return True
