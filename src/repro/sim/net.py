"""Calibrated network + CPU cost model (DESIGN.md §4).

All constants live in :class:`NetParams` so the calibration is in one place.
The model is calibrated so that the *unreplicated* RPC and Mu baselines land
on the paper's measurements (Fig 8); uBFT / MinBFT / SGX numbers are then
*predicted* by protocol structure, which is the reproduction claim.

Message size accounting: every protocol message computes its wire size from
its payload (see ``repro.core.crypto.wire_size_cached`` — sizes of shared
payload subtrees are memoized); batched payloads (tuples of request tuples)
are priced recursively, so a PREPARE carrying a batch pays for every request
it coalesces; latency = ``base + size * per_byte`` plus a small lognormal
jitter, plus unbounded extra delay before GST if asynchrony injection is
enabled.

Jitter draws are pre-drawn in vectorized numpy blocks from the simulator's
seeded RNG.  Filling an array consumes the PCG64 bitstream exactly like the
equivalent sequence of scalar draws, so per-hop jitter values are
bit-identical to the scalar-draw implementation — provided every consumer
pulls from the *same* stream in call order, which is why the Mu baseline's
leader also draws through :meth:`NetworkModel.jitter`.
"""

from __future__ import annotations

import heapq
import random
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

from repro.sim.events import Process, Simulator


@dataclass
class NetParams:
    # One-way RDMA-write-style message: base latency (µs) and per-byte cost
    # (µs/byte).  0.9 µs + 1.1 ns/B reproduces: 32 B hop ≈ 0.94 µs (unrepl.
    # RPC 2.2 µs incl. handling), 8 KiB hop ≈ 9.9 µs (unrepl. RPC ≈ 20 µs).
    base_us: float = 0.9
    per_byte_us: float = 0.0011
    # Lognormal jitter on each hop (multiplicative, mean≈1).
    jitter_sigma: float = 0.08
    # Known post-GST delay bound δ (µs) — used by the register δ-cooldown.
    delta_us: float = 10.0
    # Crypto cost model (µs) — DESIGN.md §4, backed out of Fig 9/10.
    sign_us: float = 15.0
    verify_us: float = 30.0
    hmac_us: float = 0.1
    checksum_per_byte_us: float = 0.0001  # xxHash ≈ 10 GB/s
    crypto_dispatch_us: float = 3.0       # thread-pool dispatch+sync
    # SGX baseline: cost of one enclave access (paper: 7–12.5 µs).
    enclave_access_us: float = 8.0
    # Bookkeeping signatures (CTBcast summaries, checkpoints) run in a
    # BACKGROUND task (§3: "relegating the few bookkeeping signatures to a
    # background task") that wakes on a scheduling quantum:
    bg_quantum_us: float = 75.0
    # Disaggregated-memory node service time per READ/WRITE (µs).
    memnode_service_us: float = 0.3


class NetworkModel:
    """Point-to-point message fabric with per-link asynchrony hooks."""

    #: jitter factors pre-drawn per refill (vectorized; see module docstring)
    JITTER_BLOCK = 4096

    def __init__(self, sim: Simulator, params: Optional[NetParams] = None):
        self.sim = sim
        self.p = params or NetParams()
        # (src, dst) -> extra one-way delay in µs (adversarial asynchrony /
        # partition modeling; applied only before sim.gst unless forced).
        self.link_delay: Dict[Tuple[str, str], float] = {}
        self.partitioned: set = set()
        # Forced partitions drop regardless of GST (fault-schedule driver:
        # an operator-visible network fault, not pre-GST asynchrony).
        self.forced: set = set()
        # Gray failure (``slow_replica`` fault): a degraded *source* stays
        # up but every send pays an extra delay and/or loses a seeded
        # fraction.  Applied regardless of GST (a sick NIC, not pre-GST
        # asynchrony).  Drop draws come from a dedicated per-entry RNG —
        # never the simulator's jitter stream, so enabling a degradation
        # cannot perturb the jitter draws of unaffected traffic.
        self.degraded: Dict[str, Tuple[float, float, random.Random]] = {}
        self.bytes_sent: int = 0
        self.msgs_sent: int = 0
        #: messages that went through :meth:`send_fanout`
        self.fanout_msgs: int = 0
        #: same-timestamp delivery runs enqueued as one heap entry
        self.coalesced_runs: int = 0
        self._jitter_buf = None
        self._jitter_idx = 0
        self._jitter_sigma = None   # sigma the buffer was drawn with

    # -- latency model ----------------------------------------------------
    def jitter(self) -> float:
        """Next multiplicative jitter factor (lognormal, mean≈1) from the
        pre-drawn block.  Blocks refill deterministically from the seeded
        RNG (vectorized fills consume the bitstream exactly like scalar
        draws); a mid-run ``jitter_sigma`` change discards the stale
        block.  The block lives as a plain Python list — scalar indexing
        into a numpy array costs more than the draw itself."""
        i = self._jitter_idx
        buf = self._jitter_buf
        sigma = self.p.jitter_sigma
        if buf is None or i >= len(buf) or sigma != self._jitter_sigma:
            buf = self._jitter_buf = self.sim.rng.lognormal(
                mean=0.0, sigma=sigma, size=self.JITTER_BLOCK).tolist()
            self._jitter_sigma = sigma
            i = 0
        self._jitter_idx = i + 1
        return buf[i]

    def latency(self, src: str, dst: str, size: int) -> float:
        lat = self.p.base_us + size * self.p.per_byte_us
        if self.p.jitter_sigma > 0:
            lat *= self.jitter()
        if self.link_delay:
            extra = self.link_delay.get((src, dst), 0.0)
            if extra and self.sim.now < self.sim.gst:
                lat += extra
        return lat

    # -- send --------------------------------------------------------------
    def send(self, src: str, dst: str, msg: Any, size: int,
             deliver: Optional[Callable[[], None]] = None) -> None:
        """One-way message.  If ``deliver`` is given it is invoked at arrival
        time instead of the default ``Process.deliver`` (used by the circular
        buffer primitive to model slot overwrites)."""
        if (self.forced or self.partitioned) and (
                (src, dst) in self.forced or (
                    (src, dst) in self.partitioned and
                    self.sim.now < self.sim.gst)):
            return  # dropped; retransmission layers must cope
        deg = None
        if self.degraded:
            deg = self.degraded.get(src)
            if deg is not None and deg[1] and deg[2].random() < deg[1]:
                return  # gray failure: the sender's NIC lost it
        self.bytes_sent += size
        self.msgs_sent += 1
        # inlined latency(): base + per-byte, jittered from the pre-drawn
        # block — one call frame per message matters at this volume
        p = self.p
        lat = p.base_us + size * p.per_byte_us
        if p.jitter_sigma > 0:
            i = self._jitter_idx
            buf = self._jitter_buf
            if buf is None or i >= len(buf) or \
                    p.jitter_sigma != self._jitter_sigma:
                lat *= self.jitter()
            else:
                self._jitter_idx = i + 1
                lat *= buf[i]
        sim = self.sim
        if self.link_delay:
            extra = self.link_delay.get((src, dst), 0.0)
            if extra and sim.now < sim.gst:
                lat += extra
        if deg is not None:
            lat += deg[0]

        if deliver is not None:
            sim.after(lat, deliver)
            return

        procs = sim.processes
        proc = procs.get(dst)
        if proc is None or proc.crashed:
            return

        def _arrive() -> None:
            p = procs.get(dst)
            if p is not None:
                p.deliver(src, msg, size)

        # inlined sim.after() — one call frame per message matters here
        sim._seq += 1
        heapq.heappush(sim._heap, (sim.now + lat, sim._seq, _arrive))

    def send_fanout(self, src: str, dsts: Any, msg: Any, size: int) -> None:
        """Fan ONE encoded message to many peers in one call.

        Equivalent to ``for dst in dsts: send(src, dst, msg, size)`` —
        bit-identical, because jitter factors are drawn per destination in
        ``dsts`` order from the same pre-drawn block — but the guard
        checks, accounting, and base-latency math are hoisted out of the
        loop.  Whenever the fabric has *any* per-link state (partitions,
        forced drops, degradations, link delays) it falls back to the
        scalar path, which short-circuits drops before drawing jitter.

        When the per-hop latency is fully deterministic (``jitter_sigma ==
        0``), all n deliveries land on the same timestamp and are enqueued
        as one coalesced heap run (``Simulator.push_run``), preserving
        ``(time, seq)`` execution order exactly (the n individual pushes
        would have held consecutive seqs)."""
        if self.forced or self.partitioned or self.degraded or self.link_delay:
            for dst in dsts:
                self.send(src, dst, msg, size)
            return
        ndst = len(dsts)
        self.bytes_sent += size * ndst
        self.msgs_sent += ndst
        self.fanout_msgs += ndst
        p = self.p
        base = p.base_us + size * p.per_byte_us
        sim = self.sim
        now = sim.now
        procs = sim.processes
        heap = sim._heap
        sigma = p.jitter_sigma

        if sigma > 0:
            buf = self._jitter_buf
            i = self._jitter_idx
            for dst in dsts:
                if buf is None or i >= len(buf) or \
                        sigma != self._jitter_sigma:
                    self._jitter_idx = i
                    lat = base * self.jitter()
                    buf = self._jitter_buf
                    i = self._jitter_idx
                else:
                    lat = base * buf[i]
                    i += 1
                proc = procs.get(dst)
                if proc is None or proc.crashed:
                    continue

                def _arrive(dst: str = dst) -> None:
                    pr = procs.get(dst)
                    if pr is not None:
                        pr.deliver(src, msg, size)

                sim._seq += 1
                heapq.heappush(heap, (now + lat, sim._seq, _arrive))
            self._jitter_idx = i
            return

        # deterministic latency: every delivery shares one timestamp
        run = []
        append = run.append
        for dst in dsts:
            proc = procs.get(dst)
            if proc is None or proc.crashed:
                continue

            def _arrive(dst: str = dst) -> None:
                pr = procs.get(dst)
                if pr is not None:
                    pr.deliver(src, msg, size)

            append(_arrive)
        if not run:
            return
        if len(run) == 1:
            sim._seq += 1
            heapq.heappush(heap, (now + base, sim._seq, run[0]))
            return
        self.coalesced_runs += 1
        sim.push_run(now + base, run)

    # -- asynchrony / failure injection ------------------------------------
    def degrade_src(self, pid: str, delay_us: float = 0.0,
                    drop: float = 0.0, seed: int = 0) -> None:
        """Gray-degrade every send *from* ``pid``: add ``delay_us`` to its
        one-way latency and drop a ``drop`` fraction (seeded, deterministic,
        independent of the jitter stream).  Applies regardless of GST."""
        if not 0.0 <= drop < 1.0:
            raise ValueError(f"drop fraction must be in [0, 1): {drop!r}")
        self.degraded[pid] = (float(delay_us), float(drop),
                              random.Random(seed))

    def clear_degrade(self, pid: str) -> None:
        self.degraded.pop(pid, None)

    def delay_link(self, src: str, dst: str, extra_us: float) -> None:
        self.link_delay[(src, dst)] = extra_us

    def partition(self, src: str, dst: str, forced: bool = False) -> None:
        self.partitioned.add((src, dst))
        if forced:
            self.forced.add((src, dst))

    def heal_link(self, src: str, dst: str) -> None:
        self.partitioned.discard((src, dst))
        self.forced.discard((src, dst))
        self.link_delay.pop((src, dst), None)

    def heal(self) -> None:
        self.partitioned.clear()
        self.forced.clear()
        self.link_delay.clear()
