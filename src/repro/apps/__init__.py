"""Replicated applications (§7: Flip, Memcached/Redis-style KV, Liquibook)."""

from repro.apps.flip import FlipApp
from repro.apps.kvstore import KVStoreApp
from repro.apps.matching import MatchingEngineApp

__all__ = ["FlipApp", "KVStoreApp", "MatchingEngineApp"]
