"""Flip — the paper's toy application: reverses its input (§7.1)."""

from __future__ import annotations

from repro.core.consensus import App


class FlipApp(App):
    def __init__(self) -> None:
        self.count = 0

    def apply(self, req: bytes) -> bytes:
        self.count += 1
        return req[::-1]

    def snapshot(self):
        return self.count

    def adopt(self, snap) -> None:
        self.count = snap
