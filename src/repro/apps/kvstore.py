"""In-memory KV store — the Memcached/Redis analog of §7.1.

Request wire format (binary, matching the paper's 16 B keys / 32 B values):
    b"G" + key            -> GET
    b"S" + klen(1) + key + value -> SET
    b"M" + n(1) + n × (klen(1) + key + vlen(1) + value) -> MSET (multi-put)
Responses: value bytes (b"" on miss) or b"OK".

Every length field is one byte, so the encoders *raise* on anything that
cannot be framed (``>255`` pairs, keys or values) instead of silently
truncating, and :meth:`KVStoreApp.apply` answers a deterministic ``b"ERR"``
on any payload whose declared lengths disagree with its actual bytes — a
malformed request must never mis-parse into a different (but valid-looking)
operation, because every honest replica must produce the *same* reply.

:class:`ShardKVApp` extends the store with the participant/coordinator
state of cross-shard two-phase commit (``repro/service/``): PREPARE locks
keys and records a pending intent, DECIDE records the transaction outcome
exactly once on the coordinator shard's log, FINISH applies-or-discards the
intent.  Each of those is an ordinary consensus request — *each 2PC phase
is itself a BFT-committed slot* (see DESIGN_SHARDING.md):

    b"P" + txid(20) + deadline_us(<Q) + coord(<H) + n(1) + pairs -> TPREP
    b"D" + txid(20) + outcome(1: C|A)                            -> TDECIDE
    b"F" + txid(20) + outcome(1: C|A)                            -> TFINISH
    b"R" + txid(20) + outcome(1) + n(1) + n × (plen(1)+pid+sig(64))
                                             -> recovery TFINISH + outcome
                                                certificate (f+1 coordinator
                                                replica signatures; verified
                                                at the consensus layer's svc
                                                endorsement gate, not here)
    b"O" + txid(20)                                              -> outcome?

A txid is ``owner_tag(8) || seq(<I) || nonce(<Q)``: the tag binds the
transaction to the submitting client (sha256 of its pid — collision-free
where the old crc32 salt was not), the seq separates one client's
transactions, and the nonce makes the txid unguessable to other clients.
The coordinator's DECIDE record enforces the binding: a commit outcome is
only ever recorded when proposed by the txid's owner (authenticated caller
via :meth:`~repro.core.consensus.App.apply_from`); abort stays open to
anyone so replica recovery probes can presume-abort abandoned
transactions.
"""

from __future__ import annotations

import hashlib
import struct
import zlib
from typing import Dict, List, Tuple

from repro.core.consensus import App

#: one-byte length fields frame every key/value/pair-count on the wire
MAX_LEN = 255

#: owner tag (8) + per-client seq (4) + unguessable nonce (8)
TXID_LEN = 20

VOTE_OK = b"VOTE_OK"
VOTE_CONFLICT = b"VOTE_CONFLICT"


def tx_owner_tag(pid: str) -> bytes:
    """The 8-byte owner component of a txid: a collision-resistant digest
    of the submitting client's pid.  Forging a commit-DECIDE for another
    client's transaction would need a second preimage of its tag under an
    authenticated caller identity — out of the model's reach."""
    return hashlib.sha256(pid.encode()).digest()[:8]


def make_txid(pid: str, seq: int, nonce: int) -> bytes:
    """``owner_tag || seq || nonce``.  ``nonce`` models a draw from the
    client's CSPRNG: unpredictable to every other client, so in-flight
    transactions cannot even be *named* (let alone decided) by a
    Byzantine client that merely guesses txids."""
    return tx_owner_tag(pid) + struct.pack("<IQ", seq & 0xFFFFFFFF, nonce)


def get_req(key: bytes) -> bytes:
    return b"G" + key


def set_req(key: bytes, value: bytes) -> bytes:
    if len(key) > MAX_LEN:
        raise ValueError(f"key of {len(key)} B does not fit the 1-byte "
                         f"length field (max {MAX_LEN})")
    return b"S" + bytes([len(key)]) + key + value


def mset_req(pairs: List[Tuple[bytes, bytes]]) -> bytes:
    """One request carrying several puts — application-level batching that
    composes with the consensus layer's slot batching."""
    return b"M" + _encode_pairs(pairs)


def _encode_pairs(pairs: List[Tuple[bytes, bytes]]) -> bytes:
    if len(pairs) > MAX_LEN:
        raise ValueError(f"{len(pairs)} pairs do not fit the 1-byte count "
                         f"field (max {MAX_LEN})")
    out = bytes([len(pairs)])
    for k, v in pairs:
        if len(k) > MAX_LEN or len(v) > MAX_LEN:
            raise ValueError(f"key/value of {len(k)}/{len(v)} B does not "
                             f"fit the 1-byte length field (max {MAX_LEN})")
        out += bytes([len(k)]) + k + bytes([len(v)]) + v
    return out


def _decode_pairs(req: bytes, off: int):
    """Parse ``n(1) + n × (klen+key+vlen+value)`` fully before anything is
    applied; returns ``None`` on any length mismatch (deterministic ERR at
    the caller) so a truncated payload can never half-apply."""
    if off >= len(req):
        return None
    n = req[off]
    off += 1
    pairs = []
    for _ in range(n):
        if off >= len(req):
            return None
        klen = req[off]
        key = req[off + 1:off + 1 + klen]
        off += 1 + klen
        if len(key) != klen or off >= len(req):
            return None
        vlen = req[off]
        value = req[off + 1:off + 1 + vlen]
        off += 1 + vlen
        if len(value) != vlen:
            return None
        pairs.append((key, value))
    if off != len(req):
        return None
    return pairs


class KVStoreApp(App):
    def __init__(self) -> None:
        self.store: Dict[bytes, bytes] = {}

    def apply(self, req: bytes) -> bytes:
        op = req[:1]
        if op == b"G":
            return self.store.get(req[1:], b"")
        if op == b"S":
            if len(req) < 2:
                return b"ERR"
            klen = req[1]
            key = req[2:2 + klen]
            if len(key) != klen:
                return b"ERR"   # declared length overruns the payload
            value = req[2 + klen:]
            self.store[key] = value
            return b"OK"
        if op == b"M":
            # parse the whole payload before touching the store: a
            # malformed/truncated request is rejected atomically
            pairs = _decode_pairs(req, 1)
            if pairs is None:
                return b"ERR"
            for key, value in pairs:
                self.store[key] = value
            return b"OK"
        return b"ERR"

    def snapshot(self):
        return tuple(sorted(self.store.items()))

    def adopt(self, snap) -> None:
        self.store = dict(snap)


# --------------------------------------------------------------------------
# Sharded-service participant: 2PC state behind the same App interface
# --------------------------------------------------------------------------
_TPREP_HDR = struct.Struct("<QH")   # deadline_us, coordinator shard index


def tprep_req(txid: bytes, deadline_us: float, coord_shard: int,
              pairs: List[Tuple[bytes, bytes]]) -> bytes:
    """PREPARE this shard's slice of a cross-shard transaction: lock the
    keys, record the intent, vote.  ``deadline_us`` (absolute sim time) is
    consumed by the *replica-layer* recovery timers, never by apply()."""
    assert len(txid) == TXID_LEN
    return (b"P" + txid + _TPREP_HDR.pack(int(deadline_us), coord_shard) +
            _encode_pairs(pairs))


def tdecide_req(txid: bytes, outcome: bytes) -> bytes:
    """Record the transaction outcome on the coordinator shard (exactly
    once: the first DECIDE in its log wins; later ones read it back)."""
    assert outcome in (b"C", b"A") and len(txid) == TXID_LEN
    return b"D" + txid + outcome


def tfinish_req(txid: bytes, outcome: bytes) -> bytes:
    """Apply (C) or discard (A) the pending intent and release its locks."""
    assert outcome in (b"C", b"A") and len(txid) == TXID_LEN
    return b"F" + txid + outcome


def toutcome_req(txid: bytes) -> bytes:
    """Read the recorded outcome (b"OUT"+o, or b"NONE")."""
    assert len(txid) == TXID_LEN
    return b"O" + txid


#: wire size of one signature in an outcome certificate (Ed25519)
SIG_LEN = 64


def rfinish_req(txid: bytes, outcome: bytes,
                cert: Tuple[Tuple[str, bytes], ...]) -> bytes:
    """A *recovery* FINISH: semantically TFINISH, but carrying the f+1
    coordinator-shard signatures over ``("txout", txid, outcome)`` that
    prove the outcome against the coordinator's replicated record.  The
    certificate is what lets every honest replica endorse the slot
    immediately (no local probe state needed) while refusing a Byzantine
    leader's forged outcome."""
    assert outcome in (b"C", b"A") and len(txid) == TXID_LEN
    assert len(cert) <= MAX_LEN
    blob = bytes([len(cert)])
    for pid, sig in cert:
        p = pid.encode()
        assert len(p) <= MAX_LEN and len(sig) == SIG_LEN
        blob += bytes([len(p)]) + p + sig
    return b"R" + txid + outcome + blob


def parse_rfinish(req: bytes):
    """(txid, outcome, ((pid, sig), ...)) of a recovery FINISH, or None."""
    if req[:1] != b"R" or len(req) < 3 + TXID_LEN:
        return None
    txid, outcome = req[1:1 + TXID_LEN], req[1 + TXID_LEN:2 + TXID_LEN]
    if outcome not in (b"C", b"A"):
        return None
    off = 2 + TXID_LEN
    n = req[off]
    off += 1
    cert = []
    for _ in range(n):
        if off >= len(req):
            return None
        plen = req[off]
        pid = req[off + 1:off + 1 + plen]
        off += 1 + plen
        if len(pid) != plen:
            return None
        sig = req[off:off + SIG_LEN]
        off += SIG_LEN
        if len(sig) != SIG_LEN:
            return None
        cert.append((pid.decode(), sig))
    if off != len(req):
        return None
    return txid, outcome, tuple(cert)


def parse_tprep(req: bytes):
    """(txid, deadline_us, coord_shard, pairs) of a TPREP, or None."""
    if req[:1] != b"P" or len(req) < 1 + TXID_LEN + _TPREP_HDR.size:
        return None
    txid = req[1:1 + TXID_LEN]
    deadline, coord = _TPREP_HDR.unpack_from(req, 1 + TXID_LEN)
    pairs = _decode_pairs(req, 1 + TXID_LEN + _TPREP_HDR.size)
    if pairs is None:
        return None
    return txid, float(deadline), coord, pairs


# --------------------------------------------------------------------------
# Keyrange handoff (shard split/merge) — ISSUE 7
# --------------------------------------------------------------------------
#: range header: modulus(4) + residue(4) + target shard(2) + router epoch(4)
_RANGE_HDR = struct.Struct("<IIHI")
_RANGE_KEY = struct.Struct("<II")      # modulus + residue
_RB_LEN = struct.Struct("<H")          # 2-byte framing for range blobs


def key_in_range(key: bytes, mod: int, res: int) -> bool:
    """Does ``key`` fall in the (modulus, residue) crc32 range?  The same
    function the router's table lookup applies — replicas and routers must
    agree bit-for-bit on range membership."""
    return zlib.crc32(key) % mod == res


def freeze_req(mod: int, res: int, target: int, repoch: int) -> bytes:
    """FREEZE a key range ahead of its handoff: from this slot on, writes
    and new PREPAREs touching the range bounce deterministically
    (``b"FROZEN"`` / VOTE_CONFLICT) while reads keep being served — the
    range's data has not moved yet."""
    return b"B" + _RANGE_HDR.pack(mod, res, target, repoch)


def capture_req(mod: int, res: int) -> bytes:
    """CAPTURE a frozen, drained range: record the range's store pairs as
    an outbound snapshot at this exact log position (identical on every
    replica — it feeds the transfer fingerprint)."""
    return b"T" + _RANGE_KEY.pack(mod, res)


def _encode_cert(cert: Tuple[Tuple[str, bytes], ...]) -> bytes:
    """(pid, sig) entries, 1-byte-count framed — the certificate format
    shared by recovery FINISH, range ADOPT and range CUT slots."""
    assert len(cert) <= MAX_LEN
    out = bytes([len(cert)])
    for pid, sig in cert:
        p = pid.encode()
        assert len(p) <= MAX_LEN and len(sig) == SIG_LEN
        out += bytes([len(p)]) + p + sig
    return out


def _parse_cert(req: bytes, off: int):
    """(((pid, sig), ...), next_off) or None on any length mismatch."""
    if off >= len(req):
        return None
    n = req[off]
    off += 1
    cert = []
    for _ in range(n):
        if off >= len(req):
            return None
        plen = req[off]
        pid = req[off + 1:off + 1 + plen]
        off += 1 + plen
        if len(pid) != plen:
            return None
        sig = req[off:off + SIG_LEN]
        off += SIG_LEN
        if len(sig) != SIG_LEN:
            return None
        cert.append((pid.decode(), sig))
    return tuple(cert), off


def cut_req(mod: int, res: int, target: int, repoch: int,
            cert: Tuple[Tuple[str, bytes], ...] = ()) -> bytes:
    """CUT a transferred range: drop its keys from the store, record the
    handoff (subsequent ops answer ``b"MOVED"+target``), and commit the
    router epoch bump into this shard's log.  ``cert`` carries f+1
    target-shard signatures over ``("adopted", mod, res, repoch)`` —
    checked at the svc endorsement gate, so a Byzantine leader cannot
    delete a range that no shard has adopted."""
    return b"X" + _RANGE_HDR.pack(mod, res, target, repoch) + \
        _encode_cert(cert)


def parse_cut(req: bytes):
    """(mod, res, target, repoch, cert) of a CUT, or None."""
    if req[:1] != b"X" or len(req) < 1 + _RANGE_HDR.size:
        return None
    mod, res, target, repoch = _RANGE_HDR.unpack_from(req, 1)
    parsed = _parse_cert(req, 1 + _RANGE_HDR.size)
    if parsed is None or parsed[1] != len(req):
        return None
    return mod, res, target, repoch, parsed[0]


def _range_blob(pairs: List[Tuple[bytes, bytes]]) -> bytes:
    """2-byte-framed pair encoding for range transfer (a captured range
    may exceed the 1-byte MSET framing limits)."""
    out = _RB_LEN.pack(len(pairs))
    for k, v in pairs:
        out += _RB_LEN.pack(len(k)) + k + _RB_LEN.pack(len(v)) + v
    return out


def _parse_range_blob(req: bytes, off: int):
    """((pairs...), next_off) or None on any length mismatch."""
    if off + _RB_LEN.size > len(req):
        return None
    (n,) = _RB_LEN.unpack_from(req, off)
    off += _RB_LEN.size
    pairs = []
    for _ in range(n):
        if off + _RB_LEN.size > len(req):
            return None
        (klen,) = _RB_LEN.unpack_from(req, off)
        off += _RB_LEN.size
        key = req[off:off + klen]
        off += klen
        if len(key) != klen or off + _RB_LEN.size > len(req):
            return None
        (vlen,) = _RB_LEN.unpack_from(req, off)
        off += _RB_LEN.size
        value = req[off:off + vlen]
        off += vlen
        if len(value) != vlen:
            return None
        pairs.append((key, value))
    return tuple(pairs), off


def range_fp(mod: int, res: int, repoch: int,
             pairs: Tuple[Tuple[bytes, bytes], ...]) -> bytes:
    """Fingerprint of a captured range — what the source replicas sign
    (``("resh", mod, res, repoch, fp)``) and the adopt slot's certificate
    attests to."""
    h = hashlib.sha256(_RANGE_KEY.pack(mod, res) + struct.pack("<I", repoch))
    for k, v in pairs:
        h.update(_RB_LEN.pack(len(k)) + k + _RB_LEN.pack(len(v)) + v)
    return h.digest()


def adopt_req(mod: int, res: int, src_shard: int, repoch: int,
              pairs: Tuple[Tuple[bytes, bytes], ...],
              cert: Tuple[Tuple[str, bytes], ...]) -> bytes:
    """ADOPT a transferred range at the target shard: install the pairs.
    Carries the f+1 source-shard signatures over the range fingerprint —
    verified at the consensus layer's svc endorsement gate (like a
    recovery FINISH's outcome certificate), so a Byzantine leader cannot
    plant forged keys via a fabricated adopt slot."""
    return (b"J" + _RANGE_HDR.pack(mod, res, src_shard, repoch) +
            _range_blob(list(pairs)) + _encode_cert(cert))


def parse_adopt(req: bytes):
    """(mod, res, src_shard, repoch, pairs, cert) of an ADOPT, or None."""
    if req[:1] != b"J" or len(req) < 1 + _RANGE_HDR.size:
        return None
    mod, res, src_shard, repoch = _RANGE_HDR.unpack_from(req, 1)
    parsed = _parse_range_blob(req, 1 + _RANGE_HDR.size)
    if parsed is None:
        return None
    pairs, off = parsed
    certp = _parse_cert(req, off)
    if certp is None or certp[1] != len(req):
        return None
    return mod, res, src_shard, repoch, pairs, certp[0]


class ShardKVApp(KVStoreApp):
    """One shard of the partitioned keyspace: the plain kvstore plus the
    replicated 2PC state of in-flight cross-shard transactions.

    Everything here is deterministic state-machine logic — votes, outcome
    records and lock transitions are all products of the shard's consensus
    log, so 2f+1 replicas hold identical 2PC state at identical log
    positions.  GETs return only *committed* values: a pending intent lives
    outside ``store`` until its FINISH(C) executes.
    """

    def __init__(self) -> None:
        super().__init__()
        #: authenticated pid of the client whose request is being applied
        #: ("" for internal/service slots) — set by apply_from for the
        #: duration of one apply; part of the agreed batch, so identical
        #: on every honest replica (determinism preserved)
        self._caller = ""
        #: key -> txid holding its write lock
        self.locks: Dict[bytes, bytes] = {}
        #: txid -> (deadline_us, coord_shard, pairs) awaiting the outcome
        self.pending: Dict[bytes, Tuple[float, int, tuple]] = {}
        #: txid -> vote this shard committed (idempotent re-PREPARE)
        self.votes: Dict[bytes, bytes] = {}
        #: coordinator role: txid -> recorded outcome (b"C" | b"A")
        self.outcomes: Dict[bytes, bytes] = {}
        #: txid -> outcome applied at this shard (idempotent re-FINISH)
        self.finished: Dict[bytes, bytes] = {}
        # ---- keyrange handoff state (shard split/merge, ISSUE 7) ----
        #: frozen ranges awaiting handoff: (mod, res) -> (target, repoch)
        self.moving: Dict[Tuple[int, int], Tuple[int, int]] = {}
        #: captured outbound snapshots: (mod, res) -> ((key, value), ...)
        self.outbound: Dict[Tuple[int, int], tuple] = {}
        #: completed handoffs: (mod, res) -> target shard (MOVED bounces)
        self.handoff: Dict[Tuple[int, int], int] = {}
        #: ranges this shard adopted: (mod, res) -> router epoch
        self.adopted: Dict[Tuple[int, int], int] = {}
        #: highest router epoch committed into this shard's log
        self.router_epoch = 0

    # ------------------------------------------------------------- apply
    def apply_from(self, caller: str, req: bytes) -> bytes:
        self._caller = caller
        try:
            return self.apply(req)
        finally:
            self._caller = ""

    def apply(self, req: bytes) -> bytes:
        op = req[:1]
        if op == b"P":
            return self._tprep(req)
        if op == b"D":
            return self._tdecide(req)
        if op == b"F":
            return self._tfinish(req)
        if op == b"B":
            return self._freeze(req)
        if op == b"T":
            return self._capture(req)
        if op == b"X":
            return self._cut(req)
        if op == b"J":
            return self._adopt_range(req)
        if op == b"G":
            state = self._range_state(req[1:])
            if state is not None and state[0] == "moved":
                # a GET for a handed-off key must *redirect*, never serve a
                # stale miss — the key may exist at the target
                return b"MOVED" + struct.pack("<H", state[1])
            return super().apply(req)
        if op == b"R":
            # recovery FINISH: the outcome certificate was verified by the
            # consensus layer before this slot could be certified; here it
            # only needs to frame correctly
            parsed = parse_rfinish(req)
            if parsed is None:
                return b"ERR"
            txid, outcome, _cert = parsed
            return self._finish_tx(txid, outcome)
        if op == b"O":
            if len(req) != 1 + TXID_LEN:
                return b"ERR"
            out = self.outcomes.get(req[1:1 + TXID_LEN])
            return b"NONE" if out is None else b"OUT" + out
        if op == b"S" or op == b"M":
            # single-shard writes respect transaction locks: a locked key
            # bounces (deterministically) until the transaction finishes,
            # so a cross-shard MSET cannot be half-overwritten mid-flight
            return self._locked_write(req)
        return super().apply(req)

    # ----------------------------------------------------- range handoff
    def _range_state(self, key: bytes):
        """("moved", target) / ("frozen", target) / None for ``key``.
        A product of this shard's log (freeze/cut slots), so identical on
        every replica at the same log position."""
        if self.handoff:
            h = zlib.crc32(key)
            for (m, r), tgt in self.handoff.items():
                if h % m == r:
                    return ("moved", tgt)
        if self.moving:
            h = zlib.crc32(key)
            for (m, r), (tgt, _e) in self.moving.items():
                if h % m == r:
                    return ("frozen", tgt)
        return None

    def _write_bounce(self, keys) -> bytes:
        """The deterministic bounce for a write touching a frozen or
        handed-off range (b"" = no bounce).  Writes are refused during the
        whole freeze window — unlike reads, which this shard keeps serving
        until the cut — so the captured snapshot can never miss a write."""
        for k in keys:
            state = self._range_state(k)
            if state is None:
                continue
            if state[0] == "moved":
                return b"MOVED" + struct.pack("<H", state[1])
            return b"FROZEN"
        return b""

    def _freeze(self, req: bytes) -> bytes:
        if len(req) != 1 + _RANGE_HDR.size:
            return b"ERR"
        mod, res, target, repoch = _RANGE_HDR.unpack_from(req, 1)
        if mod < 1:
            return b"ERR"
        key = (mod, res)
        if key in self.handoff or key in self.moving:
            return b"OK"    # idempotent replay
        self.moving[key] = (target, repoch)
        return b"OK"

    def _capture(self, req: bytes) -> bytes:
        if len(req) != 1 + _RANGE_KEY.size:
            return b"ERR"
        mod, res = _RANGE_KEY.unpack_from(req, 1)
        key = (mod, res)
        if key in self.outbound:
            return b"OK"    # idempotent replay: keep the first capture
        if key not in self.moving:
            return b"ERR"   # capture without a freeze is never legal
        if any(key_in_range(k, mod, res) for k in self.locks):
            # an in-flight transaction prepared under the old epoch still
            # holds in-range locks: it must finish at *this* shard before
            # the range snapshot is fixed (the control plane drains and
            # retries — this guard keeps the invariant deterministic)
            return b"BUSY"
        self.outbound[key] = tuple(sorted(
            (k, v) for k, v in self.store.items()
            if key_in_range(k, mod, res)))
        return b"OK"

    def _cut(self, req: bytes) -> bytes:
        parsed = parse_cut(req)
        if parsed is None:
            return b"ERR"
        mod, res, target, repoch, _cert = parsed
        key = (mod, res)
        if key in self.handoff:
            return b"OK"    # idempotent replay
        if key not in self.outbound:
            return b"ERR"   # cut before capture would lose the range
        for k in [k for k in self.store if key_in_range(k, mod, res)]:
            del self.store[k]
        self.moving.pop(key, None)
        self.outbound.pop(key, None)
        # the range is leaving: a stale adoption marker from an earlier
        # epoch must not suppress a future re-adoption of the same range
        self.adopted.pop(key, None)
        self.handoff[key] = target
        self.router_epoch = max(self.router_epoch, repoch)
        return b"OK"

    def _adopt_range(self, req: bytes) -> bytes:
        parsed = parse_adopt(req)
        if parsed is None:
            return b"ERR"
        mod, res, _src_shard, repoch, pairs, _cert = parsed
        key = (mod, res)
        if self.adopted.get(key) == repoch:
            # idempotent replay — epoch-keyed, because the same range may
            # leave (cut) and come back under a later epoch
            return b"OK"
        for k, v in pairs:
            self.store[k] = v
        self.adopted[key] = repoch
        # the range is ours again: drop the MOVED marker a previous
        # outbound handoff of this same range left behind (split → merge
        # back), or every in-range op bounces to a shard that no longer
        # owns it
        self.handoff.pop(key, None)
        self.router_epoch = max(self.router_epoch, repoch)
        return b"OK"

    def _locked_write(self, req: bytes) -> bytes:
        if req[:1] == b"S":
            if len(req) < 2:
                return b"ERR"
            klen = req[1]
            key = req[2:2 + klen]
            if len(key) != klen:
                return b"ERR"
            bounce = self._write_bounce((key,))
            if bounce:
                return bounce
            if key in self.locks:
                return b"LOCKED"
            return super().apply(req)
        pairs = _decode_pairs(req, 1)
        if pairs is None:
            return b"ERR"
        bounce = self._write_bounce(k for k, _v in pairs)
        if bounce:
            return bounce
        if any(k in self.locks for k, _v in pairs):
            return b"LOCKED"
        return super().apply(req)

    def _tprep(self, req: bytes) -> bytes:
        parsed = parse_tprep(req)
        if parsed is None:
            return b"ERR"
        txid, deadline, coord, pairs = parsed
        prior = self.votes.get(txid)
        if prior is not None:
            return prior                       # idempotent re-PREPARE
        if self.finished.get(txid) is not None:
            return VOTE_CONFLICT               # already finished (aborted)
        if any(self._range_state(k) is not None for k, _v in pairs):
            # a PREPARE that would lock a frozen or handed-off range loses
            # without being recorded: the coordinator presumes abort, the
            # client re-splits against the new routing table and retries
            return VOTE_CONFLICT
        if any(self.locks.get(k, txid) != txid for k, _v in pairs):
            self.votes[txid] = VOTE_CONFLICT   # a losing vote never locks
            return VOTE_CONFLICT
        for k, _v in pairs:
            self.locks[k] = txid
        self.pending[txid] = (deadline, coord, tuple(pairs))
        self.votes[txid] = VOTE_OK
        return VOTE_OK

    def _tdecide(self, req: bytes) -> bytes:
        if len(req) != 2 + TXID_LEN or req[-1:] not in (b"C", b"A"):
            return b"ERR"
        txid, proposed = req[1:1 + TXID_LEN], req[-1:]
        out = self.outcomes.get(txid)
        if out is None:
            if proposed == b"C" and tx_owner_tag(self._caller) != txid[:8]:
                # only the transaction's owner may record a *commit*: an
                # honest owner proposes C only after collecting all-OK
                # votes, so a recorded C implies every participant locked
                # and will apply — no torn transaction.  Anyone (recovery
                # probes included) may still record an abort: aborting is
                # always atomic under presumed-abort, so the worst a
                # non-owner can do is deny progress, never tear.
                return b"ERR_NOT_OWNER"
            # first DECIDE in the coordinator shard's log wins — the log's
            # total order is what makes the outcome unique and replicated
            out = self.outcomes[txid] = proposed
        return b"OUT" + out

    def _tfinish(self, req: bytes) -> bytes:
        if len(req) != 2 + TXID_LEN or req[-1:] not in (b"C", b"A"):
            return b"ERR"
        return self._finish_tx(req[1:1 + TXID_LEN], req[-1:])

    def _finish_tx(self, txid: bytes, outcome: bytes) -> bytes:
        prior = self.finished.get(txid)
        if prior is not None:
            return b"OK" if prior == outcome else b"ERR"
        entry = self.pending.pop(txid, None)
        if entry is None:
            # FINISH without a live intent: record the outcome so a late
            # PREPARE replay cannot resurrect the transaction
            self.finished[txid] = outcome
            return b"OK"
        _deadline, _coord, pairs = entry
        if outcome == b"C":
            for k, v in pairs:
                self.store[k] = v
        for k, _v in pairs:
            if self.locks.get(k) == txid:
                del self.locks[k]
        self.finished[txid] = outcome
        return b"OK"

    # --------------------------------------------------------- snapshots
    def snapshot(self):
        return (tuple(sorted(self.store.items())),
                tuple(sorted(self.locks.items())),
                tuple(sorted(self.pending.items())),
                tuple(sorted(self.votes.items())),
                tuple(sorted(self.outcomes.items())),
                tuple(sorted(self.finished.items())),
                tuple(sorted(self.moving.items())),
                tuple(sorted(self.outbound.items())),
                tuple(sorted(self.handoff.items())),
                tuple(sorted(self.adopted.items())),
                self.router_epoch)

    def adopt(self, snap) -> None:
        (store, locks, pending, votes, outcomes, finished,
         moving, outbound, handoff, adopted, repoch) = snap
        self.store = dict(store)
        self.locks = dict(locks)
        self.pending = dict(pending)
        self.votes = dict(votes)
        self.outcomes = dict(outcomes)
        self.finished = dict(finished)
        self.moving = dict(moving)
        self.outbound = dict(outbound)
        self.handoff = dict(handoff)
        self.adopted = dict(adopted)
        self.router_epoch = repoch
