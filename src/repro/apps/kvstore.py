"""In-memory KV store — the Memcached/Redis analog of §7.1.

Request wire format (binary, matching the paper's 16 B keys / 32 B values):
    b"G" + key            -> GET
    b"S" + klen(1) + key + value -> SET
    b"M" + n(1) + n × (klen(1) + key + vlen(1) + value) -> MSET (multi-put)
Responses: value bytes (b"" on miss) or b"OK".

Every length field is one byte, so the encoders *raise* on anything that
cannot be framed (``>255`` pairs, keys or values) instead of silently
truncating, and :meth:`KVStoreApp.apply` answers a deterministic ``b"ERR"``
on any payload whose declared lengths disagree with its actual bytes — a
malformed request must never mis-parse into a different (but valid-looking)
operation, because every honest replica must produce the *same* reply.

:class:`ShardKVApp` extends the store with the participant/coordinator
state of cross-shard two-phase commit (``repro/service/``): PREPARE locks
keys and records a pending intent, DECIDE records the transaction outcome
exactly once on the coordinator shard's log, FINISH applies-or-discards the
intent.  Each of those is an ordinary consensus request — *each 2PC phase
is itself a BFT-committed slot* (see DESIGN_SHARDING.md):

    b"P" + txid(8) + deadline_us(<Q) + coord(<H) + n(1) + pairs -> TPREP
    b"D" + txid(8) + outcome(1: C|A)                            -> TDECIDE
    b"F" + txid(8) + outcome(1: C|A)                            -> TFINISH
    b"O" + txid(8)                                              -> outcome?
"""

from __future__ import annotations

import struct
from typing import Dict, List, Tuple

from repro.core.consensus import App

#: one-byte length fields frame every key/value/pair-count on the wire
MAX_LEN = 255

VOTE_OK = b"VOTE_OK"
VOTE_CONFLICT = b"VOTE_CONFLICT"


def get_req(key: bytes) -> bytes:
    return b"G" + key


def set_req(key: bytes, value: bytes) -> bytes:
    if len(key) > MAX_LEN:
        raise ValueError(f"key of {len(key)} B does not fit the 1-byte "
                         f"length field (max {MAX_LEN})")
    return b"S" + bytes([len(key)]) + key + value


def mset_req(pairs: List[Tuple[bytes, bytes]]) -> bytes:
    """One request carrying several puts — application-level batching that
    composes with the consensus layer's slot batching."""
    return b"M" + _encode_pairs(pairs)


def _encode_pairs(pairs: List[Tuple[bytes, bytes]]) -> bytes:
    if len(pairs) > MAX_LEN:
        raise ValueError(f"{len(pairs)} pairs do not fit the 1-byte count "
                         f"field (max {MAX_LEN})")
    out = bytes([len(pairs)])
    for k, v in pairs:
        if len(k) > MAX_LEN or len(v) > MAX_LEN:
            raise ValueError(f"key/value of {len(k)}/{len(v)} B does not "
                             f"fit the 1-byte length field (max {MAX_LEN})")
        out += bytes([len(k)]) + k + bytes([len(v)]) + v
    return out


def _decode_pairs(req: bytes, off: int):
    """Parse ``n(1) + n × (klen+key+vlen+value)`` fully before anything is
    applied; returns ``None`` on any length mismatch (deterministic ERR at
    the caller) so a truncated payload can never half-apply."""
    if off >= len(req):
        return None
    n = req[off]
    off += 1
    pairs = []
    for _ in range(n):
        if off >= len(req):
            return None
        klen = req[off]
        key = req[off + 1:off + 1 + klen]
        off += 1 + klen
        if len(key) != klen or off >= len(req):
            return None
        vlen = req[off]
        value = req[off + 1:off + 1 + vlen]
        off += 1 + vlen
        if len(value) != vlen:
            return None
        pairs.append((key, value))
    if off != len(req):
        return None
    return pairs


class KVStoreApp(App):
    def __init__(self) -> None:
        self.store: Dict[bytes, bytes] = {}

    def apply(self, req: bytes) -> bytes:
        op = req[:1]
        if op == b"G":
            return self.store.get(req[1:], b"")
        if op == b"S":
            if len(req) < 2:
                return b"ERR"
            klen = req[1]
            key = req[2:2 + klen]
            if len(key) != klen:
                return b"ERR"   # declared length overruns the payload
            value = req[2 + klen:]
            self.store[key] = value
            return b"OK"
        if op == b"M":
            # parse the whole payload before touching the store: a
            # malformed/truncated request is rejected atomically
            pairs = _decode_pairs(req, 1)
            if pairs is None:
                return b"ERR"
            for key, value in pairs:
                self.store[key] = value
            return b"OK"
        return b"ERR"

    def snapshot(self):
        return tuple(sorted(self.store.items()))

    def adopt(self, snap) -> None:
        self.store = dict(snap)


# --------------------------------------------------------------------------
# Sharded-service participant: 2PC state behind the same App interface
# --------------------------------------------------------------------------
_TPREP_HDR = struct.Struct("<QH")   # deadline_us, coordinator shard index


def tprep_req(txid: bytes, deadline_us: float, coord_shard: int,
              pairs: List[Tuple[bytes, bytes]]) -> bytes:
    """PREPARE this shard's slice of a cross-shard transaction: lock the
    keys, record the intent, vote.  ``deadline_us`` (absolute sim time) is
    consumed by the *replica-layer* recovery timers, never by apply()."""
    assert len(txid) == 8
    return (b"P" + txid + _TPREP_HDR.pack(int(deadline_us), coord_shard) +
            _encode_pairs(pairs))


def tdecide_req(txid: bytes, outcome: bytes) -> bytes:
    """Record the transaction outcome on the coordinator shard (exactly
    once: the first DECIDE in its log wins; later ones read it back)."""
    assert outcome in (b"C", b"A") and len(txid) == 8
    return b"D" + txid + outcome


def tfinish_req(txid: bytes, outcome: bytes) -> bytes:
    """Apply (C) or discard (A) the pending intent and release its locks."""
    assert outcome in (b"C", b"A") and len(txid) == 8
    return b"F" + txid + outcome


def toutcome_req(txid: bytes) -> bytes:
    """Read the recorded outcome (b"OUT"+o, or b"NONE")."""
    assert len(txid) == 8
    return b"O" + txid


def parse_tprep(req: bytes):
    """(txid, deadline_us, coord_shard, pairs) of a TPREP, or None."""
    if req[:1] != b"P" or len(req) < 9 + _TPREP_HDR.size:
        return None
    txid = req[1:9]
    deadline, coord = _TPREP_HDR.unpack_from(req, 9)
    pairs = _decode_pairs(req, 9 + _TPREP_HDR.size)
    if pairs is None:
        return None
    return txid, float(deadline), coord, pairs


class ShardKVApp(KVStoreApp):
    """One shard of the partitioned keyspace: the plain kvstore plus the
    replicated 2PC state of in-flight cross-shard transactions.

    Everything here is deterministic state-machine logic — votes, outcome
    records and lock transitions are all products of the shard's consensus
    log, so 2f+1 replicas hold identical 2PC state at identical log
    positions.  GETs return only *committed* values: a pending intent lives
    outside ``store`` until its FINISH(C) executes.
    """

    def __init__(self) -> None:
        super().__init__()
        #: key -> txid holding its write lock
        self.locks: Dict[bytes, bytes] = {}
        #: txid -> (deadline_us, coord_shard, pairs) awaiting the outcome
        self.pending: Dict[bytes, Tuple[float, int, tuple]] = {}
        #: txid -> vote this shard committed (idempotent re-PREPARE)
        self.votes: Dict[bytes, bytes] = {}
        #: coordinator role: txid -> recorded outcome (b"C" | b"A")
        self.outcomes: Dict[bytes, bytes] = {}
        #: txid -> outcome applied at this shard (idempotent re-FINISH)
        self.finished: Dict[bytes, bytes] = {}

    # ------------------------------------------------------------- apply
    def apply(self, req: bytes) -> bytes:
        op = req[:1]
        if op == b"P":
            return self._tprep(req)
        if op == b"D":
            return self._tdecide(req)
        if op == b"F":
            return self._tfinish(req)
        if op == b"O":
            if len(req) != 9:
                return b"ERR"
            out = self.outcomes.get(req[1:9])
            return b"NONE" if out is None else b"OUT" + out
        if op == b"S" or op == b"M":
            # single-shard writes respect transaction locks: a locked key
            # bounces (deterministically) until the transaction finishes,
            # so a cross-shard MSET cannot be half-overwritten mid-flight
            return self._locked_write(req)
        return super().apply(req)

    def _locked_write(self, req: bytes) -> bytes:
        if req[:1] == b"S":
            if len(req) < 2:
                return b"ERR"
            klen = req[1]
            key = req[2:2 + klen]
            if len(key) != klen:
                return b"ERR"
            if key in self.locks:
                return b"LOCKED"
            return super().apply(req)
        pairs = _decode_pairs(req, 1)
        if pairs is None:
            return b"ERR"
        if any(k in self.locks for k, _v in pairs):
            return b"LOCKED"
        return super().apply(req)

    def _tprep(self, req: bytes) -> bytes:
        parsed = parse_tprep(req)
        if parsed is None:
            return b"ERR"
        txid, deadline, coord, pairs = parsed
        prior = self.votes.get(txid)
        if prior is not None:
            return prior                       # idempotent re-PREPARE
        if self.finished.get(txid) is not None:
            return VOTE_CONFLICT               # already finished (aborted)
        if any(self.locks.get(k, txid) != txid for k, _v in pairs):
            self.votes[txid] = VOTE_CONFLICT   # a losing vote never locks
            return VOTE_CONFLICT
        for k, _v in pairs:
            self.locks[k] = txid
        self.pending[txid] = (deadline, coord, tuple(pairs))
        self.votes[txid] = VOTE_OK
        return VOTE_OK

    def _tdecide(self, req: bytes) -> bytes:
        if len(req) != 10 or req[9:10] not in (b"C", b"A"):
            return b"ERR"
        txid, proposed = req[1:9], req[9:10]
        out = self.outcomes.get(txid)
        if out is None:
            # first DECIDE in the coordinator shard's log wins — the log's
            # total order is what makes the outcome unique and replicated
            out = self.outcomes[txid] = proposed
        return b"OUT" + out

    def _tfinish(self, req: bytes) -> bytes:
        if len(req) != 10 or req[9:10] not in (b"C", b"A"):
            return b"ERR"
        txid, outcome = req[1:9], req[9:10]
        prior = self.finished.get(txid)
        if prior is not None:
            return b"OK" if prior == outcome else b"ERR"
        entry = self.pending.pop(txid, None)
        if entry is None:
            # FINISH without a live intent: record the outcome so a late
            # PREPARE replay cannot resurrect the transaction
            self.finished[txid] = outcome
            return b"OK"
        _deadline, _coord, pairs = entry
        if outcome == b"C":
            for k, v in pairs:
                self.store[k] = v
        for k, _v in pairs:
            if self.locks.get(k) == txid:
                del self.locks[k]
        self.finished[txid] = outcome
        return b"OK"

    # --------------------------------------------------------- snapshots
    def snapshot(self):
        return (tuple(sorted(self.store.items())),
                tuple(sorted(self.locks.items())),
                tuple(sorted(self.pending.items())),
                tuple(sorted(self.votes.items())),
                tuple(sorted(self.outcomes.items())),
                tuple(sorted(self.finished.items())))

    def adopt(self, snap) -> None:
        store, locks, pending, votes, outcomes, finished = snap
        self.store = dict(store)
        self.locks = dict(locks)
        self.pending = dict(pending)
        self.votes = dict(votes)
        self.outcomes = dict(outcomes)
        self.finished = dict(finished)
