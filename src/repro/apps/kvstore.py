"""In-memory KV store — the Memcached/Redis analog of §7.1.

Request wire format (binary, matching the paper's 16 B keys / 32 B values):
    b"G" + key            -> GET
    b"S" + klen(1) + key + value -> SET
Responses: value bytes (b"" on miss) or b"OK".
"""

from __future__ import annotations

from typing import Dict

from repro.core.consensus import App


def get_req(key: bytes) -> bytes:
    return b"G" + key


def set_req(key: bytes, value: bytes) -> bytes:
    return b"S" + bytes([len(key)]) + key + value


class KVStoreApp(App):
    def __init__(self) -> None:
        self.store: Dict[bytes, bytes] = {}

    def apply(self, req: bytes) -> bytes:
        op = req[:1]
        if op == b"G":
            return self.store.get(req[1:], b"")
        if op == b"S":
            klen = req[1]
            key = req[2:2 + klen]
            value = req[2 + klen:]
            self.store[key] = value
            return b"OK"
        return b"ERR"

    def snapshot(self):
        return tuple(sorted(self.store.items()))

    def adopt(self, snap) -> None:
        self.store = dict(snap)
