"""In-memory KV store — the Memcached/Redis analog of §7.1.

Request wire format (binary, matching the paper's 16 B keys / 32 B values):
    b"G" + key            -> GET
    b"S" + klen(1) + key + value -> SET
    b"M" + n(1) + n × (klen(1) + key + vlen(1) + value) -> MSET (multi-put)
Responses: value bytes (b"" on miss) or b"OK".
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from repro.core.consensus import App


def get_req(key: bytes) -> bytes:
    return b"G" + key


def set_req(key: bytes, value: bytes) -> bytes:
    return b"S" + bytes([len(key)]) + key + value


def mset_req(pairs: List[Tuple[bytes, bytes]]) -> bytes:
    """One request carrying several puts — application-level batching that
    composes with the consensus layer's slot batching."""
    out = b"M" + bytes([len(pairs)])
    for k, v in pairs:
        out += bytes([len(k)]) + k + bytes([len(v)]) + v
    return out


class KVStoreApp(App):
    def __init__(self) -> None:
        self.store: Dict[bytes, bytes] = {}

    def apply(self, req: bytes) -> bytes:
        op = req[:1]
        if op == b"G":
            return self.store.get(req[1:], b"")
        if op == b"S":
            klen = req[1]
            key = req[2:2 + klen]
            value = req[2 + klen:]
            self.store[key] = value
            return b"OK"
        if op == b"M":
            # parse the whole payload before touching the store: a
            # malformed/truncated request is rejected atomically
            if len(req) < 2:
                return b"ERR"
            n = req[1]
            off = 2
            pairs = []
            for _ in range(n):
                if off >= len(req):
                    return b"ERR"
                klen = req[off]
                key = req[off + 1:off + 1 + klen]
                off += 1 + klen
                if len(key) != klen or off >= len(req):
                    return b"ERR"
                vlen = req[off]
                value = req[off + 1:off + 1 + vlen]
                off += 1 + vlen
                if len(value) != vlen:
                    return b"ERR"
                pairs.append((key, value))
            if off != len(req):
                return b"ERR"
            for key, value in pairs:
                self.store[key] = value
            return b"OK"
        return b"ERR"

    def snapshot(self):
        return tuple(sorted(self.store.items()))

    def adopt(self, snap) -> None:
        self.store = dict(snap)
