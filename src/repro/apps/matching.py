"""Financial order matching engine — the Liquibook analog of §7.1.

A price-time-priority limit order book.  Requests are 32 B (like the paper's
Liquibook workload); responses grow with the number of matched orders
(32 B – 288 B in the paper).

Request wire format:
    b"B"/b"S" + order_id(8) + price(8) + qty(8) + pad -> BUY / SELL limit
Response: sequence of fills ``(maker_id, price, qty)`` packed 24 B each,
prefixed by a 8 B fill count (so a no-fill ack is 8 B + padding to 32 B).
"""

from __future__ import annotations

import struct
from typing import Dict, List, Tuple

from repro.core.consensus import App


def order_req(side: str, order_id: int, price: int, qty: int) -> bytes:
    body = (b"B" if side == "buy" else b"S") + struct.pack(
        "<QQQ", order_id, price, qty)
    return body.ljust(32, b"\x00")


class MatchingEngineApp(App):
    def __init__(self) -> None:
        # price -> FIFO list of (order_id, qty); bids and asks kept sorted
        self.bids: List[Tuple[int, int, int]] = []  # (-price, seq, ...) heap-free impl
        self.asks: List[Tuple[int, int, int]] = []
        self._seq = 0
        self.fills = 0

    def apply(self, req: bytes) -> bytes:
        side = req[:1]
        order_id, price, qty = struct.unpack_from("<QQQ", req, 1)
        fills: List[Tuple[int, int, int]] = []
        self._seq += 1
        if side == b"B":
            # match against asks with price <= limit
            while qty > 0 and self.asks and self.asks[0][0] <= price:
                ap, aseq, (aid, aqty) = self.asks[0][0], self.asks[0][1], self.asks[0][2]
                take = min(qty, aqty)
                fills.append((aid, ap, take))
                qty -= take
                if take == aqty:
                    self.asks.pop(0)
                else:
                    self.asks[0] = (ap, aseq, (aid, aqty - take))
            if qty > 0:
                self.bids.append((-price, self._seq, (order_id, qty)))
                self.bids.sort()
        elif side == b"S":
            while qty > 0 and self.bids and -self.bids[0][0] >= price:
                bp, bseq, (bid, bqty) = -self.bids[0][0], self.bids[0][1], self.bids[0][2]
                take = min(qty, bqty)
                fills.append((bid, bp, take))
                qty -= take
                if take == bqty:
                    self.bids.pop(0)
                else:
                    self.bids[0] = (-bp, bseq, (bid, bqty - take))
            if qty > 0:
                self.asks.append((price, self._seq, (order_id, qty)))
                self.asks.sort()
        else:
            return b"ERR".ljust(32, b"\x00")
        self.fills += len(fills)
        out = struct.pack("<Q", len(fills))
        for mid, p, q in fills:
            out += struct.pack("<QQQ", mid, p, q)
        return out.ljust(32, b"\x00")

    def snapshot(self):
        return (tuple(self.bids), tuple(self.asks), self._seq, self.fills)

    def adopt(self, snap) -> None:
        bids, asks, seq, fills = snap
        self.bids = [tuple(b) for b in bids]
        self.asks = [tuple(a) for a in asks]
        self._seq = seq
        self.fills = fills
