"""uBFT-replicated inference serving (the Memcached/Redis analog: a token
server whose request order is agreed through consensus).

Every replica holds the same model + decoding state; client generation
requests are totally ordered by uBFT, so all replicas produce identical
tokens and the client accepts f+1 matching responses — a Byzantine replica
cannot forge a generation.  This is exactly the paper's SMR deployment with
the application = an autoregressive decoder.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.consensus import App, ConsensusConfig
from repro.core.smr import Cluster, build_cluster


class TokenServerApp(App):
    """Replicated state machine wrapping a deterministic decode function.

    ``decode_fn(session, prompt_tokens, n_tokens) -> tokens`` must be
    deterministic (greedy argmax) so replicas stay identical.

    The per-session token history IS the KV-cache metadata riding the
    consensus slots: every replica holds the same context per session,
    so a joiner adopting the snapshot continues decoding mid-session
    bit-for-bit.  With a ``cost_model``
    (:class:`repro.serve.costmodel.ServingCostModel`) the app reports
    each request's roofline service time — prefill over the new prompt
    plus per-token decode at the session's current context — through
    ``App.cost_us``, turning on the consensus layer's deferred execution
    engine (decided slots occupy the replica's serial decode engine for
    that long before applying).
    """

    def __init__(self, decode_fn: Callable[[str, List[int], int], List[int]],
                 cost_model: Any = None):
        self.decode_fn = decode_fn
        self.cost_model = cost_model
        self.sessions: Dict[str, List[int]] = {}

    def apply(self, req: bytes) -> bytes:
        msg = json.loads(req.decode())
        sid = msg["session"]
        hist = self.sessions.setdefault(sid, [])
        prompt = msg.get("prompt", [])
        hist.extend(int(t) for t in prompt)
        toks = self.decode_fn(sid, list(hist), int(msg.get("n", 1)))
        hist.extend(int(t) for t in toks)
        return json.dumps({"tokens": [int(t) for t in toks]}).encode()

    def cost_us(self, req: bytes) -> float:
        if self.cost_model is None:
            return 0.0
        try:
            msg = json.loads(req.decode())
            sid = msg["session"]
            n_prompt = len(msg.get("prompt", []))
            n_decode = int(msg.get("n", 1))
        except Exception:
            return 0.0   # malformed requests fail fast in apply()
        ctx = len(self.sessions.get(sid, ()))
        return float(self.cost_model.request_us(n_prompt, n_decode, ctx))

    def snapshot(self):
        return tuple(sorted((k, tuple(v)) for k, v in self.sessions.items()))

    def adopt(self, snap) -> None:
        self.sessions = {k: list(v) for k, v in snap}


@dataclass
class ReplicatedServer:
    cluster: Cluster

    @classmethod
    def build(cls, decode_fn, f: Optional[int] = None,
              f_m: Optional[int] = None, n_pools: int = 1,
              auto_reconfigure: bool = False,
              cfg: Optional[ConsensusConfig] = None,
              substrate=None, name: str = "",
              cost_model: Any = None) -> "ReplicatedServer":
        """``n_pools`` shards the serving cluster's register keys over that
        many disaggregated-memory pools (the paper's "shared by many
        replicated applications" deployment); ``auto_reconfigure`` enables
        lease-based replacement of crashed memory nodes underneath a
        running token server.

        Pass ``substrate=`` (and a ``name``) to attach the token server to
        an *existing* shared substrate instead of building a private one —
        several replicated servers (or a server next to other replicated
        apps) then co-run over the same disaggregated-memory pools.  With
        ``cfg=`` the fault budgets come from the config alone (a
        conflicting explicit ``f``/``f_m`` raises, mirroring
        ``build_cluster``); with ``substrate=`` the pool topology comes
        from the substrate alone."""
        if cfg is not None:
            if f is not None and f != cfg.f:
                raise ValueError(f"conflicting fault budgets: f={f} vs "
                                 f"cfg.f={cfg.f}")
            if f_m is not None and f_m != cfg.f_m:
                raise ValueError(f"conflicting fault budgets: f_m={f_m} vs "
                                 f"cfg.f_m={cfg.f_m}")
        else:
            cfg = ConsensusConfig(f=1 if f is None else f,
                                  f_m=1 if f_m is None else f_m,
                                  max_request_bytes=4096)
        if substrate is not None:
            if n_pools != 1 or auto_reconfigure:
                raise ValueError(
                    "n_pools/auto_reconfigure describe a private substrate "
                    "— with substrate=, the pool topology is already fixed")
            from repro.core.smr import Cluster
            cluster = Cluster.attach(substrate, lambda: TokenServerApp(
                decode_fn, cost_model=cost_model), name=name, cfg=cfg)
        else:
            cluster = build_cluster(
                lambda: TokenServerApp(decode_fn, cost_model=cost_model),
                n_pools=n_pools, auto_reconfigure=auto_reconfigure, cfg=cfg)
        return cls(cluster=cluster)

    def generate(self, client, session: str, prompt: List[int], n: int,
                 timeout: float = 60_000_000.0
                 ) -> Tuple[Optional[List[int]], float]:
        """One generation round-trip.  Returns ``(tokens, latency_us)`` —
        or ``(None, latency_us)`` when admission control shed the request
        with the agreed deterministic BUSY reply."""
        payload = json.dumps({"session": session, "prompt": prompt,
                              "n": n}).encode()
        raw, lat = self.cluster.run_request(client, payload, timeout=timeout)
        return self._parse(raw), lat

    def generate_many(self, client, requests: List[Tuple[str, List[int], int]],
                      timeout: float = 60_000_000.0
                      ) -> List[Tuple[Optional[List[int]], float]]:
        """Submit many generation requests concurrently; consensus orders
        them (coalesced into batched slots when the leader is configured
        with max_batch > 1) and every replica decodes the same sequence."""
        payloads = [json.dumps({"session": s, "prompt": p, "n": n}).encode()
                    for s, p, n in requests]
        outs = self.cluster.run_requests(client, payloads, timeout=timeout)
        return [(self._parse(raw), lat) for raw, lat in outs]

    @staticmethod
    def _parse(raw: bytes) -> Optional[List[int]]:
        if raw == b"BUSY":
            return None
        return json.loads(raw.decode())["tokens"]
