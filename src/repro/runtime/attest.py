"""State attestation: device-side fingerprints of parameter/gradient trees.

This is the TPU adaptation of the paper's checksum machinery (§6.1): instead
of RDMA-register checksums, each training replica computes a cheap
order-independent hash of its gradients/parameters *on device* every step;
the uBFT control plane (repro.core) orders and compares these fingerprints
through CTBcast, detecting silent data corruption or a Byzantine/diverged
replica (the paper's §1 failure taxonomy) before a checkpoint embeds the
damage.

A Pallas kernel (repro.kernels.fingerprint) implements the same reduction
as the TPU-target hot path; this module is the jnp reference used in the
compiled step.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

_MIX = jnp.uint32(0x9E3779B9)   # golden-ratio Weyl constant


def fingerprint_array(x: jax.Array) -> jax.Array:
    """Order-independent uint32 digest of one array (sum-mix over words)."""
    if x.dtype == jnp.bfloat16 or x.dtype == jnp.float16:
        w = jax.lax.bitcast_convert_type(x, jnp.uint16).astype(jnp.uint32)
    elif x.dtype in (jnp.float32, jnp.int32, jnp.uint32):
        w = jax.lax.bitcast_convert_type(x, jnp.uint32)
    else:
        w = x.astype(jnp.uint32)
    w = w * _MIX ^ (w >> 16)
    return jnp.sum(w, dtype=jnp.uint32)


def fingerprint_tree(tree: Any) -> jax.Array:
    """uint32 digest of a pytree (leaf digests mixed positionally)."""
    acc = jnp.uint32(0)
    for i, leaf in enumerate(jax.tree.leaves(tree)):
        h = fingerprint_array(leaf)
        acc = acc * jnp.uint32(31) + h + jnp.uint32(i)
    return acc
