"""Step builders: the functions the launcher jits/lowers.

  make_train_step(cfg, ctx)  — fwd + bwd + AdamW + attestation fingerprints
  make_prefill(cfg, ctx)     — prompt ingestion, returns last logits + caches
  make_serve_step(cfg, ctx)  — one decode token against caches/state
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.transformer import (ShardCtx, decode_step, lm_loss,
                                      prefill)
from repro.optim.adamw import AdamWConfig, adamw_update
from repro.runtime.attest import fingerprint_tree


def make_train_step(cfg: ModelConfig, ctx: Optional[ShardCtx] = None,
                    opt_cfg: Optional[AdamWConfig] = None):
    opt_cfg = opt_cfg or AdamWConfig()

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, batch["inputs"], batch["targets"], ctx)
        )(params)
        new_params, new_opt = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss}
        if cfg.attest:
            # uBFT attestation: replicas CTBcast these (see repro.runtime.trainer)
            metrics["grad_fp"] = fingerprint_tree(grads)
            metrics["param_fp"] = fingerprint_tree(new_params)
        return new_params, new_opt, metrics

    return train_step


def make_prefill(cfg: ModelConfig, ctx: Optional[ShardCtx] = None,
                 max_seq: Optional[int] = None):
    def prefill_step(params, inputs):
        return prefill(cfg, params, inputs, ctx, max_seq=max_seq)

    return prefill_step


def make_serve_step(cfg: ModelConfig, ctx: Optional[ShardCtx] = None):
    def serve_step(params, caches, tokens, position):
        return decode_step(cfg, params, caches, tokens, position, ctx)

    return serve_step
