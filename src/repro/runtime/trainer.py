"""uBFT-replicated training coordinator (the paper's technique as a
first-class framework feature — DESIGN.md §2).

Deployment model: each *training replica* is a full copy of the training job
(its own data-plane mesh slice or an independent run of the same job, per
the fault model being defended against).  The replicas' control decisions —
which step to run next, over which data range, when to cut a checkpoint,
membership changes — flow through uBFT SMR, so up to f Byzantine replicas
(silent data corruption, fail-slow nodes, bad actors — the paper's §1
failure taxonomy) cannot equivocate or diverge the run.

Per step, the coordinator state machine orders:
    STEP(step_id, data_epoch)          — all replicas run this step
    ATTEST(step_id, grad_fp, param_fp) — fingerprint votes; divergence of a
                                         replica's fingerprint exposes it
    CHECKPOINT(step_id, param_fp)      — agreed checkpoint cut (f+1 attested
                                         before any replica trusts it)

Straggler/failure handling falls out of the protocol: a slow leader loses
the fast path (unanimity) and the system continues on the slow path; a dead
leader is rotated out by the view change.  This module also provides the
in-process simulation harness used by tests/examples (2f+1 trainers on the
discrete-event simulator, each driving a real JAX train step).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import crypto
from repro.core.consensus import App, ConsensusConfig
from repro.core.smr import Cluster, build_cluster


# ---------------------------------------------------------------------------
# The replicated coordinator state machine
# ---------------------------------------------------------------------------
class CoordinatorApp(App):
    """Deterministic control-plane state machine replicated via uBFT."""

    def __init__(self) -> None:
        self.next_step = 0
        self.attestations: Dict[int, Dict[str, Tuple[int, int]]] = {}
        self.checkpoints: List[Tuple[int, int]] = []   # (step, param_fp)
        self.flagged: Dict[str, str] = {}              # replica -> reason
        self.members: List[str] = []

    def apply(self, req: bytes) -> bytes:
        msg = json.loads(req.decode()) if req else {"op": "noop"}
        op = msg.get("op")
        if op == "step":
            step = self.next_step
            self.next_step += 1
            return json.dumps({"step": step,
                               "data_epoch": msg.get("data_epoch", 0)}).encode()
        if op == "attest":
            step = msg["step"]
            who = msg["who"]
            fp = (msg["grad_fp"], msg["param_fp"])
            votes = self.attestations.setdefault(step, {})
            votes[who] = fp
            # expose divergent replicas: majority fingerprint wins
            if len(votes) >= 2:
                counts: Dict[Tuple[int, int], int] = {}
                for v in votes.values():
                    counts[v] = counts.get(v, 0) + 1
                majority = max(counts, key=counts.get)
                if counts[majority] >= 2:
                    for w, v in votes.items():
                        if v != majority and w not in self.flagged:
                            self.flagged[w] = f"divergent@step{step}"
            return json.dumps({"ok": True,
                               "flagged": sorted(self.flagged)}).encode()
        if op == "checkpoint":
            self.checkpoints.append((msg["step"], msg["param_fp"]))
            return json.dumps({"ok": True}).encode()
        if op == "join":
            if msg["who"] not in self.members:
                self.members.append(msg["who"])
            return json.dumps({"members": self.members}).encode()
        return b"{}"

    def snapshot(self):
        return (self.next_step, tuple(self.checkpoints),
                tuple(sorted(self.flagged.items())), tuple(self.members))

    def adopt(self, snap) -> None:
        self.next_step, cps, flagged, members = snap
        self.checkpoints = list(cps)
        self.flagged = dict(flagged)
        self.members = list(members)


# ---------------------------------------------------------------------------
# In-process replicated trainer harness
# ---------------------------------------------------------------------------
@dataclass
class ReplicatedTrainer:
    """2f+1 training replicas coordinated through a uBFT cluster.

    ``train_step_fn(replica_idx, step, data_epoch) -> (grad_fp, param_fp,
    metrics)`` is the data-plane callback — in production the pjit'd step on
    the replica's mesh; in tests a real (small) JAX step.
    """

    cluster: Cluster
    train_step_fn: Callable[[int, int, int], Tuple[int, int, Dict]]
    f: int = 1
    history: List[Dict] = field(default_factory=list)

    @classmethod
    def build(cls, train_step_fn, f: Optional[int] = None,
              cfg: Optional[ConsensusConfig] = None) -> "ReplicatedTrainer":
        # f comes from cfg alone in the substrate API; a conflicting
        # explicit f raises (mirrors build_cluster) instead of being
        # silently dropped.
        if cfg is not None:
            if f is not None and f != cfg.f:
                raise ValueError(f"conflicting fault budgets: f={f} vs "
                                 f"cfg.f={cfg.f}")
        else:
            cfg = ConsensusConfig(f=1 if f is None else f)
        cluster = build_cluster(CoordinatorApp, cfg=cfg)
        return cls(cluster=cluster, train_step_fn=train_step_fn, f=cfg.f)

    def _submit(self, client, payload: dict, timeout=60_000_000.0) -> dict:
        raw, _lat = self.cluster.run_request(
            client, json.dumps(payload).encode(), timeout=timeout)
        return json.loads(raw.decode() or "{}")

    def run_steps(self, n_steps: int,
                  byzantine_replica: Optional[int] = None) -> List[Dict]:
        """Drive n agreed steps; every live replica executes each step and
        attests its fingerprints.  ``byzantine_replica`` injects a corrupted
        replica (flips its gradients) to demonstrate detection."""
        client = self.cluster.new_client()
        out = []
        for _ in range(n_steps):
            order = self._submit(client, {"op": "step"})
            step = order["step"]
            fps = {}
            for idx in range(len(self.cluster.replicas)):
                if self.cluster.replicas[idx].crashed:
                    continue
                gfp, pfp, metrics = self.train_step_fn(idx, step,
                                                       order["data_epoch"])
                if byzantine_replica == idx:
                    gfp ^= 0xDEADBEEF      # silent corruption
                    pfp ^= 0xDEADBEEF
                fps[idx] = (gfp, pfp)
                resp = self._submit(client, {
                    "op": "attest", "step": step,
                    "who": f"t{idx}", "grad_fp": gfp, "param_fp": pfp})
            rec = {"step": step, "fps": fps,
                   "flagged": resp.get("flagged", [])}
            self.history.append(rec)
            out.append(rec)
        return out

    def agree_checkpoint(self, step: int, param_fp: int) -> dict:
        client = self.cluster.new_client()
        return self._submit(client, {"op": "checkpoint", "step": step,
                                     "param_fp": param_fp})

    @property
    def coordinator_state(self) -> CoordinatorApp:
        return self.cluster.replicas[0].app
