"""First-class membership epochs for the replica group.

The paper's protocol text treats the replica set as a static parameter
(n = 2f+1 processes fixed at deployment).  That assumption silently
degrades the deployment story: one crashed replica permanently burns a
slot of the fault budget, and the Fig 11 reconfiguration narrative (and
the bounded-memory argument of Table 2) only stays meaningful if the
*group itself* can be repaired.  A :class:`MembershipEpoch` makes the
group explicit:

* ``epoch`` — a monotonically increasing configuration number.  Epoch 0
  is the deployment-time group; every replica replacement bumps it by
  one.  Protocol messages that are only meaningful relative to a
  configuration (SEAL_VIEW / NEW_VIEW) carry the epoch when it is
  non-zero, and stale-epoch messages are rejected exactly like stale
  views.  (Epoch-0 messages keep the historical wire shape so static
  deployments stay bit-identical on the recorded golden traces.)
* ``replicas`` — the ordered member tuple.  Order is load-bearing:
  leader selection is ``replicas[view % n]``, and a replacement takes
  the slot of the replica it replaces (:meth:`replace`) so the
  view→leader mapping is disturbed as little as possible.

Epoch switches are *agreed*, not broadcast: the control plane
(:meth:`repro.core.smr.Cluster.replace_replica`) routes the epoch bump
through a consensus slot (a MEMBERSHIP request), so every honest replica
applies the same switch at the same point of its execution order — see
``DESIGN_MEMBERSHIP.md`` for the safety argument.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class MembershipEpoch:
    """One epoch of the replica group: (epoch number, ordered members)."""

    epoch: int
    replicas: Tuple[str, ...]

    def __post_init__(self):
        if len(set(self.replicas)) != len(self.replicas):
            raise ValueError(f"duplicate replica pid in {self.replicas!r}")

    # ------------------------------------------------------------- derived
    @property
    def n(self) -> int:
        return len(self.replicas)

    @property
    def f(self) -> int:
        """Byzantine budget implied by n = 2f+1."""
        return (len(self.replicas) - 1) // 2

    @property
    def quorum(self) -> int:
        return self.f + 1

    def leader(self, view: int) -> str:
        return self.replicas[view % len(self.replicas)]

    def __contains__(self, pid: str) -> bool:
        return pid in self.replicas

    # ------------------------------------------------------------- evolve
    def replace(self, old: str, new: str) -> "MembershipEpoch":
        """The next epoch with ``new`` in ``old``'s slot (index preserved,
        so the view→leader mapping only changes where it must)."""
        if old not in self.replicas:
            raise ValueError(f"{old!r} is not a member of epoch {self.epoch}")
        if new in self.replicas:
            raise ValueError(f"{new!r} is already a member of epoch "
                             f"{self.epoch}")
        members = tuple(new if r == old else r for r in self.replicas)
        return MembershipEpoch(self.epoch + 1, members)
