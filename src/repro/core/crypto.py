"""Simulation-faithful cryptography (DESIGN.md §3).

The paper uses Ed25519 (dalek), BLAKE3 HMACs and xxHash checksums.  Inside the
simulation we need the *semantics* — unforgeability, transferable
authentication, collision-resistant fingerprints — with a calibrated cost
model (the costs live in ``repro.sim.net.NetParams``; timing is applied by the
protocol layer, these functions are pure).

Unforgeability discipline: secrets live privately inside :class:`Signer`
objects; a process (including Byzantine test adversaries) is only ever handed
its *own* Signer.  ``KeyRegistry.verify`` recomputes the MAC from its private
secret table — it plays the role of "the math", not of a trusted process.
Adversary code in tests never touches the registry internals, so signatures
are unforgeable by construction.
"""

from __future__ import annotations

import hashlib
import hmac
import struct
import zlib
from dataclasses import dataclass, is_dataclass, fields
from typing import Any, Dict, Optional, Tuple

SIG_SIZE = 64        # wire size of an Ed25519 signature
FINGERPRINT_SIZE = 32  # BLAKE3-style 256-bit digest
CHECKSUM_SIZE = 8    # xxHash64


def fingerprint(data: bytes) -> bytes:
    """Collision-resistant 32 B digest (stands in for BLAKE3)."""
    return hashlib.sha256(data).digest()


def checksum(data: bytes) -> int:
    """Fast 8-byte checksum (stands in for xxHash64)."""
    return (zlib.crc32(data) << 32) | (zlib.crc32(data[::-1]) & 0xFFFFFFFF)


def checksum_bytes(data: bytes) -> bytes:
    return struct.pack("<Q", checksum(data) & 0xFFFFFFFFFFFFFFFF)


def encode(obj: Any) -> bytes:
    """Canonical deterministic encoding of protocol payloads."""
    return _enc(obj)


def _enc(obj: Any) -> bytes:
    if obj is None:
        return b"N"
    if isinstance(obj, bool):
        return b"B" + (b"1" if obj else b"0")
    if isinstance(obj, int):
        return b"I" + struct.pack("<q", obj)
    if isinstance(obj, float):
        return b"F" + struct.pack("<d", obj)
    if isinstance(obj, bytes):
        return b"Y" + struct.pack("<I", len(obj)) + obj
    if isinstance(obj, str):
        b = obj.encode()
        return b"S" + struct.pack("<I", len(b)) + b
    if isinstance(obj, (tuple, list)):
        inner = b"".join(_enc(x) for x in obj)
        return b"T" + struct.pack("<I", len(obj)) + inner
    if isinstance(obj, dict):
        items = sorted(obj.items(), key=lambda kv: repr(kv[0]))
        inner = b"".join(_enc(k) + _enc(v) for k, v in items)
        return b"D" + struct.pack("<I", len(items)) + inner
    if is_dataclass(obj):
        inner = b"".join(_enc(getattr(obj, f.name)) for f in fields(obj))
        name = type(obj).__name__.encode()
        return b"C" + struct.pack("<I", len(name)) + name + inner
    raise TypeError(f"cannot encode {type(obj)!r}")


def decode(data: bytes) -> Any:
    """Inverse of :func:`encode` for the container/scalar subset."""
    obj, off = _dec(data, 0)
    if off != len(data):
        raise ValueError("trailing bytes in encoded payload")
    return obj


def decode_tuple3(data: bytes) -> Tuple[Any, Any, Any]:
    obj = decode(data)
    if not (isinstance(obj, tuple) and len(obj) == 3):
        raise ValueError("bad 3-tuple payload")
    return obj


def _dec(data: bytes, off: int):
    tag = data[off:off + 1]
    off += 1
    if tag == b"N":
        return None, off
    if tag == b"B":
        return data[off:off + 1] == b"1", off + 1
    if tag == b"I":
        return struct.unpack_from("<q", data, off)[0], off + 8
    if tag == b"F":
        return struct.unpack_from("<d", data, off)[0], off + 8
    if tag == b"Y":
        ln = struct.unpack_from("<I", data, off)[0]
        return data[off + 4:off + 4 + ln], off + 4 + ln
    if tag == b"S":
        ln = struct.unpack_from("<I", data, off)[0]
        return data[off + 4:off + 4 + ln].decode(), off + 4 + ln
    if tag == b"T":
        n = struct.unpack_from("<I", data, off)[0]
        off += 4
        items = []
        for _ in range(n):
            it, off = _dec(data, off)
            items.append(it)
        return tuple(items), off
    raise ValueError(f"bad tag {tag!r}")


def wire_size(obj: Any) -> int:
    """Estimated wire size in bytes of a protocol payload."""
    if obj is None:
        return 1
    if isinstance(obj, bool):
        return 1
    if isinstance(obj, (int, float)):
        return 8
    if isinstance(obj, bytes):
        return len(obj)
    if isinstance(obj, str):
        return len(obj)
    if isinstance(obj, (tuple, list)):
        return 4 + sum(wire_size(x) for x in obj)
    if isinstance(obj, dict):
        return 4 + sum(wire_size(k) + wire_size(v) for k, v in obj.items())
    if is_dataclass(obj):
        return 8 + sum(wire_size(getattr(obj, f.name)) for f in fields(obj))
    raise TypeError(f"cannot size {type(obj)!r}")


#: per-request framing inside a batched slot: rid + client id + length header
REQUEST_WIRE_OVERHEAD = 16


def batch_wire_size(batch: Any) -> int:
    """Wire size of a batched consensus payload (a tuple of request
    triples): every coalesced request pays its own framing overhead on top
    of its recursive payload size, so the cost model prices batches
    honestly rather than treating a batch as one flat blob."""
    return 4 + sum(wire_size(r) + REQUEST_WIRE_OVERHEAD for r in batch)


class Signer:
    """Holds a private key; the only way to produce this pid's signatures."""

    def __init__(self, pid: str, secret: bytes):
        self.pid = pid
        self.__secret = secret

    def sign(self, payload: Any) -> bytes:
        data = encode(payload)
        mac = hmac.new(self.__secret, data, hashlib.sha256).digest()
        return mac + mac  # pad to 64 B like Ed25519


class KeyRegistry:
    """Public-key infrastructure stand-in (pre-published public keys)."""

    def __init__(self) -> None:
        self._secrets: Dict[str, bytes] = {}

    def keygen(self, pid: str) -> Signer:
        secret = hashlib.sha256(b"key:" + pid.encode()).digest()
        self._secrets[pid] = secret
        return Signer(pid, secret)

    def verify(self, pid: str, payload: Any, sig: bytes) -> bool:
        secret = self._secrets.get(pid)
        if secret is None or sig is None:
            return False
        data = encode(payload)
        mac = hmac.new(secret, data, hashlib.sha256).digest()
        return hmac.compare_digest(mac + mac, sig)


@dataclass(frozen=True)
class SignedBundle:
    """A payload with f+1 signatures from distinct processes (a certificate)."""
    payload: Any
    sigs: Tuple[Tuple[str, bytes], ...]  # ((pid, sig), ...)

    def verify(self, registry: KeyRegistry, quorum: int) -> bool:
        pids = {pid for pid, _ in self.sigs}
        if len(pids) < quorum:
            return False
        return all(registry.verify(pid, self.payload, sig) for pid, sig in self.sigs)
