"""Simulation-faithful cryptography (DESIGN.md §3).

The paper uses Ed25519 (dalek), BLAKE3 HMACs and xxHash checksums.  Inside the
simulation we need the *semantics* — unforgeability, transferable
authentication, collision-resistant fingerprints — with a calibrated cost
model (the costs live in ``repro.sim.net.NetParams``; timing is applied by the
protocol layer, these functions are pure).

Unforgeability discipline: secrets live privately inside :class:`Signer`
objects; a process (including Byzantine test adversaries) is only ever handed
its *own* Signer.  ``KeyRegistry.verify`` recomputes the MAC from its private
secret table — it plays the role of "the math", not of a trusted process.
Adversary code in tests never touches the registry internals, so signatures
are unforgeable by construction.
"""

from __future__ import annotations

import hashlib
import hmac
import struct
import zlib
from dataclasses import dataclass, is_dataclass, fields
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as _np

SIG_SIZE = 64        # wire size of an Ed25519 signature
FINGERPRINT_SIZE = 32  # BLAKE3-style 256-bit digest
CHECKSUM_SIZE = 8    # xxHash64


# ---------------------------------------------------------------------------
# Digest-path observability
# ---------------------------------------------------------------------------
# Module-global counters (plain ints: an increment is the cheapest thing
# Python can do, and these sit on the hottest paths in the repo).  Surfaced
# through Cluster.stats()["engine"] so benchmarks can prove the batched
# path is actually taken, not merely available.

_wire_hits = 0          # _entry() found a live cache entry
_wire_misses = 0        # _entry() had to create one
_fp_scalar = 0          # SHA-256 digests computed one at a time
_fp_batch_calls = 0     # fingerprint_batch() invocations
_fp_batch_items = 0     # messages digested through the batch API
_fp_batch_hits = 0      # batch items answered from the wire cache
_ck_scalar = 0          # checksums computed one at a time
_ck_batch_calls = 0
_ck_batch_items = 0
_mac_scalar = 0         # HMACs computed one at a time (sign + verify)
_mac_batch_calls = 0
_mac_batch_items = 0


def digest_stats() -> Dict[str, int]:
    """Snapshot of the wire-cache / digest-path counters."""
    return {
        "wire_cache_hits": _wire_hits,
        "wire_cache_misses": _wire_misses,
        "scalar_fingerprints": _fp_scalar,
        "batch_fingerprint_calls": _fp_batch_calls,
        "batch_fingerprint_items": _fp_batch_items,
        "batch_fingerprint_hits": _fp_batch_hits,
        "scalar_checksums": _ck_scalar,
        "batch_checksum_calls": _ck_batch_calls,
        "batch_checksum_items": _ck_batch_items,
        "scalar_macs": _mac_scalar,
        "batch_mac_calls": _mac_batch_calls,
        "batch_mac_items": _mac_batch_items,
    }


def reset_digest_stats() -> None:
    global _wire_hits, _wire_misses, _fp_scalar, _fp_batch_calls, \
        _fp_batch_items, _fp_batch_hits, _ck_scalar, _ck_batch_calls, \
        _ck_batch_items, _mac_scalar, _mac_batch_calls, _mac_batch_items
    _wire_hits = _wire_misses = _fp_scalar = 0
    _fp_batch_calls = _fp_batch_items = _fp_batch_hits = 0
    _ck_scalar = _ck_batch_calls = _ck_batch_items = 0
    _mac_scalar = _mac_batch_calls = _mac_batch_items = 0


def fingerprint(data: bytes) -> bytes:
    """Collision-resistant 32 B digest (stands in for BLAKE3)."""
    global _fp_scalar
    _fp_scalar += 1
    return hashlib.sha256(data).digest()


#: suffix fed to the second CRC pass of :func:`checksum` — continuing the
#: running CRC over a fixed salt decorrelates the two words without the
#: O(n) reversed copy the old ``data[::-1]`` pass required.
_CHECKSUM_SALT = b"\xa5ubft\x5a"


def checksum(data: bytes) -> int:
    """Fast 8-byte checksum (stands in for xxHash64): the plain CRC32 in
    the high word and a salted continuation of it in the low word —
    single pass over ``data``, no copies."""
    global _ck_scalar
    _ck_scalar += 1
    hi = zlib.crc32(data)
    return (hi << 32) | zlib.crc32(_CHECKSUM_SALT, hi)


def checksum_bytes(data: bytes) -> bytes:
    return struct.pack("<Q", checksum(data) & 0xFFFFFFFFFFFFFFFF)


def encode(obj: Any) -> bytes:
    """Canonical deterministic encoding of protocol payloads.

    This is the *fresh* (uncached) computation; the hot path goes through
    :func:`encode_cached`, which must always agree with it (enforced by a
    property test).
    """
    return _enc(obj)


# ---------------------------------------------------------------------------
# Zero-re-encode wire layer (DESIGN.md "wire-cache invariant")
# ---------------------------------------------------------------------------
# Protocol payloads are immutable tuples (and bytes) passed *by reference*
# through the simulator, so a payload can be encoded / fingerprinted /
# sized once per lifetime and every later touch — the sender's retransmits,
# every receiver, every verify — reuses that result.  The cache is
# identity-keyed: each entry holds a strong reference to its payload, which
# pins the id() for the entry's lifetime (no aliasing is possible while the
# entry lives).  Two generations bound memory: inserts go to the young
# generation; when it fills, it becomes the old generation and the previous
# old generation (entries unreferenced for a full cycle) is dropped.
#
# Only immutable containers (tuple, bytes) are cached.  Lists, dicts and
# dataclasses always re-encode — mutating *those* after send is therefore
# visible, while the discipline for tuples/bytes is: a payload handed to
# ``Node.send`` / ``TBcastService.broadcast`` must never be mutated
# afterwards (Byzantine test adversaries included — build a new tuple
# instead).  Receiver-side reuse does not weaken unforgeability: the
# KeyRegistry still recomputes MACs from its private secret table; the
# cache only memoizes the *public* deterministic encoding.

_CACHE_LIMIT = 1 << 16
# id(obj) -> [obj, enc|None, fp|None, size|None, deeply_immutable|None]
_g0: Dict[int, list] = {}
_g1: Dict[int, list] = {}

#: scalar types that are safe to memoize beneath a cached tuple
_PURE_SCALARS = (int, float, str, bool, type(None))


def _entry(obj: Any) -> list:
    global _g0, _g1, _wire_hits, _wire_misses
    key = id(obj)
    e = _g0.get(key)
    if e is not None:
        _wire_hits += 1
        return e
    e = _g1.get(key)
    if e is not None:
        _wire_hits += 1
        _g0[key] = e        # promote: survived a generation
        return e
    _wire_misses += 1
    if len(_g0) >= _CACHE_LIMIT:
        _g1 = _g0
        _g0 = {}
    e = [obj, None, None, None, None]
    _g0[key] = e
    return e


def _pure(obj: Any) -> bool:
    """True iff ``obj`` is deeply immutable (tuples of tuples/bytes/
    scalars).  A tuple with a list/dict/dataclass anywhere beneath it must
    never be memoized — mutating that child has to stay visible."""
    if type(obj) is tuple:
        e = _entry(obj)
        p = e[4]
        if p is None:
            p = e[4] = all(_pure(x) for x in obj)
        return p
    return type(obj) is bytes or isinstance(obj, _PURE_SCALARS)


def clear_wire_cache() -> None:
    """Drop all memoized encodings (tests / long-lived drivers)."""
    global _g0, _g1
    _g0 = {}
    _g1 = {}


def wire_cache_len() -> int:
    return len(_g0) + len(_g1)


def _enc_c(obj: Any) -> bytes:
    """Cache-aware mirror of :func:`_enc` — identical bytes, but deeply
    immutable tuple subtrees are memoized so shared payloads encode once.
    Tuples with mutable descendants (a COMMIT's cert dict, NEW_VIEW's cert
    map) re-encode every time, keeping child mutation visible."""
    if type(obj) is tuple:
        e = _entry(obj)
        v = e[1]
        if v is None:
            v = (b"T" + struct.pack("<I", len(obj)) +
                 b"".join(_enc_c(x) for x in obj))
            if _pure(obj):
                e[1] = v
        return v
    return _enc(obj)


def encode_cached(obj: Any) -> bytes:
    """Memoized :func:`encode` for immutable payloads (tuples / bytes);
    falls through to a fresh encode for anything else."""
    if type(obj) is tuple:
        return _enc_c(obj)
    if type(obj) is bytes:
        e = _entry(obj)
        v = e[1]
        if v is None:
            v = e[1] = _enc(obj)
        return v
    return _enc(obj)


def encode_shallow(obj: Any) -> bytes:
    """Encode a freshly-built wrapper without caching the wrapper itself:
    tuple *children* (the shared subtrees) still go through the memo.
    Signature payloads are built fresh per sign/verify call, so caching
    them would be all misses."""
    if type(obj) is tuple:
        return (b"T" + struct.pack("<I", len(obj)) +
                b"".join(_enc_c(x) for x in obj))
    return _enc(obj)


def fingerprint_cached(obj: Any) -> bytes:
    """Memoized ``fingerprint(encode(obj))`` — the protocol-layer digest."""
    global _fp_scalar
    if type(obj) is tuple or type(obj) is bytes:
        e = _entry(obj)
        v = e[2]
        if v is None:
            _fp_scalar += 1
            v = hashlib.sha256(encode_cached(obj)).digest()
            if _pure(obj):
                e[2] = v
        return v
    _fp_scalar += 1
    return hashlib.sha256(_enc(obj)).digest()


def fingerprint_fresh(obj: Any) -> bytes:
    """``fingerprint(encode(obj))`` with no memoization anywhere on the
    wrapper path: for one-shot wrapper tuples (summary digests, ballot
    wrappers) whose top levels never recur, inserting them into the wire
    cache is pure churn — this digests through the plain recursive
    encoder instead.  Byte-identical to the cached/fresh variants."""
    global _fp_scalar
    _fp_scalar += 1
    return hashlib.sha256(_enc(obj)).digest()


def _enc(obj: Any) -> bytes:
    if obj is None:
        return b"N"
    if isinstance(obj, bool):
        return b"B" + (b"1" if obj else b"0")
    if isinstance(obj, int):
        return b"I" + struct.pack("<q", obj)
    if isinstance(obj, float):
        return b"F" + struct.pack("<d", obj)
    if isinstance(obj, bytes):
        return b"Y" + struct.pack("<I", len(obj)) + obj
    if isinstance(obj, str):
        b = obj.encode()
        return b"S" + struct.pack("<I", len(b)) + b
    if isinstance(obj, (tuple, list)):
        inner = b"".join(_enc(x) for x in obj)
        return b"T" + struct.pack("<I", len(obj)) + inner
    if isinstance(obj, dict):
        items = sorted(obj.items(), key=lambda kv: repr(kv[0]))
        inner = b"".join(_enc(k) + _enc(v) for k, v in items)
        return b"D" + struct.pack("<I", len(items)) + inner
    if is_dataclass(obj):
        inner = b"".join(_enc(getattr(obj, f.name)) for f in fields(obj))
        name = type(obj).__name__.encode()
        return b"C" + struct.pack("<I", len(name)) + name + inner
    raise TypeError(f"cannot encode {type(obj)!r}")


def decode(data: bytes) -> Any:
    """Inverse of :func:`encode` for the container/scalar subset."""
    obj, off = _dec(data, 0)
    if off != len(data):
        raise ValueError("trailing bytes in encoded payload")
    return obj


def decode_tuple3(data: bytes) -> Tuple[Any, Any, Any]:
    obj = decode(data)
    if not (isinstance(obj, tuple) and len(obj) == 3):
        raise ValueError("bad 3-tuple payload")
    return obj


def _dec(data: bytes, off: int):
    tag = data[off:off + 1]
    off += 1
    if tag == b"N":
        return None, off
    if tag == b"B":
        return data[off:off + 1] == b"1", off + 1
    if tag == b"I":
        return struct.unpack_from("<q", data, off)[0], off + 8
    if tag == b"F":
        return struct.unpack_from("<d", data, off)[0], off + 8
    if tag == b"Y":
        ln = struct.unpack_from("<I", data, off)[0]
        return data[off + 4:off + 4 + ln], off + 4 + ln
    if tag == b"S":
        ln = struct.unpack_from("<I", data, off)[0]
        return data[off + 4:off + 4 + ln].decode(), off + 4 + ln
    if tag == b"T":
        n = struct.unpack_from("<I", data, off)[0]
        off += 4
        items = []
        for _ in range(n):
            it, off = _dec(data, off)
            items.append(it)
        return tuple(items), off
    raise ValueError(f"bad tag {tag!r}")


def wire_size(obj: Any) -> int:
    """Estimated wire size in bytes of a protocol payload (fresh
    computation; the hot path uses :func:`wire_size_cached`)."""
    if obj is None:
        return 1
    if isinstance(obj, bool):
        return 1
    if isinstance(obj, (int, float)):
        return 8
    if isinstance(obj, bytes):
        return len(obj)
    if isinstance(obj, str):
        return len(obj)
    if isinstance(obj, (tuple, list)):
        return 4 + sum(wire_size(x) for x in obj)
    if isinstance(obj, dict):
        return 4 + sum(wire_size(k) + wire_size(v) for k, v in obj.items())
    if is_dataclass(obj):
        return 8 + sum(wire_size(getattr(obj, f.name)) for f in fields(obj))
    raise TypeError(f"cannot size {type(obj)!r}")


def wire_size_cached(obj: Any) -> int:
    """Memoized :func:`wire_size`: tuple subtrees are sized once, so a
    fresh wrapper around a shared payload costs O(shallow fields)."""
    if type(obj) is tuple:
        e = _entry(obj)
        v = e[3]
        if v is None:
            v = 4 + sum(wire_size_cached(x) for x in obj)
            if _pure(obj):
                e[3] = v
        return v
    return wire_size(obj)


def wire_size_shallow(obj: Any) -> int:
    """Size a message body without inserting it into the cache: scalar
    fields are priced inline and only *nested tuples* (the shared payload
    subtrees that actually recur — batches, certs, window contents) go
    through the memo.  ``Node.send`` wraps every message in a fresh tuple,
    so caching the wrapper itself would be all misses."""
    if type(obj) is not tuple:
        return wire_size_cached(obj)
    n = 4
    for x in obj:
        t = type(x)
        if t is tuple:
            n += wire_size_cached(x)
        elif t is int or t is float:
            n += 8
        elif t is bytes or t is str:
            n += len(x)
        elif x is None or t is bool:
            n += 1
        else:
            n += wire_size(x)
    return n


#: per-request framing inside a batched slot: rid + client id + length header
REQUEST_WIRE_OVERHEAD = 16


def batch_wire_size(batch: Any) -> int:
    """Wire size of a batched consensus payload (a tuple of request
    triples): every coalesced request pays its own framing overhead on top
    of its recursive payload size, so the cost model prices batches
    honestly rather than treating a batch as one flat blob."""
    return 4 + sum(wire_size_cached(r) + REQUEST_WIRE_OVERHEAD for r in batch)


def wire_size_batch(objs: Sequence[Any]) -> List[int]:
    """Batch :func:`wire_size_cached` — one audited entry point for call
    sites that size a run of payloads at once (TBcast retransmission
    sweeps), so batching shows up in profiles as one frame."""
    ws = wire_size_cached
    return [ws(o) for o in objs]


def encode_batch_cached(objs: Sequence[Any]) -> List[bytes]:
    """Batch :func:`encode_cached` (CTBcast unanimity fallback compares a
    run of diverging LOCKED slots in one pass)."""
    enc = encode_cached
    return [enc(o) for o in objs]


# ---------------------------------------------------------------------------
# Batched digests (ROADMAP item 3a)
# ---------------------------------------------------------------------------
# The protocol layer digests messages in *runs* — a certify window of t
# fingerprints, a read quorum's 2q checksum blobs, a slot's n-way MAC check —
# and at that volume the per-call Python dispatch costs as much as the
# digest.  The batch APIs below share one dispatch across a run and are
# byte-identical to mapping their scalar counterparts (property-tested in
# tests/test_batch_engine.py).
#
# SHA-256 has two compute backends:
#   * "hashlib" — one C call per lane; fastest below ~2k one-block lanes.
#   * "numpy"   — a lane-wise vectorization of the compression function
#     over the 32-bit word representation: each lane is one (padded)
#     message, and all 64 rounds run across the whole batch per block.
#     Wins only for very large batches of short messages; it exists so
#     the equivalence contract has a vectorized witness and so wide
#     attestation sweeps have a non-serial path.
# ``backend=None`` picks by batch size.  The device-attestation digest
# (Weyl reduce, repro.runtime.attest) additionally has the
# kernels/fingerprint.py Pallas kernel as a selectable backend — see
# :func:`attest_batch`.

_U32 = _np.uint32

_SHA256_H0 = _np.array(
    [0x6A09E667, 0xBB67AE85, 0x3C6EF372, 0xA54FF53A,
     0x510E527F, 0x9B05688C, 0x1F83D9AB, 0x5BE0CD19], dtype=_np.uint32)

_SHA256_K = _np.array(
    [0x428A2F98, 0x71374491, 0xB5C0FBCF, 0xE9B5DBA5, 0x3956C25B, 0x59F111F1,
     0x923F82A4, 0xAB1C5ED5, 0xD807AA98, 0x12835B01, 0x243185BE, 0x550C7DC3,
     0x72BE5D74, 0x80DEB1FE, 0x9BDC06A7, 0xC19BF174, 0xE49B69C1, 0xEFBE4786,
     0x0FC19DC6, 0x240CA1CC, 0x2DE92C6F, 0x4A7484AA, 0x5CB0A9DC, 0x76F988DA,
     0x983E5152, 0xA831C66D, 0xB00327C8, 0xBF597FC7, 0xC6E00BF3, 0xD5A79147,
     0x06CA6351, 0x14292967, 0x27B70A85, 0x2E1B2138, 0x4D2C6DFC, 0x53380D13,
     0x650A7354, 0x766A0ABB, 0x81C2C92E, 0x92722C85, 0xA2BFE8A1, 0xA81A664B,
     0xC24B8B70, 0xC76C51A3, 0xD192E819, 0xD6990624, 0xF40E3585, 0x106AA070,
     0x19A4C116, 0x1E376C08, 0x2748774C, 0x34B0BCB5, 0x391C0CB3, 0x4ED8AA4A,
     0x5B9CCA4F, 0x682E6FF3, 0x748F82EE, 0x78A5636F, 0x84C87814, 0x8CC70208,
     0x90BEFFFA, 0xA4506CEB, 0xBEF9A3F7, 0xC67178F2], dtype=_np.uint32)


def _rotr(x: _np.ndarray, n: int) -> _np.ndarray:
    return (x >> _U32(n)) | (x << _U32(32 - n))


def _sha256_batch_np(datas: Sequence[bytes]) -> List[bytes]:
    """Lane-wise vectorized SHA-256: digest ``n`` messages at once.

    Each lane holds one message, padded per FIPS 180-4 into its own block
    run; every round of the compression function executes across all lanes
    as uint32 array ops (silent mod-2**32 wraparound is exactly the
    arithmetic SHA-256 wants).  Lanes whose messages need fewer blocks
    freeze their state once their last block is folded in.  Byte-identical
    to ``hashlib.sha256`` (property-tested)."""
    n = len(datas)
    if n == 0:
        return []
    lens = [len(d) for d in datas]
    nblk = _np.array([(ln + 8) // 64 + 1 for ln in lens], dtype=_np.int64)
    maxb = int(nblk.max())
    buf = _np.zeros((n, maxb * 64), dtype=_np.uint8)
    for i, d in enumerate(datas):
        ln = lens[i]
        if ln:
            buf[i, :ln] = _np.frombuffer(d, dtype=_np.uint8)
        buf[i, ln] = 0x80
        end = int(nblk[i]) * 64
        buf[i, end - 8:end] = _np.frombuffer(
            struct.pack(">Q", ln * 8), dtype=_np.uint8)
    w8 = buf.reshape(n, maxb * 16, 4).astype(_np.uint32)
    w32 = ((w8[:, :, 0] << _U32(24)) | (w8[:, :, 1] << _U32(16)) |
           (w8[:, :, 2] << _U32(8)) | w8[:, :, 3]).reshape(n, maxb, 16)
    state = _np.tile(_SHA256_H0, (n, 1))
    W = _np.empty((n, 64), dtype=_np.uint32)
    for b in range(maxb):
        W[:, :16] = w32[:, b]
        for t in range(16, 64):
            w15 = W[:, t - 15]
            w2 = W[:, t - 2]
            s0 = _rotr(w15, 7) ^ _rotr(w15, 18) ^ (w15 >> _U32(3))
            s1 = _rotr(w2, 17) ^ _rotr(w2, 19) ^ (w2 >> _U32(10))
            W[:, t] = W[:, t - 16] + s0 + W[:, t - 7] + s1
        a, bv, c, d = (state[:, j].copy() for j in range(4))
        e, f, g, h = (state[:, j].copy() for j in range(4, 8))
        for t in range(64):
            s1 = _rotr(e, 6) ^ _rotr(e, 11) ^ _rotr(e, 25)
            ch = (e & f) ^ (~e & g)
            t1 = h + s1 + ch + _SHA256_K[t] + W[:, t]
            s0 = _rotr(a, 2) ^ _rotr(a, 13) ^ _rotr(a, 22)
            maj = (a & bv) ^ (a & c) ^ (bv & c)
            t2 = s0 + maj
            h = g
            g = f
            f = e
            e = d + t1
            d = c
            c = bv
            bv = a
            a = t1 + t2
        folded = state + _np.stack((a, bv, c, d, e, f, g, h), axis=1)
        if b == 0:
            state = folded
        else:
            state = _np.where((nblk > b)[:, None], folded, state)
    raw = state.astype(">u4").tobytes()
    return [raw[i * 32:(i + 1) * 32] for i in range(n)]


#: below this many lanes hashlib's per-message C call beats the numpy
#: round-loop's fixed vector-dispatch cost (measured crossover ~2k
#: one-block lanes; kept conservative)
_SHA_NUMPY_MIN_LANES = 2048


def fingerprint_batch(datas: Sequence[bytes],
                      backend: Optional[str] = None) -> List[bytes]:
    """Batch :func:`fingerprint`: digests for a run of encoded messages.

    Byte-identical to ``[fingerprint(d) for d in datas]`` on every
    backend.  ``backend`` is ``"hashlib"``, ``"numpy"``, or ``None`` to
    pick by batch size."""
    global _fp_batch_calls, _fp_batch_items
    _fp_batch_calls += 1
    _fp_batch_items += len(datas)
    if backend is None:
        backend = ("numpy" if len(datas) >= _SHA_NUMPY_MIN_LANES
                   else "hashlib")
    if backend == "hashlib":
        sha = hashlib.sha256
        return [sha(d).digest() for d in datas]
    if backend == "numpy":
        return _sha256_batch_np(datas)
    raise ValueError(f"unknown fingerprint backend {backend!r}")


def fingerprint_batch_cached(objs: Sequence[Any]) -> List[bytes]:
    """Batch :func:`fingerprint_cached`: one pass collects memoized
    digests, the misses are digested through :func:`fingerprint_batch`,
    and pure misses are written back — so overlapping batches (sliding
    certify windows) converge to all-hits."""
    global _fp_batch_hits
    out: List[Optional[bytes]] = [None] * len(objs)
    miss_i: List[int] = []
    miss_e: List[Optional[list]] = []
    miss_d: List[bytes] = []
    for i, obj in enumerate(objs):
        if type(obj) is tuple or type(obj) is bytes:
            e = _entry(obj)
            v = e[2]
            if v is None:
                miss_i.append(i)
                miss_e.append(e)
                miss_d.append(encode_cached(obj))
            else:
                out[i] = v
        else:
            miss_i.append(i)
            miss_e.append(None)
            miss_d.append(_enc(obj))
    _fp_batch_hits += len(objs) - len(miss_i)
    if miss_i:
        for i, e, dg in zip(miss_i, miss_e, fingerprint_batch(miss_d)):
            out[i] = dg
            if e is not None and _pure(e[0]):
                e[2] = dg
    return out  # type: ignore[return-value]


def checksum_batch(datas: Sequence[bytes]) -> List[int]:
    """Batch :func:`checksum` for a run of blobs (a read quorum's
    sub-register pairs).  CRC32 is already one C call per blob; the batch
    form amortizes the Python dispatch and keeps the loop in one frame.
    (A lane-wise numpy CRC needs a table gather per byte *position* —
    measured slower than zlib's C loop below several hundred lanes, so it
    earns no backend here.)"""
    global _ck_batch_calls, _ck_batch_items
    _ck_batch_calls += 1
    _ck_batch_items += len(datas)
    crc = zlib.crc32
    salt = _CHECKSUM_SALT
    out: List[int] = []
    append = out.append
    for d in datas:
        hi = crc(d)
        append((hi << 32) | crc(salt, hi))
    return out


def checksum_bytes_batch(datas: Sequence[bytes]) -> List[bytes]:
    pack = struct.pack
    return [pack("<Q", c & 0xFFFFFFFFFFFFFFFF)
            for c in checksum_batch(datas)]


# -- device attestation (Weyl reduce; matches repro.runtime.attest) ---------

MIX32 = 0x9E3779B9  # golden-ratio Weyl constant


def attest_words_np(words: Any) -> int:
    """Numpy reference of the Pallas fingerprint kernel
    (repro.kernels.fingerprint): order-independent per-word Weyl mix
    summed mod 2**32.  Block structure is irrelevant to a plain sum, so
    this matches the kernel for every block size and padding (zero words
    mix to zero)."""
    w = _np.asarray(words, dtype=_np.uint32).ravel()
    w = w * _U32(MIX32) ^ (w >> _U32(16))
    return int(w.sum(dtype=_np.uint32))


def attest_batch(arrays: Sequence[Any], backend: str = "numpy") -> List[int]:
    """Attestation digests for a batch of word arrays.

    ``backend="numpy"`` runs the reference reduction; ``backend="pallas"``
    runs ``repro.kernels.fingerprint.fingerprint_pallas`` (interpret mode
    on CPU — the same kernel compiles for TPU), so accelerator
    deployments hand the reduction to the data plane while the simulator
    stays numpy-only.  Both backends produce identical uint32 digests
    (parity-tested in tests/test_batch_engine.py)."""
    if backend == "numpy":
        return [attest_words_np(a) for a in arrays]
    if backend == "pallas":
        from repro.kernels.fingerprint import fingerprint_pallas
        import jax.numpy as jnp
        out: List[int] = []
        for a in arrays:
            w = _np.asarray(a, dtype=_np.uint32).ravel()
            if w.size == 0:
                out.append(0)  # empty shard: sum of no words
                continue
            out.append(int(fingerprint_pallas(jnp.asarray(w))[0]))
        return out
    raise ValueError(f"unknown attest backend {backend!r}")


class Signer:
    """Holds a private key; the only way to produce this pid's signatures."""

    def __init__(self, pid: str, secret: bytes):
        self.pid = pid
        self.__secret = secret

    def sign(self, payload: Any) -> bytes:
        global _mac_scalar
        _mac_scalar += 1
        data = encode_shallow(payload)
        mac = hmac.new(self.__secret, data, hashlib.sha256).digest()
        return mac + mac  # pad to 64 B like Ed25519

    def sign_batch(self, payloads: Sequence[Any]) -> List[bytes]:
        """Batch :meth:`sign`: one dispatch for a run of signatures
        (element-wise identical to mapping ``sign``).  The secret never
        leaves the loop body."""
        global _mac_batch_calls, _mac_batch_items
        _mac_batch_calls += 1
        _mac_batch_items += len(payloads)
        secret = self.__secret
        new = hmac.new
        sha = hashlib.sha256
        enc = encode_shallow
        out: List[bytes] = []
        append = out.append
        for p in payloads:
            mac = new(secret, enc(p), sha).digest()
            append(mac + mac)
        return out


class KeyRegistry:
    """Public-key infrastructure stand-in (pre-published public keys)."""

    def __init__(self) -> None:
        self._secrets: Dict[str, bytes] = {}

    def keygen(self, pid: str) -> Signer:
        secret = hashlib.sha256(b"key:" + pid.encode()).digest()
        self._secrets[pid] = secret
        return Signer(pid, secret)

    def verify(self, pid: str, payload: Any, sig: bytes) -> bool:
        # Recomputes the MAC from the private secret table on every call —
        # memoizing the *encoding* is safe (it is public and deterministic),
        # memoizing the verdict would not model "the math".
        global _mac_scalar
        _mac_scalar += 1
        secret = self._secrets.get(pid)
        if secret is None or sig is None:
            return False
        data = encode_shallow(payload)
        mac = hmac.new(secret, data, hashlib.sha256).digest()
        return hmac.compare_digest(mac + mac, sig)

    def verify_batch(self, items: Iterable[Tuple[str, Any, bytes]]
                     ) -> List[bool]:
        """Batch :meth:`verify` over ``(pid, payload, sig)`` triples — one
        dispatch for a quorum's worth of MACs.  Every MAC is still
        recomputed from the private secret table exactly like ``verify``:
        batching shares the encoding work and the Python dispatch, never
        verdicts or secrets."""
        global _mac_batch_calls, _mac_batch_items
        _mac_batch_calls += 1
        secrets = self._secrets
        new = hmac.new
        sha = hashlib.sha256
        enc = encode_shallow
        eq = hmac.compare_digest
        out: List[bool] = []
        append = out.append
        n = 0
        for pid, payload, sig in items:
            n += 1
            secret = secrets.get(pid)
            if secret is None or sig is None:
                append(False)
                continue
            mac = new(secret, enc(payload), sha).digest()
            append(eq(mac + mac, sig))
        _mac_batch_items += n
        return out


@dataclass(frozen=True)
class SignedBundle:
    """A payload with f+1 signatures from distinct processes (a certificate)."""
    payload: Any
    sigs: Tuple[Tuple[str, bytes], ...]  # ((pid, sig), ...)

    def verify(self, registry: KeyRegistry, quorum: int) -> bool:
        pids = {pid for pid, _ in self.sigs}
        if len(pids) < quorum:
            return False
        return all(registry.verify(pid, self.payload, sig) for pid, sig in self.sigs)
