"""Simulation-faithful cryptography (DESIGN.md §3).

The paper uses Ed25519 (dalek), BLAKE3 HMACs and xxHash checksums.  Inside the
simulation we need the *semantics* — unforgeability, transferable
authentication, collision-resistant fingerprints — with a calibrated cost
model (the costs live in ``repro.sim.net.NetParams``; timing is applied by the
protocol layer, these functions are pure).

Unforgeability discipline: secrets live privately inside :class:`Signer`
objects; a process (including Byzantine test adversaries) is only ever handed
its *own* Signer.  ``KeyRegistry.verify`` recomputes the MAC from its private
secret table — it plays the role of "the math", not of a trusted process.
Adversary code in tests never touches the registry internals, so signatures
are unforgeable by construction.
"""

from __future__ import annotations

import hashlib
import hmac
import struct
import zlib
from dataclasses import dataclass, is_dataclass, fields
from typing import Any, Dict, Optional, Tuple

SIG_SIZE = 64        # wire size of an Ed25519 signature
FINGERPRINT_SIZE = 32  # BLAKE3-style 256-bit digest
CHECKSUM_SIZE = 8    # xxHash64


def fingerprint(data: bytes) -> bytes:
    """Collision-resistant 32 B digest (stands in for BLAKE3)."""
    return hashlib.sha256(data).digest()


#: suffix fed to the second CRC pass of :func:`checksum` — continuing the
#: running CRC over a fixed salt decorrelates the two words without the
#: O(n) reversed copy the old ``data[::-1]`` pass required.
_CHECKSUM_SALT = b"\xa5ubft\x5a"


def checksum(data: bytes) -> int:
    """Fast 8-byte checksum (stands in for xxHash64): the plain CRC32 in
    the high word and a salted continuation of it in the low word —
    single pass over ``data``, no copies."""
    hi = zlib.crc32(data)
    return (hi << 32) | zlib.crc32(_CHECKSUM_SALT, hi)


def checksum_bytes(data: bytes) -> bytes:
    return struct.pack("<Q", checksum(data) & 0xFFFFFFFFFFFFFFFF)


def encode(obj: Any) -> bytes:
    """Canonical deterministic encoding of protocol payloads.

    This is the *fresh* (uncached) computation; the hot path goes through
    :func:`encode_cached`, which must always agree with it (enforced by a
    property test).
    """
    return _enc(obj)


# ---------------------------------------------------------------------------
# Zero-re-encode wire layer (DESIGN.md "wire-cache invariant")
# ---------------------------------------------------------------------------
# Protocol payloads are immutable tuples (and bytes) passed *by reference*
# through the simulator, so a payload can be encoded / fingerprinted /
# sized once per lifetime and every later touch — the sender's retransmits,
# every receiver, every verify — reuses that result.  The cache is
# identity-keyed: each entry holds a strong reference to its payload, which
# pins the id() for the entry's lifetime (no aliasing is possible while the
# entry lives).  Two generations bound memory: inserts go to the young
# generation; when it fills, it becomes the old generation and the previous
# old generation (entries unreferenced for a full cycle) is dropped.
#
# Only immutable containers (tuple, bytes) are cached.  Lists, dicts and
# dataclasses always re-encode — mutating *those* after send is therefore
# visible, while the discipline for tuples/bytes is: a payload handed to
# ``Node.send`` / ``TBcastService.broadcast`` must never be mutated
# afterwards (Byzantine test adversaries included — build a new tuple
# instead).  Receiver-side reuse does not weaken unforgeability: the
# KeyRegistry still recomputes MACs from its private secret table; the
# cache only memoizes the *public* deterministic encoding.

_CACHE_LIMIT = 1 << 16
# id(obj) -> [obj, enc|None, fp|None, size|None, deeply_immutable|None]
_g0: Dict[int, list] = {}
_g1: Dict[int, list] = {}

#: scalar types that are safe to memoize beneath a cached tuple
_PURE_SCALARS = (int, float, str, bool, type(None))


def _entry(obj: Any) -> list:
    global _g0, _g1
    key = id(obj)
    e = _g0.get(key)
    if e is not None:
        return e
    e = _g1.get(key)
    if e is not None:
        _g0[key] = e        # promote: survived a generation
        return e
    if len(_g0) >= _CACHE_LIMIT:
        _g1 = _g0
        _g0 = {}
    e = [obj, None, None, None, None]
    _g0[key] = e
    return e


def _pure(obj: Any) -> bool:
    """True iff ``obj`` is deeply immutable (tuples of tuples/bytes/
    scalars).  A tuple with a list/dict/dataclass anywhere beneath it must
    never be memoized — mutating that child has to stay visible."""
    if type(obj) is tuple:
        e = _entry(obj)
        p = e[4]
        if p is None:
            p = e[4] = all(_pure(x) for x in obj)
        return p
    return type(obj) is bytes or isinstance(obj, _PURE_SCALARS)


def clear_wire_cache() -> None:
    """Drop all memoized encodings (tests / long-lived drivers)."""
    global _g0, _g1
    _g0 = {}
    _g1 = {}


def wire_cache_len() -> int:
    return len(_g0) + len(_g1)


def _enc_c(obj: Any) -> bytes:
    """Cache-aware mirror of :func:`_enc` — identical bytes, but deeply
    immutable tuple subtrees are memoized so shared payloads encode once.
    Tuples with mutable descendants (a COMMIT's cert dict, NEW_VIEW's cert
    map) re-encode every time, keeping child mutation visible."""
    if type(obj) is tuple:
        e = _entry(obj)
        v = e[1]
        if v is None:
            v = (b"T" + struct.pack("<I", len(obj)) +
                 b"".join(_enc_c(x) for x in obj))
            if _pure(obj):
                e[1] = v
        return v
    return _enc(obj)


def encode_cached(obj: Any) -> bytes:
    """Memoized :func:`encode` for immutable payloads (tuples / bytes);
    falls through to a fresh encode for anything else."""
    if type(obj) is tuple:
        return _enc_c(obj)
    if type(obj) is bytes:
        e = _entry(obj)
        v = e[1]
        if v is None:
            v = e[1] = _enc(obj)
        return v
    return _enc(obj)


def encode_shallow(obj: Any) -> bytes:
    """Encode a freshly-built wrapper without caching the wrapper itself:
    tuple *children* (the shared subtrees) still go through the memo.
    Signature payloads are built fresh per sign/verify call, so caching
    them would be all misses."""
    if type(obj) is tuple:
        return (b"T" + struct.pack("<I", len(obj)) +
                b"".join(_enc_c(x) for x in obj))
    return _enc(obj)


def fingerprint_cached(obj: Any) -> bytes:
    """Memoized ``fingerprint(encode(obj))`` — the protocol-layer digest."""
    if type(obj) is tuple or type(obj) is bytes:
        e = _entry(obj)
        v = e[2]
        if v is None:
            v = hashlib.sha256(encode_cached(obj)).digest()
            if _pure(obj):
                e[2] = v
        return v
    return hashlib.sha256(_enc(obj)).digest()


def _enc(obj: Any) -> bytes:
    if obj is None:
        return b"N"
    if isinstance(obj, bool):
        return b"B" + (b"1" if obj else b"0")
    if isinstance(obj, int):
        return b"I" + struct.pack("<q", obj)
    if isinstance(obj, float):
        return b"F" + struct.pack("<d", obj)
    if isinstance(obj, bytes):
        return b"Y" + struct.pack("<I", len(obj)) + obj
    if isinstance(obj, str):
        b = obj.encode()
        return b"S" + struct.pack("<I", len(b)) + b
    if isinstance(obj, (tuple, list)):
        inner = b"".join(_enc(x) for x in obj)
        return b"T" + struct.pack("<I", len(obj)) + inner
    if isinstance(obj, dict):
        items = sorted(obj.items(), key=lambda kv: repr(kv[0]))
        inner = b"".join(_enc(k) + _enc(v) for k, v in items)
        return b"D" + struct.pack("<I", len(items)) + inner
    if is_dataclass(obj):
        inner = b"".join(_enc(getattr(obj, f.name)) for f in fields(obj))
        name = type(obj).__name__.encode()
        return b"C" + struct.pack("<I", len(name)) + name + inner
    raise TypeError(f"cannot encode {type(obj)!r}")


def decode(data: bytes) -> Any:
    """Inverse of :func:`encode` for the container/scalar subset."""
    obj, off = _dec(data, 0)
    if off != len(data):
        raise ValueError("trailing bytes in encoded payload")
    return obj


def decode_tuple3(data: bytes) -> Tuple[Any, Any, Any]:
    obj = decode(data)
    if not (isinstance(obj, tuple) and len(obj) == 3):
        raise ValueError("bad 3-tuple payload")
    return obj


def _dec(data: bytes, off: int):
    tag = data[off:off + 1]
    off += 1
    if tag == b"N":
        return None, off
    if tag == b"B":
        return data[off:off + 1] == b"1", off + 1
    if tag == b"I":
        return struct.unpack_from("<q", data, off)[0], off + 8
    if tag == b"F":
        return struct.unpack_from("<d", data, off)[0], off + 8
    if tag == b"Y":
        ln = struct.unpack_from("<I", data, off)[0]
        return data[off + 4:off + 4 + ln], off + 4 + ln
    if tag == b"S":
        ln = struct.unpack_from("<I", data, off)[0]
        return data[off + 4:off + 4 + ln].decode(), off + 4 + ln
    if tag == b"T":
        n = struct.unpack_from("<I", data, off)[0]
        off += 4
        items = []
        for _ in range(n):
            it, off = _dec(data, off)
            items.append(it)
        return tuple(items), off
    raise ValueError(f"bad tag {tag!r}")


def wire_size(obj: Any) -> int:
    """Estimated wire size in bytes of a protocol payload (fresh
    computation; the hot path uses :func:`wire_size_cached`)."""
    if obj is None:
        return 1
    if isinstance(obj, bool):
        return 1
    if isinstance(obj, (int, float)):
        return 8
    if isinstance(obj, bytes):
        return len(obj)
    if isinstance(obj, str):
        return len(obj)
    if isinstance(obj, (tuple, list)):
        return 4 + sum(wire_size(x) for x in obj)
    if isinstance(obj, dict):
        return 4 + sum(wire_size(k) + wire_size(v) for k, v in obj.items())
    if is_dataclass(obj):
        return 8 + sum(wire_size(getattr(obj, f.name)) for f in fields(obj))
    raise TypeError(f"cannot size {type(obj)!r}")


def wire_size_cached(obj: Any) -> int:
    """Memoized :func:`wire_size`: tuple subtrees are sized once, so a
    fresh wrapper around a shared payload costs O(shallow fields)."""
    if type(obj) is tuple:
        e = _entry(obj)
        v = e[3]
        if v is None:
            v = 4 + sum(wire_size_cached(x) for x in obj)
            if _pure(obj):
                e[3] = v
        return v
    return wire_size(obj)


def wire_size_shallow(obj: Any) -> int:
    """Size a message body without inserting it into the cache: scalar
    fields are priced inline and only *nested tuples* (the shared payload
    subtrees that actually recur — batches, certs, window contents) go
    through the memo.  ``Node.send`` wraps every message in a fresh tuple,
    so caching the wrapper itself would be all misses."""
    if type(obj) is not tuple:
        return wire_size_cached(obj)
    n = 4
    for x in obj:
        t = type(x)
        if t is tuple:
            n += wire_size_cached(x)
        elif t is int or t is float:
            n += 8
        elif t is bytes or t is str:
            n += len(x)
        elif x is None or t is bool:
            n += 1
        else:
            n += wire_size(x)
    return n


#: per-request framing inside a batched slot: rid + client id + length header
REQUEST_WIRE_OVERHEAD = 16


def batch_wire_size(batch: Any) -> int:
    """Wire size of a batched consensus payload (a tuple of request
    triples): every coalesced request pays its own framing overhead on top
    of its recursive payload size, so the cost model prices batches
    honestly rather than treating a batch as one flat blob."""
    return 4 + sum(wire_size_cached(r) + REQUEST_WIRE_OVERHEAD for r in batch)


class Signer:
    """Holds a private key; the only way to produce this pid's signatures."""

    def __init__(self, pid: str, secret: bytes):
        self.pid = pid
        self.__secret = secret

    def sign(self, payload: Any) -> bytes:
        data = encode_shallow(payload)
        mac = hmac.new(self.__secret, data, hashlib.sha256).digest()
        return mac + mac  # pad to 64 B like Ed25519


class KeyRegistry:
    """Public-key infrastructure stand-in (pre-published public keys)."""

    def __init__(self) -> None:
        self._secrets: Dict[str, bytes] = {}

    def keygen(self, pid: str) -> Signer:
        secret = hashlib.sha256(b"key:" + pid.encode()).digest()
        self._secrets[pid] = secret
        return Signer(pid, secret)

    def verify(self, pid: str, payload: Any, sig: bytes) -> bool:
        # Recomputes the MAC from the private secret table on every call —
        # memoizing the *encoding* is safe (it is public and deterministic),
        # memoizing the verdict would not model "the math".
        secret = self._secrets.get(pid)
        if secret is None or sig is None:
            return False
        data = encode_shallow(payload)
        mac = hmac.new(secret, data, hashlib.sha256).digest()
        return hmac.compare_digest(mac + mac, sig)


@dataclass(frozen=True)
class SignedBundle:
    """A payload with f+1 signatures from distinct processes (a certificate)."""
    payload: Any
    sigs: Tuple[Tuple[str, bytes], ...]  # ((pid, sig), ...)

    def verify(self, registry: KeyRegistry, quorum: int) -> bool:
        pids = {pid for pid, _ in self.sigs}
        if len(pids) < quorum:
            return False
        return all(registry.verify(pid, self.payload, sig) for pid, sig in self.sigs)
