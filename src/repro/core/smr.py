"""SMR cluster wiring: replicas + memory nodes + clients (Figure 1).

A :class:`Cluster` is one replicated application: 2f+1
:class:`UbftReplica`s plus any number of :class:`Client`s.  Clusters no
longer own their infrastructure — they :meth:`Cluster.attach` to a
:class:`~repro.core.substrate.Substrate` (simulator + network + key
registry + shared memory pools), so N independent applications can co-run
on one event loop over the *same* disaggregated memory ("shared by many
replicated applications", §8).  Clients send unsigned requests to *all*
replicas (§5.4) and complete when f+1 matching responses arrive.

``build_cluster`` remains as a thin shim (private substrate + one unnamed
app) so existing call sites migrate incrementally; it reproduces the
historical construction order bit-for-bit (golden traces).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import crypto
from repro.core.consensus import App, ConsensusConfig, UbftReplica
from repro.core.health import (HealthConfig, HealthMonitor, ReconfigPlan,
                               ReplicaHealth, as_health_config)
from repro.core.node import Node
from repro.core.registers import POOL_MEMORY_BUDGET, MemoryNode, MemoryPool
from repro.core.substrate import Substrate
from repro.sim.events import Simulator
from repro.sim.net import NetParams, NetworkModel


class ReplacementError(RuntimeError):
    """A replica replacement was rejected by a guard (unknown/retired
    target, one already in flight, a stale plan, …)."""


class Client(Node):
    """Closed-loop uBFT client."""

    def __init__(self, sim: Simulator, net: NetworkModel, registry, pid: str,
                 replicas: List[str], f: int):
        super().__init__(sim, net, registry, pid)
        self.replicas = replicas
        self.f = f
        self._next_rid = 0
        self._outstanding: Dict[tuple, dict] = {}
        self.latencies: List[float] = []
        self.handle("REP", self._on_reply)

    def request(self, payload: bytes,
                cb: Optional[Callable[[bytes, float], None]] = None) -> tuple:
        rid = (self.pid, self._next_rid)
        self._next_rid += 1
        self._outstanding[rid] = {
            "t0": self.sim.now, "replies": {}, "cb": cb, "done": False,
        }
        body = (rid, payload)
        size = crypto.wire_size_shallow(body) + 19  # len("REQ") + 16
        self.send_fanout(self.replicas, "REQ", body, size=size)
        return rid

    def _on_reply(self, src: str, body: Any) -> None:
        rid, result = body
        st = self._outstanding.get(rid)
        if st is None or st["done"]:
            return
        # replies are fresh bytes per replica: group raw bytes results by
        # value directly (domain-tagged so a crafted bytes result can never
        # collide with the *encoding* of a structured one), encode anything
        # else
        replies = st["replies"]
        if type(result) is bytes:
            enc = (0, result)
        else:
            enc = (1, crypto.encode(result))
        who = replies.get(enc)
        if who is None:
            who = replies[enc] = set()
        who.add(src)
        # only the reply group that just grew can newly reach the quorum
        if len(who) >= self.f + 1:  # f+1 matching responses
            st["done"] = True
            lat = self.sim.now - st["t0"]
            self.latencies.append(lat)
            if st["cb"] is not None:
                st["cb"](result, lat)
            del self._outstanding[rid]


@dataclass
class Cluster:
    """One replicated application over a (possibly shared) substrate."""
    sim: Simulator
    net: NetworkModel
    registry: crypto.KeyRegistry
    replicas: List[UbftReplica]
    pools: List[MemoryPool]
    clients: List[Client] = field(default_factory=list)
    #: application name on the substrate ("" = legacy unnamed single app)
    name: str = ""
    #: the substrate this cluster is attached to (None only for hand-built
    #: Cluster objects in old-style tests)
    substrate: Optional[Substrate] = None
    #: retained for live replica replacement (``replace_replica``)
    app_factory: Optional[Callable[[], App]] = None
    cfg: Optional[ConsensusConfig] = None
    replica_cls: Any = None
    #: replicas retired by an epoch switch (control-plane bookkeeping)
    retired_replicas: List[UbftReplica] = field(default_factory=list)
    #: set when a shard merge retires this whole group: it stays attached
    #: (recorded 2PC outcomes must remain probeable) but owns no key range
    #: and receives no fresh client traffic
    retired: bool = False
    #: (sim time, old_pid, new_pid) per initiated replacement
    replacements: List[Tuple[float, str, str]] = field(default_factory=list)
    #: (sim time, old_pid, reason) per *rejected* replacement request —
    #: the guard surface for idempotency (``replace_replica``)
    rejected_replacements: List[Tuple[float, str, str]] = \
        field(default_factory=list)
    #: self-healing control plane, set by ``enable_self_healing``
    health_monitor: Optional[HealthMonitor] = None
    #: called with ``(old_replica, joiner)`` at the end of every
    #: ``replace_replica`` — the service layer attaches its per-replica
    #: machinery (e.g. 2PC recovery timers) to the joiner here, so an
    #: epoch switch never silently shrinks the recovery fleet
    replace_hooks: List[Callable[[UbftReplica, UbftReplica], None]] = \
        field(default_factory=list)

    @classmethod
    def attach(cls, substrate: Substrate, app_factory: Callable[[], App],
               name: str = "", cfg: Optional[ConsensusConfig] = None,
               replica_cls=UbftReplica,
               budget: int = POOL_MEMORY_BUDGET,
               pools: Optional[Any] = None) -> "Cluster":
        """Attach one replicated application to a shared substrate.

        Builds 2f+1 replicas (f from ``cfg`` alone) named
        ``<name>/r<i>`` (bare ``r<i>`` for the unnamed app) on the
        substrate's event loop, sharing its network, key registry and
        memory pools.  Register keys are sharded ``crc32(name:owner:reg)``
        so this app's slice of the shared pools is independent of its
        neighbours'; ``budget`` is this app's per-pool Table 2 byte budget
        (overruns surface as per-app faults via
        ``substrate.audit_budgets()``, not as a global assert).

        ``pools`` pins this app's register sharding to a *subset* of the
        substrate's pools (a placement policy on top of the namespaced
        crc32 sharding): pass pool indices, names, or MemoryPool objects;
        ``None`` spreads over every pool (the default layout, preserved
        bit-for-bit).
        """
        if name in substrate.apps:
            raise ValueError(f"app {name!r} already attached to substrate")
        cfg = cfg or ConsensusConfig(f_m=substrate.f_m)
        if cfg.f_m != substrate.f_m:
            # the memory fault budget is a property of the shared TCB; an
            # app believing f_m is smaller would run f_m+1 register quorums
            # that need not intersect on the substrate's 2·f_m+1-node pools
            raise ValueError(
                f"cfg.f_m={cfg.f_m} disagrees with the substrate's "
                f"f_m={substrate.f_m} — the memory fault budget comes from "
                f"the shared pools, not per-app config")
        app_pools = substrate.select_pools(pools)
        prefix = f"{name}/" if name else ""
        replica_pids = [f"{prefix}r{i}" for i in range(2 * cfg.f + 1)]
        replicas = [
            replica_cls(substrate.sim, substrate.net, substrate.registry,
                        pid, replica_pids, app_pools, app_factory(),
                        cfg, namespace=name)
            for pid in replica_pids
        ]
        cluster = cls(sim=substrate.sim, net=substrate.net,
                      registry=substrate.registry, replicas=replicas,
                      pools=app_pools, name=name, substrate=substrate,
                      app_factory=app_factory, cfg=cfg,
                      replica_cls=replica_cls)
        substrate.register_app(name, cluster, tuple(replica_pids),
                               budget=budget)
        return cluster

    @property
    def mem_nodes(self) -> List[MemoryNode]:
        """Current TCB membership across all pools (legacy flat view)."""
        return [n for p in self.pools for n in p.member_nodes()]

    @property
    def replica_pids(self) -> List[str]:
        return [r.pid for r in self.replicas]

    def new_client(self, pid: Optional[str] = None) -> Client:
        if pid is None:
            prefix = f"{self.name}/" if self.name else ""
            pid = f"{prefix}c{len(self.clients)}"
        c = Client(self.sim, self.net, self.registry, pid,
                   self.replica_pids, self.replicas[0].f)
        self.clients.append(c)
        return c

    # ------------------------------------------------ replica replacement
    def current_epoch(self) -> int:
        """Highest membership epoch among live voting replicas."""
        return max((r.membership.epoch for r in self.replicas
                    if not r.joining), default=0)

    def current_members(self) -> Tuple[str, ...]:
        """Membership of the current epoch."""
        e = self.current_epoch()
        for r in self.replicas:
            if not r.joining and r.membership.epoch == e:
                return r.membership.replicas
        return tuple(self.replica_pids)

    def current_leader(self) -> str:
        """Leader pid of the current epoch's seated view (as observed by
        one live replica of that epoch)."""
        e = self.current_epoch()
        for r in self.replicas:
            if not r.joining and not r.crashed and r.membership.epoch == e:
                return r.leader()
        return self.current_members()[0]

    def next_replica_pid(self) -> str:
        """The deterministic pid the next joiner will get — fixed ahead of
        time so reconfiguration plans can be precomputed."""
        prefix = f"{self.name}/" if self.name else ""
        return f"{prefix}r{len(self.replicas) + len(self.retired_replicas)}"

    def replacement_in_flight(self) -> bool:
        """True while an epoch bump is pending or a joiner is still
        non-voting — the never-more-than-one-concurrent-replacement
        guard."""
        if any(r.joining for r in self.replicas):
            return True
        return any(ne > r.membership.epoch
                   for r in self.replicas if not r.crashed
                   for ne in r.pending_membership)

    def _reject_replacement(self, old_pid: str, reason: str,
                            strict: bool) -> None:
        self.rejected_replacements.append((self.sim.now, old_pid, reason))
        if strict:
            raise ReplacementError(
                f"cannot replace {old_pid!r} in app {self.name!r}: {reason}")
        return None

    def replace_replica(self, old_pid: str,
                        new_pid: Optional[str] = None,
                        plan: Optional[ReconfigPlan] = None,
                        strict: bool = False
                        ) -> Optional[UbftReplica]:
        """Replace a (typically crashed) replica with a fresh one — the
        control-plane operation behind the membership-epoch machinery.

        The sequence (DESIGN_MEMBERSHIP.md):

        1. install the joiner *non-voting* (``joining=True``) — it observes
           the group but cannot affect any quorum;
        2. survivors publish their latest signed checkpoint + boundary
           snapshot + prepared-slot state into their own ``xfer/<epoch>``
           registers, and the joiner pulls f+1 of them — the state
           transfer travels entirely through the disaggregated-memory
           pools (the PR 2 machinery);
        3. every pool re-keys the old pid's register permission to the new
           pid (``MemoryPool.rekey_owner`` — the reconfiguration
           pull/merge path, retried on timeout), so a Byzantine replaced
           replica cannot keep writing.  Rekey completion is *not* ordered
           before joiner activation: if the joiner writes an inherited
           register before ``adopt_wts`` lands, its entry is transiently
           shadowed by the inherited higher-timestamp blob — harmless for
           safety (the inherited CTBcast entries carry the old pid's
           signature and fail verification at every reader) and
           self-healing (``adopt_wts`` takes the max, so the next write
           supersedes);
        4. survivors route the epoch bump through a consensus slot
           (MEMBERSHIP); executing it switches every honest replica to the
           new epoch at the same point of its execution order, and f+1
           EPOCH confirmations activate the joiner.

        Guards (idempotency): a request naming a pid that is unknown,
        already retired, or mid-replacement — or arriving while another
        epoch bump is in flight — is rejected with a clear reason
        (recorded in :attr:`rejected_replacements`; raised as
        :class:`ReplacementError` with ``strict=True``) instead of racing
        the membership machinery.

        ``plan`` executes a precomputed :class:`~repro.core.health
        .ReconfigPlan` instead of deciding online: the joiner pid, the
        target epoch and the ``rekey_owner`` pool order come from the
        plan, which is validated against the live membership first (a
        stale plan is a rejection, never a partial execution).

        Returns the joiner (already on the event loop), or ``None`` when
        the replacement cannot start.  The switch itself completes
        asynchronously — drive the simulator and watch
        ``replica.membership.epoch``.
        """
        if self.app_factory is None:
            raise RuntimeError("replace_replica needs the app factory — "
                               "attach the cluster via Cluster.attach")
        by_pid = {r.pid: r for r in self.replicas}
        old = by_pid.get(old_pid)
        if old is None:
            if any(r.pid == old_pid for r in self.retired_replicas):
                return self._reject_replacement(
                    old_pid, "already retired by an earlier epoch switch",
                    strict)
            return self._reject_replacement(
                old_pid, "unknown pid (not in this cluster)", strict)
        if old.joining:
            return self._reject_replacement(
                old_pid, "target is itself a joiner still mid-replacement",
                strict)
        survivors = [r for r in self.replicas
                     if r.pid != old_pid and not r.crashed and not r.joining]
        if not survivors:
            return self._reject_replacement(
                old_pid, "no live survivors to transfer state from", strict)
        if self.replacement_in_flight():
            return self._reject_replacement(
                old_pid, "a replacement is already in flight", strict)
        cur_epoch = max(r.membership.epoch for r in survivors)
        members = next(r for r in survivors
                       if r.membership.epoch == cur_epoch).membership.replicas
        if old_pid not in members:
            return self._reject_replacement(
                old_pid, "not a member of the current epoch", strict)
        e = cur_epoch + 1
        pools = list(self.pools)
        if plan is not None:
            if new_pid is not None and new_pid != plan.new_pid:
                return self._reject_replacement(
                    old_pid, f"new_pid {new_pid!r} conflicts with the "
                    f"plan's {plan.new_pid!r}", strict)
            if (plan.old_pid != old_pid or plan.epoch != e or
                    plan.members != tuple(members)):
                return self._reject_replacement(
                    old_pid, f"stale plan (plan epoch {plan.epoch} / "
                    f"members {plan.members} vs live epoch {e} / "
                    f"{tuple(members)})", strict)
            by_name = {p.name: p for p in pools}
            if set(plan.rekey_order) != set(by_name):
                return self._reject_replacement(
                    old_pid, "plan's pool placement no longer matches the "
                    "cluster", strict)
            pools = [by_name[n] for n in plan.rekey_order]
            new_pid = plan.new_pid
        if new_pid is None:
            new_pid = self.next_replica_pid()
        if new_pid in self.sim.processes:
            return self._reject_replacement(
                old_pid, f"joiner pid {new_pid!r} is already a live "
                f"process", strict)
        cls = self.replica_cls or UbftReplica
        joiner = cls(self.sim, self.net, self.registry, new_pid,
                     list(members), self.pools, self.app_factory(),
                     self.cfg, namespace=self.name, joining=True,
                     epoch=cur_epoch)
        survivor_pids = [r.pid for r in survivors
                         if r.membership.epoch == cur_epoch]
        for r in survivors:
            r.publish_xfer(e)

        def _do_rekeys() -> None:
            for pool in pools:
                pool.rekey_owner(old_pid, new_pid,
                                 cb=joiner.regs.adopt_wts)
        if old.crashed:
            _do_rekeys()
        else:
            # A live target is still a voting member of the current epoch
            # (possibly its seated leader) until the agreed switch
            # executes.  Revoking its register permissions at fire time
            # would mute its slow-path broadcasts mid-epoch and wedge the
            # group; revoke at joiner activation instead — the switch
            # retires the old pid at the same point of the execution
            # order, so it cannot keep writing past its epoch either way.
            joiner.on_activate_hooks.append(_do_rekeys)
        joiner.begin_join(e, survivor_pids, (old_pid, new_pid))
        for r in survivors:
            r.propose_membership(e, old_pid, new_pid)
        if not old.crashed:
            # A live target proposes its own retirement: when the seated
            # leader is the one being rotated out, the survivors' ECHOs
            # alone would only reach it after a starvation-driven view
            # change (a full patience window).  An honest leader proposes
            # immediately; a Byzantine one still loses its view to the
            # progress timer as before.
            old.propose_membership(e, old_pid, new_pid)
        # control-plane bookkeeping: the cluster now routes around old_pid
        idx = self.replicas.index(old)
        self.replicas[idx] = joiner
        self.retired_replicas.append(old)
        # Clients fan REQs to every pid that is a member now or will be
        # next epoch: a live target stays a voting member — possibly the
        # seated leader — until the agreed switch executes, and cutting
        # it out of the fan-out at fire time would leave requests issued
        # during the switch without any copy at the one replica that can
        # propose them.  The retired pid is pruned once the joiner votes.
        fanout = self.replica_pids
        if not old.crashed:
            fanout = fanout + [old_pid]
        for c in self.clients:
            c.replicas = fanout

        def _prune_retired() -> None:
            if joiner.joining and not joiner.crashed:
                self.sim.after(50.0, _prune_retired)
                return
            for c in self.clients:
                c.replicas = self.replica_pids
        if not old.crashed:
            self.sim.after(50.0, _prune_retired)
        if self.substrate is not None:
            self.substrate.add_owner(self.name, new_pid)
        self.replacements.append((self.sim.now, old_pid, new_pid))
        for hook in self.replace_hooks:
            hook(old, joiner)
        return joiner

    def submit_internal(self, rid: tuple, payload: bytes) -> None:
        """Route a service-level request (``("svc", ...)`` rid, applied to
        the app, no reply) into this group's consensus from the control
        plane: every live replica proposes it, the deterministic rid
        dedupes the submissions into one slot.  This is the cluster-side
        hook behind ``repro.service``'s cross-shard 2PC recovery (a single
        replica uses ``UbftReplica.propose_internal`` directly)."""
        for r in self.replicas:
            if not r.crashed and not r.joining:
                r.propose_internal(rid, payload)

    # ------------------------------------------------ self-healing plane
    def enable_self_healing(self, cfg: Any = None) -> HealthMonitor:
        """Turn on the suspicion-driven control plane (core/health.py):
        one :class:`HealthMonitor` for the group, one
        :class:`ReplicaHealth` agent per replica (joiners included, via
        ``replace_hooks``).  ``cfg`` is a :class:`HealthConfig`, a dict of
        overrides, or None/True for defaults.  Idempotent — a second call
        returns the existing monitor."""
        if self.health_monitor is not None:
            return self.health_monitor
        hcfg = as_health_config(cfg)
        mon = HealthMonitor(self, hcfg)
        for r in self.replicas:
            r.gap_repair_us = hcfg.gap_repair_us
            ReplicaHealth(r, mon, hcfg)

        def _on_replace(old: UbftReplica, joiner: UbftReplica) -> None:
            agent = getattr(old, "health_agent", None)
            if agent is not None:
                agent.stop()
            joiner.gap_repair_us = hcfg.gap_repair_us
            ReplicaHealth(joiner, mon, hcfg)
            mon.forget(old.pid)

        self.replace_hooks.append(_on_replace)
        self.health_monitor = mon
        return mon

    # ------------------------------------------------------ telemetry
    def stats(self) -> Dict[str, Any]:
        """One telemetry surface for benchmarks and controllers:
        replacement history (accepted + rejected), per-pool rekey retry
        counts (``aborted_rekeys`` et al.), per-replica health/suspicion
        counters, and — when self-healing is enabled — the monitor's
        accusation, replacement and gating logs."""
        pools = {
            p.name: {
                "rekeys": len(p.rekeys),
                "aborted_rekeys": len(p.aborted_rekeys),
                "aborted_syncs": len(p.aborted_syncs),
                "reconfigurations": len(p.reconfigurations),
            }
            for p in self.pools
        }
        health: Dict[str, Any] = {}
        for r in self.replicas:
            hc = getattr(r, "health_counters", None) or {}
            entry = {
                "starvations": hc.get("starvations", 0),
                "view_changes": hc.get("view_changes", 0),
                "seated_past": dict(hc.get("seated_past", {})),
            }
            agent = getattr(r, "health_agent", None)
            if agent is not None:
                entry["hb_misses"] = dict(agent.misses)
                entry["suspects"] = sorted(agent.suspects)
            health[r.pid] = entry
        out: Dict[str, Any] = {
            "epoch": self.current_epoch(),
            "members": list(self.current_members()),
            "replacements": list(self.replacements),
            "rejected_replacements": list(self.rejected_replacements),
            "replacement_in_flight": self.replacement_in_flight(),
            "pools": pools,
            "health": health,
        }
        mon = self.health_monitor
        if mon is not None:
            out["suspicions"] = {t: sorted(acc)
                                 for t, acc in mon.accusations.items() if acc}
            out["auto_replacements"] = [dict(rec) for rec in mon.replacements]
            out["deferred"] = list(mon.deferred)
            out["rotation"] = [dict(rec) for rec in mon.rotation_log]
        admission: Dict[str, Any] = {}
        for r in self.replicas:
            cfg = getattr(r, "cfg", None)
            if cfg is None or cfg.admission is None:
                continue
            admission[r.pid] = dict(
                r.admission_stats,
                backlog=r._client_backlog,
                shed_queued=len(r.shed_queue),
                exec_lag=max(r.decided.keys(), default=-1) - r.exec_upto,
            )
        if admission:
            out["admission"] = admission
        # engine observability: wire-cache / digest-path counters (module
        # global — shared by every app on the substrate) plus this
        # fabric's fan-out accounting, so benchmarks can prove the batched
        # paths are actually taken on the hot path
        out["engine"] = {
            "digests": crypto.digest_stats(),
            "net": {
                "msgs_sent": self.net.msgs_sent,
                "bytes_sent": self.net.bytes_sent,
                "fanout_msgs": self.net.fanout_msgs,
                "coalesced_runs": self.net.coalesced_runs,
            },
            "events_processed": self.sim.events_processed,
        }
        return out

    def memory_by_pool(self) -> Dict[str, int]:
        """This app's occupied disaggregated memory per shared pool
        (Table 2, split per application)."""
        if self.substrate is None:
            return {p.name: p.memory_bytes() for p in self.pools}
        return self.substrate.app_pool_bytes(self.name)

    def run_request(self, client: Client, payload: bytes,
                    timeout: float = 1_000_000.0) -> Tuple[bytes, float]:
        """Issue one request and run the simulation until it completes."""
        box: dict = {}

        def done(result: bytes, lat: float) -> None:
            box["result"] = result
            box["lat"] = lat

        client.request(payload, done)
        ok = self.sim.run_until(lambda: "result" in box, timeout=timeout)
        if not ok:
            raise TimeoutError(
                f"request did not complete within {timeout} µs "
                f"(t={self.sim.now})")
        return box["result"], box["lat"]

    def run_requests(self, client: Client, payloads: List[bytes],
                     timeout: float = 10_000_000.0) -> List[Tuple[bytes, float]]:
        """Issue many requests concurrently (they ride the leader's batched
        slots) and run until every one completes.  Returns (result, latency)
        per payload, in submission order."""
        out: List[Optional[Tuple[bytes, float]]] = [None] * len(payloads)
        left = {"n": len(payloads)}

        def mk(i: int):
            def done(result: bytes, lat: float) -> None:
                out[i] = (result, lat)
                left["n"] -= 1
            return done

        for i, p in enumerate(payloads):
            client.request(p, mk(i))
        ok = self.sim.run_until(lambda: left["n"] == 0, timeout=timeout)
        if not ok:
            raise TimeoutError(
                f"{left['n']}/{len(payloads)} requests incomplete after "
                f"{timeout} µs (t={self.sim.now})")
        return out  # type: ignore[return-value]


def build_cluster(app_factory: Callable[[], App],
                  f: Optional[int] = None, f_m: Optional[int] = None,
                  cfg: Optional[ConsensusConfig] = None,
                  params: Optional[NetParams] = None,
                  seed: int = 0,
                  replica_cls=UbftReplica,
                  n_pools: int = 1,
                  auto_reconfigure: bool = False,
                  lease_us: float = 200.0) -> Cluster:
    """Legacy shim: a private :class:`Substrate` plus one unnamed app.

    Assembles a 2f+1-replica uBFT deployment over ``n_pools`` memory pools
    of 2f_m+1 nodes each, exactly as the pre-substrate builder did
    (identical pids, process-creation order, and draw order — the recorded
    golden traces hold bit-for-bit).

    In the substrate API the fault parameters come from ``cfg`` alone.
    When ``cfg`` is supplied together with explicit ``f``/``f_m`` keywords
    that *disagree* with it, this shim raises instead of silently
    clobbering the config (the historical footgun: ``cfg.f`` used to be
    overwritten by the defaulted keyword).
    """
    if cfg is not None:
        if f is not None and f != cfg.f:
            raise ValueError(
                f"conflicting fault budgets: build_cluster(f={f}) vs "
                f"cfg.f={cfg.f} — with cfg=..., f comes from cfg alone")
        if f_m is not None and f_m != cfg.f_m:
            raise ValueError(
                f"conflicting fault budgets: build_cluster(f_m={f_m}) vs "
                f"cfg.f_m={cfg.f_m} — with cfg=..., f_m comes from cfg alone")
    else:
        cfg = ConsensusConfig(f=1 if f is None else f,
                              f_m=1 if f_m is None else f_m)
    substrate = Substrate(f_m=cfg.f_m, n_pools=n_pools, params=params,
                          seed=seed, auto_reconfigure=auto_reconfigure,
                          lease_us=lease_us)
    return Cluster.attach(substrate, app_factory, name="", cfg=cfg,
                          replica_cls=replica_cls)
