"""SMR cluster wiring: replicas + memory nodes + clients (Figure 1).

A :class:`Cluster` assembles 2f+1 :class:`UbftReplica`s, 2f_m+1
:class:`MemoryNode`s and any number of :class:`Client`s on one simulator.
Clients send unsigned requests to *all* replicas (§5.4) and complete when
f+1 matching responses arrive.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import crypto
from repro.core.consensus import App, ConsensusConfig, UbftReplica
from repro.core.node import Node
from repro.core.registers import MemoryNode, MemoryPool
from repro.sim.events import Simulator
from repro.sim.net import NetParams, NetworkModel


class Client(Node):
    """Closed-loop uBFT client."""

    def __init__(self, sim: Simulator, net: NetworkModel, registry, pid: str,
                 replicas: List[str], f: int):
        super().__init__(sim, net, registry, pid)
        self.replicas = replicas
        self.f = f
        self._next_rid = 0
        self._outstanding: Dict[tuple, dict] = {}
        self.latencies: List[float] = []
        self.handle("REP", self._on_reply)

    def request(self, payload: bytes,
                cb: Optional[Callable[[bytes, float], None]] = None) -> tuple:
        rid = (self.pid, self._next_rid)
        self._next_rid += 1
        self._outstanding[rid] = {
            "t0": self.sim.now, "replies": {}, "cb": cb, "done": False,
        }
        body = (rid, payload)
        size = crypto.wire_size_shallow(body) + 19  # len("REQ") + 16
        for r in self.replicas:
            self.send(r, "REQ", body, size=size)
        return rid

    def _on_reply(self, src: str, body: Any) -> None:
        rid, result = body
        st = self._outstanding.get(rid)
        if st is None or st["done"]:
            return
        # replies are fresh bytes per replica — plain encode, no memo
        replies = st["replies"]
        enc = crypto.encode(result)
        who = replies.get(enc)
        if who is None:
            who = replies[enc] = set()
        who.add(src)
        # only the reply group that just grew can newly reach the quorum
        if len(who) >= self.f + 1:  # f+1 matching responses
            st["done"] = True
            lat = self.sim.now - st["t0"]
            self.latencies.append(lat)
            if st["cb"] is not None:
                st["cb"](result, lat)
            del self._outstanding[rid]


@dataclass
class Cluster:
    sim: Simulator
    net: NetworkModel
    registry: crypto.KeyRegistry
    replicas: List[UbftReplica]
    pools: List[MemoryPool]
    clients: List[Client] = field(default_factory=list)

    @property
    def mem_nodes(self) -> List[MemoryNode]:
        """Current TCB membership across all pools (legacy flat view)."""
        return [n for p in self.pools for n in p.member_nodes()]

    @property
    def replica_pids(self) -> List[str]:
        return [r.pid for r in self.replicas]

    def new_client(self, pid: Optional[str] = None) -> Client:
        pid = pid or f"c{len(self.clients)}"
        c = Client(self.sim, self.net, self.registry, pid,
                   self.replica_pids, self.replicas[0].f)
        self.clients.append(c)
        return c

    def run_request(self, client: Client, payload: bytes,
                    timeout: float = 1_000_000.0) -> Tuple[bytes, float]:
        """Issue one request and run the simulation until it completes."""
        box: dict = {}

        def done(result: bytes, lat: float) -> None:
            box["result"] = result
            box["lat"] = lat

        client.request(payload, done)
        ok = self.sim.run_until(lambda: "result" in box, timeout=timeout)
        if not ok:
            raise TimeoutError(
                f"request did not complete within {timeout} µs "
                f"(t={self.sim.now})")
        return box["result"], box["lat"]

    def run_requests(self, client: Client, payloads: List[bytes],
                     timeout: float = 10_000_000.0) -> List[Tuple[bytes, float]]:
        """Issue many requests concurrently (they ride the leader's batched
        slots) and run until every one completes.  Returns (result, latency)
        per payload, in submission order."""
        out: List[Optional[Tuple[bytes, float]]] = [None] * len(payloads)
        left = {"n": len(payloads)}

        def mk(i: int):
            def done(result: bytes, lat: float) -> None:
                out[i] = (result, lat)
                left["n"] -= 1
            return done

        for i, p in enumerate(payloads):
            client.request(p, mk(i))
        ok = self.sim.run_until(lambda: left["n"] == 0, timeout=timeout)
        if not ok:
            raise TimeoutError(
                f"{left['n']}/{len(payloads)} requests incomplete after "
                f"{timeout} µs (t={self.sim.now})")
        return out  # type: ignore[return-value]


def build_cluster(app_factory: Callable[[], App], f: int = 1, f_m: int = 1,
                  cfg: Optional[ConsensusConfig] = None,
                  params: Optional[NetParams] = None,
                  seed: int = 0,
                  replica_cls=UbftReplica,
                  n_pools: int = 1,
                  auto_reconfigure: bool = False,
                  lease_us: float = 200.0) -> Cluster:
    """Assemble a 2f+1-replica uBFT deployment over ``n_pools`` memory
    pools of 2f_m+1 nodes each (register keys are sharded across pools;
    ``auto_reconfigure`` turns on lease-based replacement of crashed
    memory nodes)."""
    sim = Simulator(seed=seed)
    net = NetworkModel(sim, params)
    registry = crypto.KeyRegistry()
    cfg = cfg or ConsensusConfig(f=f, f_m=f_m)
    cfg.f, cfg.f_m = f, f_m

    replica_pids = [f"r{i}" for i in range(2 * f + 1)]
    # pool 0 keeps the historical m0/m1/... pids; extra shards are p<i>m<j>
    pools = [
        MemoryPool(sim, net, registry, f_m=f_m, name=f"pool{i}",
                   prefix=("m" if i == 0 else f"p{i}m"),
                   auto_reconfigure=auto_reconfigure, lease_us=lease_us)
        for i in range(n_pools)
    ]
    replicas = [
        replica_cls(sim, net, registry, pid, replica_pids, pools,
                    app_factory(), cfg)
        for pid in replica_pids
    ]
    return Cluster(sim=sim, net=net, registry=registry,
                   replicas=replicas, pools=pools)
