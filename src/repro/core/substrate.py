"""The shared disaggregated-memory substrate (§8: "a small amount of
reliable disaggregated memory ... shared by many replicated applications").

A :class:`Substrate` owns everything that is *infrastructure* rather than
application: one :class:`~repro.sim.events.Simulator` (the event loop), one
:class:`~repro.sim.net.NetworkModel` (the calibrated fabric), one
:class:`~repro.core.crypto.KeyRegistry` (key material for every process),
and the :class:`~repro.core.registers.MemoryPool`\\ s that form the TCB.

Many independent 2f+1 replica groups then *attach* to the same substrate
(:meth:`repro.core.smr.Cluster.attach`): they co-run on the one event loop
and share the same pools.  Isolation between applications is provided by

* **pid namespacing** — an app named ``A`` gets replicas ``A/r0..A/r2`` and
  clients ``A/c0..``; register cells are keyed by owner pid, so two apps
  never collide in disaggregated memory;
* **app-namespaced register-key sharding** — a replica's
  :class:`~repro.core.registers.RegisterClient` routes register keys
  ``crc32(app:owner:reg) % n_pools``, so each app's registers spread over
  the shared pools independently (the legacy single-app layout hashes
  ``crc32(owner:reg)`` and is preserved bit-for-bit for the unnamed app);
* **per-app byte budgets** — Table 2 accounting is split per app
  (:meth:`memory_by_app`); an app that exceeds its budget in any pool is
  surfaced as a *per-app fault* in :attr:`budget_faults`
  (:meth:`audit_budgets`), never as a global assert that would take down
  its neighbours.

The substrate is deliberately application-oblivious, exactly like the
paper's memory nodes: it knows app *names* and the pids registered under
them only for accounting and fault attribution.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core import crypto
from repro.core.registers import (POOL_MEMORY_BUDGET, MemoryNode, MemoryPool)
from repro.sim.events import Simulator
from repro.sim.net import NetParams, NetworkModel


@dataclass
class AppRecord:
    """One replicated application attached to the substrate."""
    name: str
    cluster: Any                 # repro.core.smr.Cluster (no import cycle)
    owner_pids: Tuple[str, ...]  # pids whose register cells belong to this app
    budget: int = POOL_MEMORY_BUDGET


class Substrate:
    """Simulator + network + key registry + shared memory pools.

    ``n_pools`` pools of ``2·f_m+1`` nodes each are created up front; pool 0
    keeps the historical ``m0/m1/...`` pids and extra shards are
    ``p<i>m<j>`` — identical to the layout ``build_cluster`` always
    produced, so single-app deployments through the shim stay bit-for-bit
    on the recorded golden traces.
    """

    def __init__(self, f_m: int = 1, n_pools: int = 1,
                 params: Optional[NetParams] = None, seed: int = 0,
                 auto_reconfigure: bool = False, lease_us: float = 200.0):
        self.sim = Simulator(seed=seed)
        self.net = NetworkModel(self.sim, params)
        self.registry = crypto.KeyRegistry()
        self.f_m = f_m
        self.pools: List[MemoryPool] = [
            MemoryPool(self.sim, self.net, self.registry, f_m=f_m,
                       name=f"pool{i}",
                       prefix=("m" if i == 0 else f"p{i}m"),
                       auto_reconfigure=auto_reconfigure, lease_us=lease_us)
            for i in range(n_pools)
        ]
        self.apps: Dict[str, AppRecord] = {}
        #: sharded services (``repro.service.ShardedService``) by name —
        #: each is a *set* of attached apps (``<name>/s<i>``) plus routing
        self.services: Dict[str, Any] = {}
        self._owner_app: Dict[str, str] = {}
        #: (sim time, app, pool name, occupied bytes, budget) per overrun —
        #: the per-app fault surface for Table 2 budget violations
        self.budget_faults: List[Tuple[float, str, str, int, int]] = []

    # ------------------------------------------------------------- attach
    def register_app(self, name: str, cluster: Any,
                     owner_pids: Tuple[str, ...],
                     budget: int = POOL_MEMORY_BUDGET) -> AppRecord:
        """Record an attached application (called by ``Cluster.attach``)."""
        if name in self.apps:
            raise ValueError(f"app {name!r} already attached to substrate")
        rec = AppRecord(name=name, cluster=cluster,
                        owner_pids=tuple(owner_pids), budget=budget)
        self.apps[name] = rec
        for pid in owner_pids:
            self._owner_app[pid] = name
        return rec

    def add_owner(self, name: str, pid: str) -> None:
        """Register a pid created after attach (replica replacement) under
        its app, so per-app accounting keeps attributing its cells."""
        rec = self.apps.get(name)
        if rec is None:
            raise KeyError(f"no app {name!r} on this substrate")
        if pid not in rec.owner_pids:
            rec.owner_pids = rec.owner_pids + (pid,)
        self._owner_app[pid] = name

    def select_pools(self, pools: Optional[Any]) -> List[MemoryPool]:
        """Resolve a pool-placement policy: ``None`` → every pool (the
        same list object, so legacy identity checks hold); otherwise a
        subset given as indices, names, or MemoryPool objects."""
        if pools is None:
            return self.pools
        by_name = {p.name: p for p in self.pools}
        out: List[MemoryPool] = []
        for ref in pools:
            if isinstance(ref, MemoryPool):
                if ref not in self.pools:
                    raise ValueError(f"pool {ref.name!r} is not on this "
                                     f"substrate")
                out.append(ref)
            elif isinstance(ref, int):
                if not 0 <= ref < len(self.pools):
                    raise ValueError(f"cannot resolve pool {ref!r} "
                                     f"(substrate has {len(self.pools)})")
                out.append(self.pools[ref])
            elif ref in by_name:
                out.append(by_name[ref])
            else:
                raise ValueError(f"cannot resolve pool {ref!r}")
        if not out:
            raise ValueError("pool placement must select at least one pool")
        if len(set(id(p) for p in out)) != len(out):
            raise ValueError("pool placement lists a pool twice — the "
                             "crc32 shard denominator would double-count")
        return out

    @property
    def clusters(self) -> Dict[str, Any]:
        return {name: rec.cluster for name, rec in self.apps.items()}

    @property
    def mem_nodes(self) -> List[MemoryNode]:
        """Current TCB membership across all pools (flat view)."""
        return [n for p in self.pools for n in p.member_nodes()]

    # --------------------------------------------- Table 2, split per app
    def memory_by_app(self) -> Dict[str, Dict[str, int]]:
        """Occupied disaggregated memory per app per pool:
        ``{app: {pool_name: bytes}}``.  Cells are attributed by their owner
        pid; owners not registered under any app (e.g. a bare
        ``RegisterClient`` used directly in a test) are attributed to their
        own pid so nothing is silently dropped."""
        out: Dict[str, Dict[str, int]] = {name: {} for name in self.apps}
        for pool in self.pools:
            per_owner = pool.memory_bytes_by_owner()
            for owner, nbytes in per_owner.items():
                app = self._owner_app.get(owner, owner)
                by_pool = out.setdefault(app, {})
                by_pool[pool.name] = by_pool.get(pool.name, 0) + nbytes
        return out

    def app_pool_bytes(self, name: str) -> Dict[str, int]:
        """Per-pool occupancy of one app (empty dict if it wrote nothing)."""
        return self.memory_by_app().get(name, {})

    def audit_budgets(self, usage: Optional[Dict[str, Dict[str, int]]] = None
                      ) -> List[Tuple[float, str, str, int, int]]:
        """Check every attached app against its per-pool byte budget.

        Overruns are appended to :attr:`budget_faults` and returned — a
        *per-app* fault record, not a global assert: one misbehaving (or
        merely oversubscribed) application must not take down the shared
        substrate or its neighbours.  ``usage`` lets a caller that already
        computed :meth:`memory_by_app` pass it in instead of re-walking
        every pool's cell map.
        """
        overruns: List[Tuple[float, str, str, int, int]] = []
        if usage is None:
            usage = self.memory_by_app()
        for name, rec in self.apps.items():
            for pool_name, nbytes in usage.get(name, {}).items():
                if nbytes >= rec.budget:
                    overruns.append((self.sim.now, name, pool_name,
                                     nbytes, rec.budget))
        self.budget_faults.extend(overruns)
        return overruns
