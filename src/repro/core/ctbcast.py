"""Consistent Tail Broadcast — Algorithm 1 of the paper.

CTBcast prevents equivocation for *all* messages while guaranteeing delivery
of only the last ``t`` messages of a correct broadcaster (tail-validity).
Properties: Tail-validity, Agreement, Integrity, No-duplication.

Fast path (signature-free, no disaggregated memory):
    broadcaster:  TBcast <LOCK, k, m>
    receiver:     on LOCK   — commit in ``locks[k%t]``, TBcast <LOCKED, k, m>
                  on LOCKED — unanimity over all n processes → deliver

Slow path (signatures + SWMR registers; triggered on timeout / by caller):
    broadcaster:  TBcast <SIGNED, k, m, sign((k, H(m)))>
    receiver:     verify sig → check/update locks → WRITE own register[k%t]
                  → READ everyone's register[k%t] → abort on conflicting k /
                  out-of-tail higher k → deliver

Registers store ``(k, sig, H(m))`` — only the 32 B fingerprint goes to
disaggregated memory (§7.6); the message body travels over TBcast.

The fast and slow paths are linked through ``locks`` (lines 15/29): whichever
path executes first at a receiver pins the message for the other path.

Summaries / broadcast blocking (§5.2, Algorithm 4 hooks): every ``t/2``
broadcasts the broadcaster requests a summary certificate of its state from
f+1 receivers and blocks once *two* segments are outstanding (the paper's
double-buffering, footnote 3).  The summary content is supplied by the layer
above (consensus) through callbacks; a standalone default is provided for
direct CTBcast use (benchmarks Figs 10/11).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import crypto
from repro.core.node import Node
from repro.core.registers import RegisterClient
from repro.core.tbcast import TBcastService


@dataclass
class _Slot:
    k: int = -1
    m: Any = None


class CTBcast:
    """One CTBcast instance: a designated broadcaster, n receivers.

    In uBFT every replica runs one instance per broadcaster (n instances per
    node); the broadcaster participates as a receiver of its own instance.
    """

    def __init__(self, node: Node, tb: TBcastService, regs: Optional[RegisterClient],
                 broadcaster: str, group: List[str], t: int,
                 deliver: Callable[[int, Any], None],
                 auto_slow_after_us: Optional[float] = None,
                 summary_interval: Optional[int] = None,
                 on_summary_needed: Optional[Callable[[int], None]] = None,
                 fast_enabled: bool = True):
        self.node = node
        self.tb = tb
        self.regs = regs
        self.broadcaster = broadcaster
        self.group = list(group)
        self.n = len(group)
        self.t = t
        self.deliver_cb = deliver
        self.auto_slow_after_us = auto_slow_after_us
        self.fast_enabled = fast_enabled
        self.is_broadcaster = node.pid == broadcaster

        # Receiver state (Algorithm 1, lines 6-10) — all arrays are t-sized.
        self.locks: List[_Slot] = [_Slot() for _ in range(t)]
        self.locked: Dict[str, List[_Slot]] = {q: [_Slot() for _ in range(t)]
                                               for q in group}
        self.delivered: List[int] = [-1] * t

        # Broadcaster state: buffer of the last 2t broadcasts (for slow-path
        # escalation and summary-based catch-up).
        self.buf: Dict[int, Any] = {}
        self.next_k = 0

        # Summary machinery (double-buffered blocking).
        self.summary_interval = summary_interval or max(1, t // 2)
        self.on_summary_needed = on_summary_needed
        self.summaries_ok: int = -1           # highest summary id certified
        self.blocked_queue: List[Tuple[int, Any]] = []
        self.stall_count = 0
        self.stalled_since: Optional[float] = None
        self.total_stall_us = 0.0

        # NB: stream names must not be prefixes of one another (TBcast
        # dispatches by prefix): LOCK vs LOCKED would collide.
        base = f"ctb/{broadcaster}"
        self._s_lock = f"{base}/LK/"
        self._s_signed = f"{base}/SG/"
        self._s_locked = f"{base}/LD/"        # per-origin via TB origin
        tb.register(self._s_lock, self._on_lock)
        tb.register(self._s_signed, self._on_signed)
        tb.register(self._s_locked, self._on_locked)

    # ------------------------------------------------------------ broadcast
    def broadcast(self, k: int, m: Any, slow: bool = False) -> None:
        """Algorithm 1, lines 2-4 (+ summary blocking)."""
        assert self.is_broadcaster
        if self._blocked(k):
            self.blocked_queue.append((k, m))
            if self.stalled_since is None:
                self.stalled_since = self.node.sim.now
                self.stall_count += 1
            return
        self._do_broadcast(k, m, slow)

    def _blocked(self, k: int) -> bool:
        # Segment i covers ks [i*si, (i+1)*si).  Before broadcasting into
        # segment i we must hold the certificate for segment i-2
        # (double buffering): summaries_ok >= i-2.
        si = self.summary_interval
        seg = k // si
        return seg - 2 > self.summaries_ok

    def _do_broadcast(self, k: int, m: Any, slow: bool) -> None:
        self.buf[k] = m
        self.next_k = max(self.next_k, k + 1)
        while len(self.buf) > 2 * self.t:
            del self.buf[min(self.buf)]
        if self.fast_enabled:
            self.tb.broadcast(self._s_lock, k, m, self.group)
        si = self.summary_interval
        if k % si == si - 1 and self.on_summary_needed is not None:
            # end of segment — ask the upper layer to certify a summary
            self.on_summary_needed(k // si)
        if slow or not self.fast_enabled:
            self.escalate(k)
        elif self.auto_slow_after_us is not None and self.auto_slow_after_us > 0:
            self.node.timer(self.auto_slow_after_us,
                            lambda: self._maybe_escalate(k))
        elif self.auto_slow_after_us == 0.0:
            self.escalate(k)

    def _maybe_escalate(self, k: int) -> None:
        if self.delivered[k % self.t] < k and k in self.buf:
            self.escalate(k)

    def escalate(self, k: int) -> None:
        """Trigger the slow path for k: sign and TBcast <SIGNED, k, m, sig>."""
        if k not in self.buf:
            return
        m = self.buf[k]
        fp = crypto.fingerprint_cached(m)
        self.node.async_sign(("ctb", self.broadcaster, k, fp), lambda sig:
                             self.tb.broadcast(self._s_signed, k, (m, sig),
                                               self.group))

    def summary_certified(self, seg: int) -> None:
        """Upper layer certified summary segment ``seg`` — unblock."""
        self.summaries_ok = max(self.summaries_ok, seg)
        q, self.blocked_queue = self.blocked_queue, []
        if self.stalled_since is not None:
            self.total_stall_us += self.node.sim.now - self.stalled_since
            self.stalled_since = None
        for k, m in q:
            self.broadcast(k, m)

    # --------------------------------------------------------- membership
    def set_group(self, group: List[str]) -> None:
        """Switch the receiver group to the current membership epoch.

        LOCKED unanimity (line 22) and every fan-out are computed over
        ``group``; a replaced replica's slots are dropped (its LOCKEDs must
        no longer gate delivery) and a joiner gets fresh t-sized arrays.
        Called by the consensus layer when an agreed MEMBERSHIP slot
        executes — never on the static path.
        """
        group = list(group)
        if group == self.group:
            return
        for q in group:
            if q not in self.locked:
                self.locked[q] = [_Slot() for _ in range(self.t)]
        for q in [q for q in self.locked if q not in group]:
            del self.locked[q]
        self.group = group
        self.n = len(group)

    # ------------------------------------------------------------ fast path
    def _on_lock(self, origin: str, stream: str, k: int, m: Any) -> None:
        if origin != self.broadcaster:
            return  # only the designated broadcaster may LOCK
        slot = self.locks[k % self.t]
        if k > slot.k:                       # line 14
            slot.k, slot.m = k, m            # line 15 (commit)
            if self.fast_enabled:
                self.tb.broadcast(self._s_locked, k, m, self.group)  # line 16

    def _on_locked(self, origin: str, stream: str, k: int, m: Any) -> None:
        if origin not in self.locked:
            return
        i = k % self.t
        slot = self.locked[origin][i]
        if k > slot.k:                       # line 20
            slot.k, slot.m = k, m            # line 21
        mismatched = None
        for q in self.group:                 # line 22 (unanimity)
            s2 = self.locked[q][i]
            if s2.k != k:
                return
            if s2.m is not m:
                if mismatched is None:
                    mismatched = []
                mismatched.append(s2.m)
        if mismatched:
            # honest LOCKEDs all carry the broadcaster's object by
            # reference; fall back to encoding only on mismatch — one
            # batch encode for every diverging slot at once
            enc = crypto.encode_cached(m)
            if any(e != enc
                   for e in crypto.encode_batch_cached(mismatched)):
                return
        self._deliver_once(k, m)             # line 23

    # ------------------------------------------------------------ slow path
    def _on_signed(self, origin: str, stream: str, k: int, payload: Any) -> None:
        if origin != self.broadcaster or self.regs is None:
            return
        m, sig = payload
        fp = crypto.fingerprint_cached(m)
        self.node.async_verify(self.broadcaster, ("ctb", self.broadcaster, k, fp),
                               sig, lambda ok: self._signed_verified(ok, k, m, sig, fp))

    def _signed_verified(self, ok: bool, k: int, m: Any, sig: bytes,
                         fp: bytes) -> None:
        if not ok:                           # line 26
            return
        slot = self.locks[k % self.t]
        same = slot.k == k and (slot.m is m or
                                crypto.encode_cached(slot.m) ==
                                crypto.encode_cached(m))
        if not (k > slot.k or same):         # lines 27-28
            return
        slot.k, slot.m = k, m                # line 29
        value = crypto.encode((k, sig, fp))
        reg = f"{self.broadcaster}/{k % self.t}"
        self.regs.write(reg, value,
                        lambda: self._read_all(k, m, fp))  # line 30

    def _read_all(self, k: int, m: Any, fp: bytes) -> None:
        reg = f"{self.broadcaster}/{k % self.t}"
        results: Dict[str, Any] = {}
        remaining = set(self.group)

        def on_read(q: str, val, byz: bool) -> None:
            results[q] = (val, byz)
            remaining.discard(q)
            if not remaining:
                self._check_registers(k, m, fp, results)

        for q in self.group:
            self.regs.read(q, reg, lambda val, byz, q=q: on_read(q, val, byz))

    def _check_registers(self, k: int, m: Any, fp: bytes, results: Dict) -> None:
        # lines 31-37: verify entries; abort on conflicting same-k message or
        # a higher k aliasing the same register (out of tail).
        entries = []
        for q, (val, byz) in results.items():
            if val is None:
                continue
            try:
                k2, sig2, fp2 = crypto.decode_tuple3(val[1])
            except Exception:
                continue
            entries.append((q, k2, sig2, fp2))
        items = [(self.broadcaster, ("ctb", self.broadcaster, k2, fp2), sig2)
                 for (_q, k2, sig2, fp2) in entries]

        def verified(oks: List[bool]) -> None:
            for ok, (_q, k2, _sig2, fp2) in zip(oks, entries):
                if not ok:
                    continue                  # line 32 (invalid → ignore)
                if k2 == k and fp2 != fp:
                    return                    # line 33: Byzantine broadcaster
                if k2 > k and (k2 - k) % self.t == 0:
                    return                    # line 35: out of tail
            self._deliver_once(k, m)          # line 37

        if items:
            self.node.async_verify_many(items, verified)
        else:
            verified([])

    # ------------------------------------------------------------- deliver
    def _deliver_once(self, k: int, m: Any) -> None:
        if k > self.delivered[k % self.t]:   # lines 40-42
            self.delivered[k % self.t] = k
            self.deliver_cb(k, m)

    # --------------------------------------------------------- accounting
    def memory_bytes(self) -> int:
        """Local bookkeeping arrays: locks(t) + locked(n·t) + delivered(t)."""
        slot = 8 + 64  # k + small message ref
        return self.t * slot + self.n * self.t * slot + self.t * 8
