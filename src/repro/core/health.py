"""Self-healing control plane: suspicion → precomputed replacement plans.

Closes the loop left open by the membership machinery (DESIGN_MEMBERSHIP.md):
``Cluster.replace_replica`` used to fire only when a schedule or operator
invoked it, so a silently degraded replica cost view-change churn forever.
Three pieces close it:

:class:`ReplicaHealth` — a per-replica agent aggregating health signals
    already latent in the protocol into a phi-style suspicion score per
    peer: lease heartbeats (mirroring ``_PoolManager``'s LEASE_PING
    machinery in ``core/registers.py`` — a peer's freshest heartbeat ages
    past the period), progress-timer starvation episodes seated past a
    pid (``UbftReplica.health_counters`` / ``on_starvation_hooks``), and
    TBcast retransmission fires toward a peer that stopped acking
    (``TBcastService.retx_fires``).  When a peer's score crosses
    ``accuse_score`` the agent ACCUSEs it to the group's monitor and keeps
    refreshing the accusation every beat; when the score falls back under
    ``retract_score`` it RETRACTs.  The accuse/retract band plus the
    decaying accumulator is the per-accuser hysteresis: one missed beat or
    one starvation episode never reaches the accuse threshold.

:class:`HealthMonitor` — the per-cluster control-plane node (the analogue
    of the pools' manager: correct infrastructure, like the paper's
    disaggregated memory).  Replacement fires only when **f+1 distinct
    current members** accuse the same target *simultaneously* and the
    quorum has been **sustained for ``hold_us``** — so f Byzantine
    replicas spamming accusations can never evict an honest replica (at
    least one honest accuser is required, and honest accusers retract
    when the target shows life).  On top of the quorum: a global
    ``cooldown_us`` between automatic replacements, a replacement
    ``budget`` per ``budget_window_us``, and exponential back-off on
    repeat targets (per *slot*, since the replacement inherits the seat) —
    a flapping gray replica cannot convert suspicion into replacement
    churn.

:class:`ReconfigPlan` / :class:`PlanTable` — recovery is plan *lookup*,
    not online decision-making: for the group's current (f, f_m,
    pool-placement) neighborhood the table fixes, per possible target, the
    target epoch, the joiner pid, the state-transfer sources and the
    ``rekey_owner`` order ahead of time.  ``rotation()`` chains 2f+1 plans
    (consecutive epoch bumps, one per seat) into a rolling full-group
    rotation — the live-upgrade story — executed strictly one at a time
    (the in-flight guard in ``Cluster.replace_replica`` plus the
    monitor's sequential chaining: never more than one concurrent
    replacement per group).

Everything here is opt-in (``Cluster.enable_self_healing``): an
unenabled cluster sends not a single extra byte, so static/golden
deployments are bit-identical with or without this module.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

from repro.core.node import Node


@dataclass
class HealthConfig:
    """Suspicion + gating parameters (see DESIGN_MEMBERSHIP.md)."""
    #: heartbeat period; also the suspicion-evaluation beat
    hb_us: float = 400.0
    #: beats of heartbeat silence before a miss is scored
    miss_after: float = 1.5
    #: per-beat decay of the miss/retransmission accumulator
    decay: float = 0.5
    #: score added per starvation episode seated past the peer
    vc_weight: float = 2.0
    #: score added per TBcast RTO fire toward the peer
    retx_weight: float = 0.5
    #: sliding window for starvation episodes
    signal_window_us: float = 60_000.0
    #: accuse when score ≥ this …
    accuse_score: float = 3.0
    #: … retract only once it falls back under this (hysteresis band)
    retract_score: float = 0.75
    #: an unrefreshed accusation lapses after this long (dead accusers
    #: cannot pin a suspicion forever)
    accuse_ttl_us: float = 2_500.0
    #: the f+1 accuser quorum must be sustained this long before firing
    hold_us: float = 1_500.0
    #: global minimum gap between automatic replacements
    cooldown_us: float = 4_000.0
    #: automatic-replacement budget per ``budget_window_us``
    budget: int = 4
    budget_window_us: float = 200_000.0
    #: repeat replacements of the same *seat* back off exponentially:
    #: the k-th needs ``backoff_base_us · 2^(k-1)`` since the previous
    backoff_base_us: float = 10_000.0
    backoff_max_exp: int = 6
    #: poll period for replacement/rotation completion watches
    poll_us: float = 250.0
    #: consensus-level decision gap repair (cfg.gap_repair_us on every
    #: replica the healing layer manages): a replica stalled behind an
    #: undecided slot pulls the missing commit certificate from members
    #: after this grace.  Rolling rotation depends on it — each step
    #: retires one COMMIT voucher, so an ex-joiner can otherwise go deaf
    #: to a slot decided around its join window until the next summary
    #: boundary (unboundedly far away on a quiet stream).
    gap_repair_us: float = 600.0


def as_health_config(val: Any) -> HealthConfig:
    """Normalize the ``self_heal`` knob: True/None → defaults, a dict →
    overrides, a HealthConfig → itself."""
    if isinstance(val, HealthConfig):
        return val
    if val is None or val is True:
        return HealthConfig()
    if isinstance(val, dict):
        return HealthConfig(**val)
    raise TypeError(f"cannot build a HealthConfig from {val!r}")


# ==========================================================================
# Precomputed reconfiguration plans
# ==========================================================================
@dataclass(frozen=True)
class ReconfigPlan:
    """One precomputed replacement: everything ``replace_replica`` would
    otherwise decide online, fixed ahead of time."""
    #: the epoch this replacement creates (= pre-epoch + 1)
    epoch: int
    old_pid: str
    new_pid: str
    #: expected membership *before* the switch — staleness guard
    members: Tuple[str, ...]
    #: survivors expected to publish ``xfer/<epoch>`` state
    xfer_sources: Tuple[str, ...]
    #: pool names in ``rekey_owner`` order
    rekey_order: Tuple[str, ...]
    #: the (f, f_m, pool-placement) neighborhood the plan was built for
    neighborhood: Tuple[int, int, Tuple[str, ...]] = (0, 0, ())


class PlanTable:
    """Per-cluster table of :class:`ReconfigPlan`\\ s, one per possible
    target in the current membership, refreshed after every epoch switch.

    The table is keyed by the group's *neighborhood* — (f, f_m, pool
    placement) — which fixes everything a plan needs: the joiner pid
    follows the cluster's deterministic naming, the transfer sources are
    the surviving seats, and the rekey order is the placement's pool
    order.  At suspicion time the control plane looks a plan up and
    executes it; it decides nothing.
    """

    def __init__(self, cluster: Any):
        self.cluster = cluster
        self.plans: Dict[str, ReconfigPlan] = {}
        self.built_epoch: int = -1
        self.refresh()

    # ------------------------------------------------------------ building
    def _neighborhood(self) -> Tuple[int, int, Tuple[str, ...]]:
        c = self.cluster
        members = c.current_members()
        f = (len(members) - 1) // 2
        f_m = getattr(c.pools[0], "f_m", 0) if c.pools else 0
        return (f, f_m, tuple(p.name for p in c.pools))

    def _plan(self, epoch: int, members: Tuple[str, ...], old: str,
              new: str, hood: Tuple[int, int, Tuple[str, ...]]
              ) -> ReconfigPlan:
        return ReconfigPlan(
            epoch=epoch, old_pid=old, new_pid=new, members=tuple(members),
            xfer_sources=tuple(m for m in members if m != old),
            rekey_order=hood[2], neighborhood=hood)

    def refresh(self) -> None:
        """Recompute one plan per current member (all sharing the next
        free joiner pid — at most one of them ever executes)."""
        c = self.cluster
        e = c.current_epoch()
        members = tuple(c.current_members())
        hood = self._neighborhood()
        new = c.next_replica_pid()
        self.plans = {old: self._plan(e + 1, members, old, new, hood)
                      for old in members}
        self.built_epoch = e

    def plan_for(self, old_pid: str) -> Optional[ReconfigPlan]:
        return self.plans.get(old_pid)

    def current(self, plan: ReconfigPlan) -> bool:
        """Is the plan still executable against the live cluster state?"""
        c = self.cluster
        return (plan.epoch == c.current_epoch() + 1 and
                plan.members == tuple(c.current_members()))

    def rotation(self) -> List[ReconfigPlan]:
        """Chained plans replacing every current seat, leader last:
        2f+1 consecutive epoch bumps, each plan's expected membership
        being the previous plan's outcome — the rolling full-group
        rotation.  Strictly sequential by construction (plan k+1 is not
        executable until plan k's epoch committed).

        Seat order matters for tail latency: replacing a follower keeps
        the view (the leader seat is untouched, proposals never stop),
        while replacing the leader forces a full view change — seal,
        share collection, NEW_VIEW, repropose — underneath live traffic.
        Scheduling the current leader's seat last pays that cost once
        per rotation instead of at every step."""
        c = self.cluster
        e = c.current_epoch()
        members = list(c.current_members())
        lead = c.current_leader()
        order = [m for m in members if m != lead]
        if lead in members:
            order.append(lead)
        hood = self._neighborhood()
        base = c.next_replica_pid()
        prefix, start = base.rsplit("r", 1)
        plans: List[ReconfigPlan] = []
        for j, old in enumerate(order):
            new = f"{prefix}r{int(start) + j}"
            plans.append(self._plan(e + 1 + j, tuple(members), old, new,
                                    hood))
            members[members.index(old)] = new
        return plans


# ==========================================================================
# Per-replica suspicion agent
# ==========================================================================
class ReplicaHealth:
    """Heartbeats + phi-style per-peer suspicion for one replica.

    Every ``hb_us`` the agent broadcasts a HEALTH_HB to the current
    membership and scores each peer:

    * *heartbeat age* — the freshest HB from the peer older than
      ``miss_after`` beats scores ``age / hb_us`` (a constant-delay gray
      peer shows up here: its HBs arrive, but always stale);
    * *retransmission silence* — TBcast RTO fires toward the peer since
      the last beat, weighted by ``retx_weight``;
    * both feed a per-beat-decayed accumulator, plus ``vc_weight`` per
      starvation episode seated past the peer within ``signal_window_us``.

    Score ≥ ``accuse_score`` → ACCUSE the monitor (refreshed every beat
    while suspect); score back under ``retract_score`` → RETRACT.
    """

    def __init__(self, replica: Any, monitor: "HealthMonitor",
                 cfg: HealthConfig):
        self.replica = replica
        self.monitor = monitor
        self.cfg = cfg
        self.pid = replica.pid
        self.stopped = False
        self.suspects: Set[str] = set()
        self.misses: Dict[str, int] = {}       # per-peer missed-beat count
        self._acc: Dict[str, float] = {}       # decayed miss/retx score
        self._last_hb: Dict[str, float] = {}
        self._retx_seen: Dict[str, int] = {}
        self._starved: Dict[str, List[float]] = {}
        self._seq = 0
        self._last_beat = replica.sim.now
        replica.health_agent = self
        replica.handle("HEALTH_HB", self._on_hb)
        replica.on_starvation_hooks.append(self._on_starvation)
        self._handle = replica.sim.periodic(cfg.hb_us, self._beat)

    def stop(self) -> None:
        """Detach (replica retired by an epoch switch): stop beating and
        go deaf to signal hooks."""
        self.stopped = True
        self._handle.cancel()

    # ------------------------------------------------------------- signals
    def _on_hb(self, src: str, _body: Any) -> None:
        self._last_hb[src] = self.replica.sim.now

    def _on_starvation(self, stale_leader: str) -> None:
        if self.stopped or stale_leader == self.pid:
            return
        self._starved.setdefault(stale_leader, []).append(
            self.replica.sim.now)

    # ---------------------------------------------------------------- beat
    def _beat(self) -> None:
        r = self.replica
        if self.stopped or r.crashed:
            return
        cfg = self.cfg
        sim = r.sim
        now = sim.now
        if now - self._last_beat > 2.0 * cfg.hb_us:
            # first beat, or back from a crash window: grace-reset so
            # peers are not condemned for our own downtime
            for m in list(self._last_hb):
                self._last_hb[m] = now
            self._acc.clear()
        self._last_beat = now
        members = r.membership.replicas
        self._seq += 1
        for m in members:
            if m != self.pid:
                r.send(m, "HEALTH_HB", self._seq)
        # drop state for pids no longer in the membership
        mset = set(members)
        for d in (self._last_hb, self._acc, self._retx_seen, self._starved,
                  self.misses):
            for m in [m for m in d if m not in mset]:
                del d[m]
        self.suspects &= mset
        retx = getattr(getattr(r, "tb", None), "retx_fires", {})
        horizon = now - cfg.signal_window_us
        for m in members:
            if m == self.pid:
                continue
            last = self._last_hb.get(m)
            if last is None:
                last = self._last_hb[m] = now   # grace on first sight
            inst = 0.0
            age = now - last
            if age > cfg.miss_after * cfg.hb_us:
                inst = age / cfg.hb_us
                self.misses[m] = self.misses.get(m, 0) + 1
            seen = retx.get(m, 0)
            delta = seen - self._retx_seen.get(m, 0)
            self._retx_seen[m] = seen
            acc = (self._acc.get(m, 0.0) * cfg.decay + inst +
                   cfg.retx_weight * delta)
            self._acc[m] = acc
            starved = self._starved.get(m)
            if starved:
                starved[:] = [t for t in starved if t >= horizon]
            score = acc + cfg.vc_weight * (len(starved) if starved else 0)
            if score >= cfg.accuse_score:
                self.suspects.add(m)
                r.send(self.monitor.pid, "HEALTH_ACCUSE", (m, score))
            elif m in self.suspects and score <= cfg.retract_score:
                self.suspects.discard(m)
                r.send(self.monitor.pid, "HEALTH_RETRACT", (m,))


# ==========================================================================
# Per-cluster monitor
# ==========================================================================
class HealthMonitor(Node):
    """Control-plane node gating suspicion into plan execution.

    Fires ``cluster.replace_replica(target, plan=...)`` only when the
    accusation quorum, hysteresis hold, cooldown, budget and per-seat
    back-off all pass — see the module docstring.  Also drives rolling
    full-group rotation (:meth:`rotate`).
    """

    def __init__(self, cluster: Any, cfg: Optional[HealthConfig] = None):
        name = getattr(cluster, "name", "")
        pid = f"{name}/healthd" if name else "healthd"
        super().__init__(cluster.sim, cluster.net, cluster.registry, pid)
        self.cluster = cluster
        self.cfg = cfg or HealthConfig()
        self.plans = PlanTable(cluster)
        #: target -> {accuser: time of freshest accusation}
        self.accusations: Dict[str, Dict[str, float]] = {}
        #: target -> time the f+1 quorum was first (continuously) met
        self.quorum_since: Dict[str, float] = {}
        #: (time, accuser, target, score, "accuse" | "retract")
        self.suspicion_log: List[Tuple[float, str, str, float, str]] = []
        #: completed/in-flight automatic replacements (dicts with
        #: t_detect / t_fire / t_active, target, new, epoch)
        self.replacements: List[Dict[str, Any]] = []
        #: (time, target, reason) — gating decisions that deferred a fire
        self.deferred: List[Tuple[float, str, str]] = []
        self.rotation_log: List[Dict[str, Any]] = []
        self.rotating = False
        self._last_fire = float("-inf")
        self._fire_times: List[float] = []
        self._seat_backoff: Dict[int, Tuple[int, float]] = {}
        self.handle("HEALTH_ACCUSE", self._on_accuse)
        self.handle("HEALTH_RETRACT", self._on_retract)
        self._handle = self.sim.periodic(self.cfg.hold_us / 2.0,
                                         self._evaluate)

    # ------------------------------------------------------------ plumbing
    def _on_accuse(self, src: str, body: Any) -> None:
        target, score = body
        if src == target:
            return
        acc = self.accusations.setdefault(target, {})
        if src not in acc:
            self.suspicion_log.append(
                (self.sim.now, src, target, float(score), "accuse"))
        acc[src] = self.sim.now

    def _on_retract(self, src: str, body: Any) -> None:
        target = body[0]
        acc = self.accusations.get(target)
        if acc and src in acc:
            del acc[src]
            self.suspicion_log.append(
                (self.sim.now, src, target, 0.0, "retract"))

    def forget(self, pid: str) -> None:
        """Drop all suspicion state naming ``pid`` (it left the group)."""
        self.accusations.pop(pid, None)
        self.quorum_since.pop(pid, None)
        for acc in self.accusations.values():
            acc.pop(pid, None)

    # ---------------------------------------------------------- evaluation
    def _evaluate(self) -> None:
        now = self.sim.now
        cfg = self.cfg
        members = tuple(self.cluster.current_members())
        f = (len(members) - 1) // 2
        mset = set(members)
        for target in list(self.accusations):
            acc = self.accusations[target]
            for a in [a for a, t in acc.items()
                      if now - t > cfg.accuse_ttl_us]:
                del acc[a]
            if target not in mset:
                self.forget(target)
                continue
            live = [a for a in acc if a in mset and a != target]
            if len(live) >= f + 1:
                self.quorum_since.setdefault(target, now)
            else:
                self.quorum_since.pop(target, None)
                continue
            if now - self.quorum_since[target] >= cfg.hold_us:
                self._try_replace(target, now, members)

    def _defer(self, target: str, reason: str) -> None:
        self.deferred.append((self.sim.now, target, reason))

    def _try_replace(self, target: str, now: float,
                     members: Tuple[str, ...]) -> None:
        cfg = self.cfg
        c = self.cluster
        if self.rotating:
            return self._defer(target, "rotation in flight")
        if c.replacement_in_flight():
            return self._defer(target, "replacement in flight")
        if now - self._last_fire < cfg.cooldown_us:
            return self._defer(target, "cooldown")
        self._fire_times = [t for t in self._fire_times
                            if now - t <= cfg.budget_window_us]
        if len(self._fire_times) >= cfg.budget:
            return self._defer(target, "budget exhausted")
        seat = members.index(target)
        bo = self._seat_backoff.get(seat)
        if bo is not None and now < bo[1]:
            return self._defer(target, f"seat {seat} backoff")
        plan = self.plans.plan_for(target)
        if plan is None or not self.plans.current(plan):
            self.plans.refresh()
            plan = self.plans.plan_for(target)
        if plan is None:
            return self._defer(target, "no plan")
        # replace_replica runs the cluster's replace_hooks synchronously,
        # and one of those is our own forget() — grab t_detect first
        t_detect = self.quorum_since.get(target, now)
        joiner = c.replace_replica(target, plan=plan)
        if joiner is None:
            reason = (c.rejected_replacements[-1][2]
                      if c.rejected_replacements else "rejected")
            return self._defer(target, f"rejected: {reason}")
        rec = {"target": target, "new": plan.new_pid, "epoch": plan.epoch,
               "seat": seat, "t_detect": t_detect,
               "t_fire": now, "t_active": None}
        self.replacements.append(rec)
        self._last_fire = now
        self._fire_times.append(now)
        exp = min(bo[0] if bo else 0, cfg.backoff_max_exp)
        self._seat_backoff[seat] = (
            (bo[0] if bo else 0) + 1,
            now + cfg.backoff_base_us * (2 ** exp))
        self.forget(target)
        self._watch(rec, joiner)

    def _watch(self, rec: Dict[str, Any], joiner: Any) -> None:
        """Poll until the joiner is an active voting member, then stamp
        the recovery time and refresh the plan table for the new epoch."""
        def check() -> None:
            if (not joiner.joining and
                    joiner.membership.epoch >= rec["epoch"]):
                rec["t_active"] = self.sim.now
                self.plans.refresh()
                return
            self.sim.after(self.cfg.poll_us, check)
        self.sim.after(self.cfg.poll_us, check)

    # ------------------------------------------------------------ rotation
    def rotate(self, done_cb: Optional[Callable[[], None]] = None) -> None:
        """Rolling full-group rotation: replace every current seat in
        slot order through chained precomputed plans — 2f+1 consecutive
        epoch bumps, strictly one replacement in flight at a time.

        Aborts (recorded in ``rotation_log``) if a concurrent automatic
        replacement invalidates the chain; automatic replacement is
        suppressed while a rotation runs, so that only happens when an
        operator races the rotation by hand.
        """
        if self.rotating:
            raise RuntimeError("a rotation is already in flight")
        self.plans.refresh()
        chain = self.plans.rotation()
        self.rotating = True
        log = self.rotation_log = []
        c = self.cluster
        poll = self.cfg.poll_us

        def settled_at(e: int) -> bool:
            live = [r for r in c.replicas if not r.crashed and not r.joining]
            return (bool(live) and not c.replacement_in_flight() and
                    all(r.membership.epoch == e for r in live))

        def step(i: int) -> None:
            if i == len(chain):
                self.rotating = False
                self.plans.refresh()
                if done_cb is not None:
                    done_cb()
                return
            plan = chain[i]

            def try_fire() -> None:
                if not settled_at(plan.epoch - 1):
                    self.sim.after(poll, try_fire)
                    return
                joiner = c.replace_replica(plan.old_pid, plan=plan)
                if joiner is None:
                    reason = (c.rejected_replacements[-1][2]
                              if c.rejected_replacements else "rejected")
                    log.append({"step": i, "old": plan.old_pid,
                                "epoch": plan.epoch, "aborted": reason})
                    self.rotating = False
                    return
                rec = {"step": i, "old": plan.old_pid, "new": plan.new_pid,
                       "epoch": plan.epoch, "t_fire": self.sim.now,
                       "t_done": None}
                log.append(rec)

                def wait_done() -> None:
                    if settled_at(plan.epoch) and not joiner.joining:
                        rec["t_done"] = self.sim.now
                        step(i + 1)
                    else:
                        self.sim.after(poll, wait_done)
                self.sim.after(poll, wait_done)
            try_fire()
        step(0)
