"""Tail Broadcast (TBcast) — §4.1/§6.2 of the paper.

Best-effort broadcast with *tail* semantics and finite memory:

* the broadcaster buffers only its last ``2t`` messages per stream and
  retransmits them until acknowledged — older messages are evicted
  ("overwritten", §6.2) and may never be delivered;
* correct receivers deliver FIFO per stream and are guaranteed the last
  ``2t`` messages of a correct broadcaster (eventually, post-GST);
* TBcast provides all CTBcast properties except agreement (a Byzantine
  broadcaster can equivocate here — CTBcast fixes that on top).

The wire substrate is the paper's circular-buffer primitive (§6.2): no
per-message acknowledgements on the critical path (acks ride a coarse timer,
mirroring the paper's piggybacking), sender-side eviction under backlog, and
FIFO skip-ahead at the receiver when the sender's window has moved on (the
``min_k`` field plays the role of the incarnation-number scan).

Memory accounting (Table 2): each stream×peer connection owns ``t`` wire
slots plus a ``t``-deep staging buffer, each slot sized for the largest
message — exposed through :meth:`TBcastService.memory_bytes`.

Ack/RTO timer lifecycle across crashes
--------------------------------------
Both coarse timers are guarded by a *pending* flag (``ack_pending`` on the
receive side, ``rto_pending`` on the send side) so at most one timer per
state is ever in flight.  The flags therefore carry a liveness obligation:
whoever sets one must guarantee the matching ``_fire`` eventually clears
it, **including across a crash+recover of this node** (crash-recover
preserves all state — §2's crash-recovery processes).  The rules:

* timers are scheduled on the raw simulator (``sim.after``), *not* through
  ``Node.timer``: the fire always runs, clears its pending flag first, and
  only then checks ``crashed`` before acting.  A fire during the crash
  window is thus a flag reset, never an ack/retransmission — a crashed
  node stays silent, but cannot strand its own bookkeeping.
* a ``Node.recover_hooks`` entry (:meth:`TBcastService._on_recover`)
  re-arms whatever the crash window dropped: receive states with
  undelivered acks schedule a fresh ack (so live senders' retransmission
  loops quiesce as soon as the node returns), and send states with live
  unacked window entries re-arm their RTO (a crash between fires would
  otherwise leave the window un-retransmitted until an unrelated broadcast
  happened to land on the same stream).
* retransmission to an unresponsive peer decays: every RTO fire that
  retransmits without intervening ack progress doubles the next interval
  (bounded by ``2^rto_backoff_max``); any ack progress resets the interval
  to ``rto_us``.  Steady-state chatter toward a crashed/partitioned peer
  is therefore bounded instead of a full-window resend every ``rto_us``
  forever.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import crypto
from repro.core.node import Node

#: per-slot header: checksum(8) + incarnation(8) + size(8)  (§6.2)
SLOT_HEADER = 24


@dataclass
class _SendState:
    """Sender-side per (stream, dst) window."""
    window: Dict[int, Any] = field(default_factory=dict)  # k -> payload
    min_k: int = 0          # lowest k still buffered
    next_k: int = 0
    acked: int = -1         # highest contiguously acked k
    rto_pending: bool = False
    backoff: int = 0        # consecutive no-progress RTO fires (exponent)
    rto_gen: int = 0        # invalidates superseded in-flight RTO timers
    rto_at: float = 0.0     # when the pending RTO fire is scheduled


@dataclass
class _RecvState:
    """Receiver-side per (origin, stream) reorder buffer."""
    pending: Dict[int, Any] = field(default_factory=dict)
    next_k: int = 0         # next k to deliver FIFO
    max_seen: int = -1
    ack_pending: bool = False
    last_acked: int = -1


class TBcastService:
    """Multiplexes tail-broadcast streams for one node."""

    def __init__(self, node: Node, t: int, rto_us: float = 60.0,
                 ack_interval_us: float = 40.0, max_msg_bytes: int = 4096,
                 rto_backoff_max: int = 6):
        self.node = node
        self.t = t
        self.rto_us = rto_us
        self.ack_interval_us = ack_interval_us
        self.max_msg_bytes = max_msg_bytes
        #: cap on the no-progress backoff exponent: the retransmission
        #: interval to an unresponsive peer decays to 2^max × rto_us and
        #: stays there (bounded — the peer may yet recover)
        self.rto_backoff_max = rto_backoff_max
        self._send: Dict[Tuple[str, str], _SendState] = {}   # (stream, dst)
        self._recv: Dict[Tuple[str, str], _RecvState] = {}   # (origin, stream)
        #: per-dst count of RTO fires that actually retransmitted — a peer
        #: that stops acking shows up here (the health layer's "ack
        #: silence" suspicion signal; local bookkeeping, no wire effect)
        self.retx_fires: Dict[str, int] = {}
        self._handlers: List[Tuple[str, Callable[[str, str, int, Any], None]]] = []
        self._route: Dict[str, Optional[Callable]] = {}  # stream -> handler
        self._conns: set = set()
        node.handle("TB", self._on_tb)
        node.handle("TB_ACK", self._on_ack)
        node.recover_hooks.append(self._on_recover)

    # ------------------------------------------------------------------ API
    def register(self, prefix: str,
                 handler: Callable[[str, str, int, Any], None]) -> None:
        """handler(origin_pid, stream, k, payload); matched by stream prefix."""
        self._handlers.append((prefix, handler))
        self._route.clear()   # memoized routes may predate this prefix

    def broadcast(self, stream: str, k: int, payload: Any,
                  group: List[str]) -> None:
        """Broadcast (k, payload) on ``stream`` to ``group`` (may include self)."""
        # wire size is identical for every destination — price it once.
        # Shallow sizing: vote payloads are fresh per-broadcast tuples
        # (their shared subtrees still hit the memo), so inserting the
        # wrapper itself into the wire cache would be pure churn.
        # (38 = tuple header 4 + two int fields 16 + kind "TB" 2 + framing 16)
        size = 38 + len(stream) + crypto.wire_size_shallow(payload)
        node = self.node
        sim = node.sim
        now = sim.now
        rto = self.rto_us
        # Consecutive wire destinations accumulate into one run shipped via
        # send_fanout (guards + pricing hoisted, one heap entry when jitter
        # permits).  A self-delivery flushes the run first, so every heap
        # push happens in the same relative order as the per-dst loop this
        # replaced.  Regrouping each run's sends before its RTO arms cannot
        # create a (time, seq) tie: arrivals land ≤ ~6 µs out, RTO timers
        # ≥ rto_us (60 µs) out — see DESIGN_PERF.md.
        pend_dst: List[str] = []
        pend_st: List[_SendState] = []

        def _flush() -> None:
            mk = pend_st[0].min_k
            if all(st.min_k == mk for st in pend_st):
                node.net.send_fanout(node.pid, pend_dst,
                                     ("TB", (stream, k, mk, payload)), size)
            else:   # window floors diverged (post-eviction): per-dst frames
                for dst, st in zip(pend_dst, pend_st):
                    node.net.send(node.pid, dst,
                                  ("TB", (stream, k, st.min_k, payload)), size)
            for dst, st in zip(pend_dst, pend_st):
                # the second disjunct catches a stale long-backoff timer
                # outliving an ack-progress reset: fresh traffic then
                # supersedes it instead of waiting out the decay
                if (not st.rto_pending or
                        st.rto_at > now + rto * (1 << st.backoff)):
                    self._arm_rto(stream, dst, st)
            pend_dst.clear()
            pend_st.clear()

        for dst in group:
            if dst == node.pid:
                if pend_dst:
                    _flush()
                # Local self-delivery (no wire) — still costs a dispatch.
                if not node.crashed:
                    done = node.occupy(node.handling_cost)

                    def _self(kk=k, pl=payload) -> None:
                        if not node.crashed:
                            self._deliver(node.pid, stream, kk, pl)

                    sim.at(done, _self)
                continue
            key = (stream, dst)
            st = self._send.get(key)
            if st is None:   # avoid constructing a throwaway default
                st = self._send[key] = _SendState()
                self._conns.add(key)
            # min_k is maintained incrementally (an O(n) min() per
            # broadcast dominated the hot path); the O(n) recompute only
            # runs on the rare eviction under backlog.
            if not st.window or k < st.min_k:
                st.min_k = k
            st.window[k] = payload
            if k >= st.next_k:
                st.next_k = k + 1
            # Evict beyond 2t (tail semantics: old messages are overwritten).
            while len(st.window) > 2 * self.t:
                oldest = min(st.window)
                del st.window[oldest]
                st.min_k = min(st.window)
            pend_dst.append(dst)
            pend_st.append(st)
        if pend_dst:
            _flush()

    def drop_peer(self, pid: str) -> None:
        """Free every connection to/from a replica retired by an epoch
        switch: its send windows stop retransmitting and its receive
        buffers are released, so the preallocated wire memory of §6.2
        (``memory_bytes``) stays bounded across replacements instead of
        accumulating one dead connection set per retired pid."""
        for key in [key for key in self._send if key[1] == pid]:
            st = self._send[key]
            # a pending RTO still holds a reference: empty the window so
            # the timer chain finds nothing live and stops re-arming
            st.window.clear()
            st.acked = st.next_k
            del self._send[key]
            self._conns.discard(key)
        for key in [key for key in self._recv if key[0] == pid]:
            del self._recv[key]

    # ----------------------------------------------------------------- wire
    def _arm_rto(self, stream: str, dst: str,
                 st: Optional[_SendState] = None) -> None:
        if st is None:
            st = self._send[(stream, dst)]
        delay = self.rto_us * (1 << st.backoff)
        due = self.node.sim.now + delay
        if st.rto_pending and st.rto_at <= due:
            return
        # either nothing pending, or the pending fire sits further out than
        # the current backoff warrants (it was armed under a higher exponent
        # before an ack reset it): supersede the old timer via the
        # generation counter — simulator timers cannot be cancelled
        st.rto_pending = True
        st.rto_at = due
        st.rto_gen += 1
        gen = st.rto_gen

        def _fire() -> None:
            if gen != st.rto_gen:
                return      # superseded by a re-arm with a shorter delay
            # the flag reset must survive a crash window (see the module
            # docstring's timer-lifecycle rules): clear first, then gate
            # the actual retransmission on liveness.  Recovery re-arms.
            st.rto_pending = False
            if self.node.crashed:
                return
            live = {k: v for k, v in st.window.items() if k > st.acked}
            if not live:
                st.backoff = 0
                return
            st.min_k = min(st.window) if st.window else st.next_k
            self.retx_fires[dst] = self.retx_fires.get(dst, 0) + 1
            # batch-size the retransmission sweep: one sizing pass for the
            # whole live window (payloads are long-lived — all memo hits)
            ks = sorted(live)
            sizes = crypto.wire_size_batch([live[kk] for kk in ks])
            frame = 38 + len(stream)
            send = self.node.net.send
            pid = self.node.pid
            mk = st.min_k
            for kk, sz in zip(ks, sizes):
                send(pid, dst, ("TB", (stream, kk, mk, live[kk])), frame + sz)
            # no ack progress since the last fire (an ack would have reset
            # the exponent): decay the next interval instead of flooding a
            # dead peer with a full-window resend every rto_us forever
            if st.backoff < self.rto_backoff_max:
                st.backoff += 1
            self._arm_rto(stream, dst)

        self.node.sim.after(delay, _fire)

    # ------------------------------------------------------------- receive
    def _on_tb(self, src: str, body: Any) -> None:
        stream, k, min_k, payload = body
        key = (src, stream)
        rs = self._recv.get(key)
        if rs is None:
            rs = self._recv[key] = _RecvState()
        if k < rs.next_k:
            self._maybe_ack(src, stream, rs)
            return  # duplicate / already delivered
        if k == rs.next_k and not rs.pending:
            # in-order fast path (the overwhelmingly common case): skip the
            # reorder-buffer round trip.  k == next_k implies min_k <= next_k,
            # so the skip-ahead below would be a no-op anyway.
            if k > rs.max_seen:
                rs.max_seen = k
            rs.next_k = k + 1
            handler = self._route.get(stream)
            if handler is not None:
                handler(src, stream, k, payload)
            else:
                self._deliver(src, stream, k, payload)
            if not rs.ack_pending and k > rs.last_acked:
                self._maybe_ack(src, stream, rs)
            return
        rs.max_seen = max(rs.max_seen, k)
        rs.pending[k] = payload
        # Skip-ahead: anything below the sender's window floor is lost
        # (overwritten at the sender) — FIFO pointer jumps forward (§6.2).
        if min_k > rs.next_k:
            for kk in [x for x in rs.pending if x < min_k]:
                del rs.pending[kk]
            rs.next_k = min_k
        self._drain(src, stream, rs)
        self._maybe_ack(src, stream, rs)

    def _drain(self, origin: str, stream: str, rs: _RecvState) -> None:
        while rs.next_k in rs.pending:
            payload = rs.pending.pop(rs.next_k)
            k = rs.next_k
            rs.next_k += 1
            self._deliver(origin, stream, k, payload)
        # Bound the reorder buffer (Byzantine sender flooding far-future ks).
        if len(rs.pending) > 2 * self.t:
            for kk in sorted(rs.pending)[: len(rs.pending) - 2 * self.t]:
                del rs.pending[kk]

    def _deliver(self, origin: str, stream: str, k: int, payload: Any) -> None:
        try:
            handler = self._route[stream]
        except KeyError:
            handler = None
            for prefix, h in self._handlers:
                if stream.startswith(prefix):
                    handler = h
                    break
            self._route[stream] = handler
        if handler is not None:
            handler(origin, stream, k, payload)

    def _maybe_ack(self, origin: str, stream: str, rs: _RecvState) -> None:
        if rs.ack_pending or rs.next_k - 1 <= rs.last_acked:
            return
        rs.ack_pending = True

        def _fire() -> None:
            # clear the flag unconditionally — a fire swallowed whole by a
            # crash guard used to strand ack_pending=True forever, leaving
            # every live sender retransmitting its window to this replica
            # indefinitely after recovery (duplicates with k < next_k hit
            # the pending-flag early-return above and never re-acked)
            rs.ack_pending = False
            if self.node.crashed:
                return      # stay silent; _on_recover re-arms if needed
            rs.last_acked = rs.next_k - 1
            self.node.send(origin, "TB_ACK", (stream, rs.last_acked))

        self.node.sim.after(self.ack_interval_us, _fire)

    def _on_ack(self, src: str, body: Any) -> None:
        stream, upto = body
        st = self._send.get((stream, src))
        if st is None:
            return
        if upto > st.acked:
            st.backoff = 0      # ack progress: retransmission back to rto_us
        st.acked = max(st.acked, upto)
        for k in [k for k in st.window if k <= st.acked]:
            del st.window[k]
        if st.window:
            st.min_k = min(st.window)

    # ------------------------------------------------------------- recovery
    def _on_recover(self) -> None:
        """Re-arm timer-driven state after a crash+recover of this node.

        Crash-recover preserves all broadcast state, but any ack/RTO fire
        that landed inside the crash window only reset its pending flag —
        the ack was never sent and the RTO chain was not re-armed.  On the
        receive side that leaves live senders retransmitting to us until we
        ack again; on the send side it leaves unacked window entries that
        would only be retransmitted if a fresh broadcast happened to land
        on the same stream.  Both are quiesced here."""
        for (origin, stream), rs in self._recv.items():
            self._maybe_ack(origin, stream, rs)
        for (stream, dst), st in self._send.items():
            if any(k > st.acked for k in st.window):
                self._arm_rto(stream, dst, st)

    # ---------------------------------------------------------- accounting
    def memory_bytes(self) -> int:
        """Preallocated wire memory (§6.2): per connection, t slots + t-deep
        staging area, each slot sized for the largest message + header."""
        slot = self.max_msg_bytes + SLOT_HEADER
        return len(self._conns) * 2 * self.t * slot
