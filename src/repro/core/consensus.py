"""uBFT consensus + SMR replica — Algorithms 2, 3, 4, 5 of the paper.

Layout of one replica (Figure 2):

    RPC ──> consensus ──> execution ──> RPC reply
             │  fast path: CTBcast(PREPARE) → TB(WILL_CERTIFY) → TB(WILL_COMMIT)
             │  slow path: CTBcast(PREPARE) → TB(CERTIFY,σ) → CTBcast(COMMIT,P_Σ)
             └─ view change: CTBcast(SEAL_VIEW) → direct CRTFY_VC → CTBcast(NEW_VIEW)

Every replica owns one CTBcast *instance per broadcaster* and interprets each
peer's CTBcast messages in FIFO order (Alg. 2 line 1), applying the Byzantine
checks of Algorithm 5 before accepting each message; a check violation
*blocks* that peer permanently.  Tail-validity gaps are healed by CTBcast
summaries (Algorithm 4): the broadcaster blocks every t/2 broadcasts until
f+1 receivers certify a digest of its recent window (double buffering,
footnote 3), and the resulting SUMMARY lets laggards jump their FIFO pointer.

Memory is practically bounded: prepares/commits/promises are dropped when the
application checkpoint (f+1 signed) slides the consensus window forward.

Hot path extensions beyond the paper's evaluation (§9 discusses throughput):
the unit of agreement is a *batch* of client requests (``as_batch``) — the
leader coalesces up to ``max_batch`` pending requests per CTBcast slot and
up to ``pipeline_depth`` slots are in flight concurrently, so throughput is
no longer bound to one request per protocol round.  Replicas execute batches
atomically and reply per-request; all safety invariants (agreement,
integrity, bounded memory) hold over batches.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Set, Tuple

from repro.core import crypto
from repro.core.crypto import SignedBundle
from repro.core.ctbcast import CTBcast
from repro.core.membership import MembershipEpoch
from repro.core.node import Node
from repro.core.registers import RegisterClient
from repro.core.tbcast import TBcastService
from repro.sim.events import Simulator
from repro.sim.net import NetworkModel


# --------------------------------------------------------------------------
# Configuration
# --------------------------------------------------------------------------
@dataclass
class AdmissionConfig:
    """SLO-aware admission control at the leader (the serving plane).

    When the leader's client backlog (requests accepted but not yet
    executed — the queue depth against the §5.4 direct-copy horizon)
    exceeds ``queue_high``, newly echoed client requests are not fed
    into the pipeline; instead the leader proposes an agreed *shed
    marker* ``(("shed", rid), "", b"")`` for them.  Executing the marker
    makes every honest replica send the identical deterministic
    ``reply`` (default ``b"BUSY"``), so the client completes on the
    normal f+1 matching-reply quorum instead of timing out into the
    collapsing queue.

    Sheds are *agreed*, and followers are their auditors: a replica only
    endorses (or signs a certificate over) a shed-bearing slot while its
    own backlog is at least ``queue_accept`` — a Byzantine leader
    shedding honest requests under light load never collects an honest
    certificate quorum and loses its view to the normal progress timer.
    """
    queue_high: int = 64           # leader sheds above this backlog
    queue_accept: Optional[int] = None   # follower vouch floor (default high/2)
    max_shed: int = 8              # shed markers per batch slot
    reply: bytes = b"BUSY"         # deterministic agreed reply

    def accept_floor(self) -> int:
        if self.queue_accept is not None:
            return self.queue_accept
        return max(1, self.queue_high // 2)


@dataclass
class ConsensusConfig:
    window: int = 256          # consensus slots per checkpoint (§7)
    t: int = 128               # CTBcast tail parameter (§7)
    f: int = 1                 # Byzantine replicas tolerated (n = 2f+1)
    f_m: int = 1               # crash-faulty memory nodes (2f_m+1 total)
    slow_after_us: float = 400.0   # fast→slow escalation timeout
    view_timeout_us: float = 4000.0
    fast_enabled: bool = True
    ctb_fast_enabled: bool = True  # CTBcast's own fast path (LOCK/LOCKED)
    slow_mode: str = "timeout"     # "timeout" | "always" (bench the slow path)
    echo_timeout_us: float = 100.0
    max_request_bytes: int = 8192
    # --- batching + pipelining (the consensus hot path) ---------------
    # The unit of agreement is a *batch*: the leader coalesces up to
    # ``max_batch`` pending requests (bounded by ``max_batch_bytes`` of
    # payload) into one CTBcast slot; replicas execute batches atomically
    # and reply per-request.  ``max_batch=1`` is the paper's
    # one-request-per-slot configuration.
    max_batch: int = 1
    max_batch_bytes: int = 16384
    # With queued requests and a non-full batch, wait up to this long for
    # more to coalesce (0 = propose immediately; batches still form under
    # backpressure from the pipeline cap).
    batch_timeout_us: float = 0.0
    # Max consensus slots in flight (proposed but not yet executed) —
    # slots no longer lock-step one decided round at a time.
    pipeline_depth: int = 64
    # Decision gap repair: a replica whose execution is stalled behind an
    # undecided slot while a *later* slot is already decided pulls the
    # missing commit certificate from current members after this grace
    # period (then retries at the same cadence).  None disables the
    # repair path entirely — no timers, no wire traffic (the default:
    # recorded scenarios predate the mechanism).  The self-healing
    # membership layer turns it on.
    gap_repair_us: Optional[float] = None
    # SLO-aware admission control (the serving plane).  None — the
    # default, and every recorded scenario — disables shedding entirely:
    # no shed markers are proposed, validated, or accepted on the wire.
    admission: Optional[AdmissionConfig] = None


# --------------------------------------------------------------------------
# Application interface (the replicated state machine)
# --------------------------------------------------------------------------
class App:
    """Deterministic state machine: bytes request -> bytes response."""

    def apply(self, req: bytes) -> bytes:  # pragma: no cover - interface
        raise NotImplementedError

    def apply_from(self, caller: str, req: bytes) -> bytes:
        """Caller-aware apply.  ``caller`` is the authenticated pid of the
        submitting client — it is part of the agreed batch (and checked
        against the network sender at REQ ingress), so every honest
        replica hands the same caller to the same request and determinism
        is preserved.  ``""`` marks internally-originated requests
        (service-level ``("svc", ...)`` slots).  The default ignores the
        caller; apps enforcing caller-bound operations (e.g. the 2PC
        coordinator's owner-only commit-DECIDE) override this."""
        return self.apply(req)

    def cost_us(self, req: bytes) -> float:
        """Deterministic execution cost of one request in simulated µs.

        The default (0.0 — and any app that does not override this) keeps
        execution instantaneous, exactly the pre-serving-plane behaviour.
        An app that overrides it (e.g. the token server charging roofline
        decode time per generated token) turns on the deferred execution
        engine: each decided slot occupies the replica's serial app engine
        for the batch's summed cost before it applies, so ``exec_upto``
        lags the decided frontier by the true service backlog.  Must be a
        pure function of the request bytes and the app state at the
        slot's execution point — every honest replica computes it at the
        same state, so the engine stays deterministic."""
        return 0.0

    def snapshot(self) -> Any:
        return None

    def adopt(self, snap: Any) -> None:
        pass

    def snapshot_fp(self) -> bytes:
        return crypto.fingerprint_cached(self.snapshot())


# --------------------------------------------------------------------------
# Per-peer consensus state (Alg. 2 lines 6-12)
# --------------------------------------------------------------------------
@dataclass
class PeerState:
    view: int = 0
    seal_view: Optional[int] = None
    new_view: Optional[Any] = None
    noncp_msgs_in_view: int = 0    # non-CHECKPOINT messages since last SEAL_VIEW
    prepares: Dict[int, Tuple[int, Any]] = field(default_factory=dict)  # slot -> (view, req)
    commits: Dict[int, Any] = field(default_factory=dict)               # slot -> commit cert
    checkpoint: Optional[Any] = None
    blocked: bool = False          # Byzantine message observed → stop
    # False while this peer's view lineage is unknown to us: either we
    # joined after the peer last sealed a view (the replayed seals were
    # epoch-gated out), or the peer sealed into a future epoch we have
    # not applied yet.  While unsynced, Byzantine-check failures drop the
    # message instead of blocking the stream — an honest peer whose view
    # we simply cannot know yet must not be cut off forever.  The first
    # same-epoch SEAL_VIEW re-establishes the view and restores strict
    # checking.
    view_synced: bool = True
    # FIFO reorder machinery for this peer's CTBcast stream
    fifo_pending: Dict[int, Any] = field(default_factory=dict)
    fifo_next: int = 0
    recent: Dict[int, Any] = field(default_factory=dict)  # last t processed (k -> msg)


def _cp_payload(start: int, window: int, app_fp: bytes) -> tuple:
    return ("cp", start, window, app_fp)


def _noop_request(v: int, s: int) -> tuple:
    """⊥ proposal used by a new leader to fill unconstrained holes."""
    return (("noop", v, s), "", b"")


def as_batch(reqs: Any) -> tuple:
    """Normalize a PREPARE payload to a batch: a tuple of request triples.

    The legacy wire format carried a single ``(rid, client, payload)``
    triple per slot; batched PREPAREs carry a tuple of such triples.  The
    unit of agreement (what gets fingerprinted, certified and decided) is
    always the normalized batch, so both formats agree on encoding.
    """
    if (isinstance(reqs, tuple) and len(reqs) == 3 and
            isinstance(reqs[1], str) and isinstance(reqs[2], bytes)):
        return (reqs,)
    return tuple(reqs)


class Checkpoint:
    """An f+1-signed application checkpoint (genesis has no sigs)."""

    def __init__(self, start: int, window: int, app_fp: bytes,
                 sigs: Tuple[Tuple[str, bytes], ...] = ()):  # ((pid, sig), ...)
        self.start = start
        self.window = window
        self.app_fp = app_fp
        self.sigs = sigs
        # cached: ``s in cp.open_slots`` runs several times per message and
        # a fresh range() per access showed up in the hot-path profile
        self._open = range(start, start + window)

    @property
    def open_slots(self) -> range:
        return self._open

    def payload(self) -> tuple:
        return _cp_payload(self.start, self.window, self.app_fp)

    def supersedes(self, other: "Checkpoint") -> bool:
        return self.start > other.start

    def valid(self, registry: crypto.KeyRegistry, quorum: int) -> bool:
        if self.start == 0:
            return True  # genesis
        pids = {pid for pid, _ in self.sigs}
        return (len(pids) >= quorum and
                all(registry.verify(pid, self.payload(), sig)
                    for pid, sig in self.sigs))

    def to_wire(self) -> tuple:
        return ("CPCERT", self.start, self.window, self.app_fp, tuple(self.sigs))

    @staticmethod
    def from_wire(w: tuple) -> "Checkpoint":
        _tag, start, window, app_fp, sigs = w
        return Checkpoint(start, window, app_fp, tuple(sigs))


# --------------------------------------------------------------------------
# The replica
# --------------------------------------------------------------------------
class UbftReplica(Node):
    """A uBFT replica: consensus engine + execution + RPC endpoint."""

    #: per-request framing inside a batched slot (rid + client id wire
    #: bytes on top of crypto.REQUEST_WIRE_OVERHEAD's length header)
    _REQ_FRAMING = crypto.REQUEST_WIRE_OVERHEAD + 64

    def __init__(self, sim: Simulator, net: NetworkModel,
                 registry: crypto.KeyRegistry, pid: str,
                 replicas: List[str], mem_nodes,
                 app: App, cfg: Optional[ConsensusConfig] = None,
                 namespace: str = "", joining: bool = False,
                 epoch: int = 0):
        # ``mem_nodes``: a bare pid list (legacy static TCB), one
        # ``MemoryPool`` or a list of pools (sharded disaggregated memory) —
        # handed to RegisterClient, which shards register keys across pools
        # and tracks pool membership across reconfigurations; every CTBcast
        # instance below rides the same pool-aware client.
        # ``namespace`` is the application name when many replicated
        # applications share one substrate: register keys shard by
        # ``crc32(app:owner:reg)`` so each app spreads over the shared
        # pools independently ("" = legacy single-app layout).
        # ``joining``/``epoch``: a replacement replica is installed
        # *non-voting* (``joining=True``) with ``replicas`` naming the
        # current epoch's members (itself excluded); it observes but casts
        # no votes until the epoch bump commits through a consensus slot
        # and f+1 members of the new epoch confirm the switch (EPOCH).
        super().__init__(sim, net, registry, pid)
        self.namespace = namespace
        self.cfg = cfg or ConsensusConfig()
        self.membership = MembershipEpoch(epoch, tuple(replicas))
        self.replicas = list(replicas)
        self.n = len(replicas)
        self.f = self.cfg.f
        assert self.n == 2 * self.f + 1, "uBFT runs with 2f+1 replicas"
        assert self.cfg.max_batch >= 1 and self.cfg.pipeline_depth >= 1, \
            "max_batch and pipeline_depth must be >= 1"
        self.quorum = self.f + 1
        self.joining = joining
        assert joining == (pid not in self.membership.replicas), \
            "a member replica must not join; a joiner is not yet a member"
        self._member_set = frozenset(replicas)
        #: pids replaced out of the group — their streams are stale-epoch
        self.retired: Set[str] = set()
        #: epoch -> (old_pid, new_pid) the control plane announced; a
        #: MEMBERSHIP slot only applies when it matches (a Byzantine leader
        #: cannot smuggle an unauthorised membership change past execution)
        self.pending_membership: Dict[int, Tuple[str, str]] = {}
        #: joiner activation: (epoch, members) -> confirming member pids
        self._epoch_votes: Dict[tuple, Set[str]] = {}
        self._epoch_view: Dict[tuple, int] = {}
        self._join_state: Optional[dict] = None
        #: completed switches, for the control plane / tests:
        #: (sim time, epoch, old_pid, new_pid)
        self.epoch_switches: List[Tuple[float, int, str, str]] = []
        self.app = app

        # A TBcast slot must hold the largest message: with batching that is
        # a PREPARE carrying up to max_batch_bytes of coalesced payload plus
        # per-request framing that scales with max_batch (Table 2 accounting
        # prices the batched slots honestly).
        slot_payload = max(self.cfg.max_request_bytes,
                           self.cfg.max_batch_bytes +
                           self.cfg.max_batch * self._REQ_FRAMING
                           if self.cfg.max_batch > 1 else 0)
        self.tb = TBcastService(self, t=self.cfg.t,
                                max_msg_bytes=slot_payload + 512)
        self.regs = RegisterClient(self, mem_nodes, self.cfg.f_m,
                                   namespace=namespace)

        # --- consensus state (Alg. 2 lines 1-12) ---
        self.view = 0
        self._leader_pid = replicas[0]  # cached replicas[view % n]
        self.next_slot = 0
        self.checkpoint = Checkpoint(0, self.cfg.window, app.snapshot_fp())
        # Participants I interpret CTBcast streams of: the current members,
        # plus myself when I am a joiner (not yet in the member list).
        participants = list(replicas)
        if pid not in self._member_set:
            participants.append(pid)
        self.state: Dict[str, PeerState] = {r: PeerState()
                                            for r in participants}
        for st in self.state.values():
            st.checkpoint = self.checkpoint
            # a joiner has no record of any peer's sealed views — the
            # replay epoch-gates out pre-join lineage, so strict view
            # checks must wait for each peer's first same-epoch seal
            st.view_synced = not joining
        #: app snapshots taken exactly at checkpoint boundaries — the only
        #: snapshots whose fingerprint a signed checkpoint can vouch for
        #: (served to joiners via XFER_REQ and published by publish_xfer)
        self._boundary_snaps: Dict[int, Any] = {0: app.snapshot()}

        self.decided: Dict[int, tuple] = {}        # slot -> request tuple
        self.exec_upto = -1                         # highest executed slot
        self.results: Dict[int, bytes] = {}
        self._last_cp_broadcast = 0

        # fast-path bookkeeping (bounded by window; pruned at checkpoints)
        self.will_certify: Dict[Tuple[int, int], Set[str]] = {}
        self.will_commit: Dict[Tuple[int, int], Set[str]] = {}
        self.my_will_certifies: Set[Tuple[int, int]] = set()
        self.my_will_commits: Set[Tuple[int, int]] = set()
        self.my_certified: Set[Tuple[int, int]] = set()
        self.my_prepared: Dict[int, Tuple[int, tuple]] = {}   # slot -> (view, req)
        self.certify_sigs: Dict[Tuple[int, int, bytes], Dict[str, bytes]] = {}
        self.my_commits: Dict[int, Any] = {}        # slot -> commit cert I broadcast
        #: slot -> sender -> cert: decided-slot certificates attached to a
        #: JOIN_SYNC (vouched by the sender, never on its stream)
        self.vouched_commits: Dict[int, Dict[str, Any]] = {}
        self.cp_sigs: Dict[tuple, Dict[str, bytes]] = {}

        # RPC / client handling
        self.pending_req: Dict[tuple, tuple] = {}   # rid -> request tuple
        self.echoes: Dict[tuple, Set[str]] = {}
        self.propose_queue: Deque[tuple] = deque()
        self.proposed_rids: Set[tuple] = set()
        self.decided_rids: Set[tuple] = set()
        self.waiting_prepare: Dict[tuple, List[Tuple[int, int]]] = {}
        # (v, s) -> rids of the batch still awaiting the clients' direct
        # copies; the slot is endorsed once the set drains (§5.4, batched)
        self.prepare_missing: Dict[Tuple[int, int], Set[tuple]] = {}
        self._batch_timer_armed = False
        self._batch_flush_due = False

        # view change
        self.vc_shares: Dict[Tuple[int, str], Dict[str, Tuple[bytes, bytes]]] = {}
        self.vc_snapshots: Dict[Tuple[int, str], Any] = {}
        self.changing_view = False
        self.new_view_sent: Set[int] = set()
        # views whose NEW_VIEW I (as leader) have FIFO-self-delivered —
        # next_slot is established by _repropose only then
        self.reproposed_views: Set[int] = set()
        self.progress_deadline: Optional[float] = None
        # Patience grows exponentially with consecutive failed views and
        # resets on progress (needed for liveness under eventual synchrony:
        # a view must eventually outlast the slow path).
        self.view_patience = self.cfg.view_timeout_us
        self.executed_rids: Set[tuple] = set()
        # Self-healing telemetry (core/health.py): per-replica health
        # signals latent in the protocol, kept as plain local counters —
        # zero wire traffic, so static/golden deployments are unaffected.
        # ``seated_past`` counts, per peer pid, the progress-timer
        # starvations this replica observed while that pid held the
        # leader's seat (the "repeated view changes seating past the same
        # pid" suspicion signal).
        self.health_counters: Dict[str, Any] = {
            "starvations": 0,       # own progress-deadline expiries
            "view_changes": 0,      # views this replica entered
            "seated_past": {},      # pid -> starvations under its lead
        }
        # fired with the abandoned leader's pid on every local
        # progress-deadline expiry — the health agent's event feed
        self.on_starvation_hooks: List[Callable[[str], None]] = []
        # Decision gap repair (cfg.gap_repair_us; off by default).  A
        # rotation retires one voucher per step, so a replica that joined
        # mid-stream can end up short of the f+1 COMMIT vouchers for a
        # slot decided around its join window — with nothing left on any
        # live stream to close the gap until the sender's next summary
        # boundary.  The repair path pulls the missing certificate from
        # current members instead of waiting.
        self.gap_repair_us: Optional[float] = self.cfg.gap_repair_us
        self._gap_repair_armed = False
        #: slot -> responder pid -> verified commit cert (pruned on decide)
        self.repair_votes: Dict[int, Dict[str, Any]] = {}
        self.gap_repairs = 0          # decisions recovered via repair

        # summaries (Alg. 4)
        self.summary_sigs: Dict[int, Dict[str, bytes]] = {}
        self._summary_digests: Dict[int, bytes] = {}  # k -> my stream digest

        # CTBcast instance per broadcaster (self included)
        self.ctb: Dict[str, CTBcast] = {}
        for p in participants:
            self.ctb[p] = CTBcast(
                self, self.tb, self.regs, broadcaster=p, group=replicas,
                t=self.cfg.t,
                deliver=(lambda k, m, p=p: self._ctb_deliver(p, k, m)),
                auto_slow_after_us=(0.0 if self.cfg.slow_mode == "always"
                                    else self.cfg.slow_after_us),
                on_summary_needed=(lambda seg, p=p: self._need_summary(seg))
                if p == pid else None,
                fast_enabled=self.cfg.ctb_fast_enabled,
            )
        self.my_ctb = self.ctb[pid]
        self.ctb_k = 0

        # TBcast streams for consensus messages — registered per kind so
        # the TB route memo lands directly on the specific handler (the
        # split-and-branch dispatch showed up in the hot-path profile).
        # NB: CERTIFY_CHECKPOINT before CERTIFY (prefix-matched).
        self.tb.register("cons/WILL_CERTIFY", self._on_will_certify)
        self.tb.register("cons/WILL_COMMIT", self._on_will_commit)
        self.tb.register("cons/CERTIFY_CHECKPOINT", self._on_tb_certify_cp)
        self.tb.register("cons/CERTIFY", self._on_tb_certify)
        self.tb.register("cons/SUMMARY", self._on_tb_summary)
        self.tb.register("cons/", self._on_tb_consensus)  # fallback

        # direct messages
        self.handle("REQ", self._on_client_request)
        self.handle("ECHO", self._on_echo)
        self.handle("CRTFY_VC", self._on_crtfy_vc)
        self.handle("CERTIFY_SUMMARY", self._on_certify_summary)
        self.handle("STATE_REQ", self._on_state_req)
        self.handle("STATE_RESP", self._on_state_resp)
        # membership epochs (replica replacement)
        self.handle("EPOCH", self._on_epoch)
        self.handle("JOIN_SYNC", self._on_join_sync)
        self.handle("XFER_REQ", self._on_xfer_req)
        self.handle("XFER_RESP", self._on_xfer_resp)
        # decision gap repair (self-healing deployments)
        self.handle("GAP_REPAIR_REQ", self._on_gap_repair_req)
        self.handle("GAP_REPAIR", self._on_gap_repair)

        # decided callback hooks (runtime integration)
        self.on_decide_hooks: List[Callable[[int, tuple], None]] = []
        # executed callback hooks (service integration): fired after the
        # app applied a request, with ``(slot, rid, payload, result)`` —
        # the sharded-service layer watches executed 2PC PREPAREs here to
        # arm its presumed-abort recovery timers
        self.on_execute_hooks: List[
            Callable[[int, tuple, bytes, bytes], None]] = []
        # fired when a joiner becomes a voting member (``joining`` flips
        # False) — the service layer re-arms recovery timers here for
        # pending 2PC intents adopted via the state-transfer snapshot,
        # which never pass through this replica's own execution stream
        self.on_activate_hooks: List[Callable[[], None]] = []
        # service-level endorsement validators, keyed by the svc request
        # kind (``("svc", kind, ...)`` rids): before this replica endorses
        # or signs a certificate over a slot containing such a request it
        # asks the registered validator whether the request is locally
        # justified (e.g. a 2PC FINISH matching a verified outcome).  A
        # blocked slot is re-checked periodically — a Byzantine leader
        # proposing an unjustifiable svc request never collects an honest
        # certificate quorum and eventually loses its view.  Kinds with no
        # registered validator are endorsed freely (legacy behaviour for
        # deployments without a service layer).
        self.svc_validators: Dict[str, Callable[[tuple, bytes], bool]] = {}
        self._svc_wait: Set[Tuple[int, int]] = set()

        # SLO-aware admission control (cfg.admission; the serving plane).
        # ``_client_backlog`` counts pending_req entries with a client
        # field — accepted-but-unexecuted client requests, i.e. the queue
        # depth against the execution horizon — maintained O(1) at the
        # _pend_put/_pend_pop choke points.
        self.shed_queue: Deque[tuple] = deque()   # rids queued to shed
        self._client_backlog = 0
        self.admission_stats: Dict[str, int] = {
            "shed": 0,           # rids this leader routed to the shed path
            "busy_replies": 0,   # BUSY replies executed here
            "dup_sheds": 0,      # shed markers that lost the race to apply
        }
        # Deferred execution engine (App.cost_us; the serving plane).
        # Checked once: apps that keep the zero-cost default execute
        # inline on the exact pre-existing path.
        self._app_has_cost = type(app).cost_us is not App.cost_us
        self._exec_inflight: Optional[int] = None
        self._exec_gen = 0
        if self._app_has_cost:
            # Node.timer swallows callbacks that fire while crashed, so a
            # crash mid-service would otherwise leave the engine wedged
            # on a completion that never arrives
            self.recover_hooks.append(self._exec_recover)

        # Per-stream high-water marks for slot-keyed TBcast votes, plus
        # the overflow-stream key counters (see _tb_slot_broadcast)
        self._tb_slot_hwm: Dict[str, int] = {}
        self._tb_overflow_k: Dict[str, int] = {}

        self._progress_timer_armed = False

    # ------------------------------------------------------------------
    # helpers
    # ------------------------------------------------------------------
    def leader(self, view: Optional[int] = None) -> str:
        if view is None:
            return self._leader_pid
        return self.replicas[view % self.n]

    def is_leader(self) -> bool:
        return self._leader_pid == self.pid

    def _ctb_broadcast(self, msg: tuple, slow: bool = False) -> None:
        k = self.ctb_k
        self.ctb_k += 1
        self.my_ctb.broadcast(k, msg, slow=slow)

    #: interned "cons/<kind>" stream names (an f-string per broadcast and a
    #: split per delivery showed up in the hot-path profile)
    _STREAMS: Dict[str, str] = {}

    def _tb_broadcast(self, stream: str, key: int, payload: Any) -> None:
        full = self._STREAMS.get(stream)
        if full is None:
            full = self._STREAMS[stream] = f"cons/{stream}"
        self.tb.broadcast(full, key, payload, self.replicas)

    def _tb_slot_broadcast(self, stream: str, s: int, payload: Any) -> None:
        """TBcast a per-slot vote, keyed by the slot — with a catch: TBcast
        receivers deliver strictly FIFO per (origin, stream), and the
        sender's window floor (``min_k``) skips them past any key it never
        buffered.  A vote for a slot *below* this stream's high-water mark
        (a re-certify in a later view, after an endorsement-gate refusal
        let higher slots overtake it) would therefore arrive below the
        receiver's FIFO pointer and be dropped as a duplicate forever —
        wedging the slot across every subsequent view.  Such votes ride a
        dedicated monotone-keyed overflow stream instead (``<stream>2``,
        prefix-routed to the same handler; the payload, not the key,
        carries the slot)."""
        hwm = self._tb_slot_hwm.get(stream, -1)
        if s > hwm:
            self._tb_slot_hwm[stream] = s
            self._tb_broadcast(stream, s, payload)
            return
        over = stream + "2"
        k = self._tb_overflow_k.get(over, 0)
        self._tb_overflow_k[over] = k + 1
        self._tb_broadcast(over, k, payload)

    def _pend_put(self, rid: tuple, req: tuple) -> None:
        """Insert into pending_req, keeping the client-backlog counter."""
        if rid not in self.pending_req and req[1] != "":
            self._client_backlog += 1
        self.pending_req[rid] = req

    def _pend_pop(self, rid: tuple) -> None:
        req = self.pending_req.pop(rid, None)
        if req is not None and req[1] != "":
            self._client_backlog -= 1

    # ==================================================================
    # RPC (client requests; §5.4 Echo round)
    # ==================================================================
    def _on_client_request(self, src: str, body: Any) -> None:
        rid, payload = body
        if not (isinstance(rid, tuple) and rid and rid[0] == src):
            # the rid's first element is the submitting pid, checked here
            # against the network-authenticated sender: a client cannot
            # submit requests under another client's identity, so the
            # ``client`` field of every decided request (and the caller
            # identity handed to ``App.apply_from``) is trustworthy
            return
        if len(payload) > self.cfg.max_request_bytes:
            # Oversized requests never enter the proposal path: an honest
            # leader proposing one would fail Algorithm 5's size check at
            # every follower and be blocked as Byzantine.  Reply with a
            # deterministic error so the client completes instead of
            # timing out (every replica sends the same reply).
            self.send(src, "REP", (rid, b"ERR_REQUEST_TOO_LARGE"))
            return
        req = (rid, src, payload)
        if rid in self.decided_rids:
            # retransmitted request — resend cached reply if executed
            for s, batch in self.decided.items():
                if s > self.exec_upto:
                    continue
                for i, r in enumerate(batch):
                    if r[0] == rid:
                        self.send(src, "REP", (rid, self.results[s][i]))
                        return
            return
        self._pend_put(rid, req)
        if len(self.pending_req) > 4 * self.cfg.window:  # Byzantine clients
            self._pend_pop(next(iter(self.pending_req)))
        # release any PREPARE that waited for the direct client copy; a
        # batched slot is endorsed once ALL its missing rids have arrived
        for (v, s) in self.waiting_prepare.pop(rid, []):
            miss = self.prepare_missing.get((v, s))
            if miss is None:
                self._endorse(v, s)
                continue
            miss.discard(rid)
            if not miss:
                del self.prepare_missing[(v, s)]
                self._endorse(v, s)
        if self.joining:
            return  # a non-voting joiner buffers but does not echo
        if self.is_leader():
            self._note_echo(rid, self.pid)
        else:
            self.send(self.leader(), "ECHO", (rid,))
            self._arm_progress_timer()

    def _on_echo(self, src: str, body: Any) -> None:
        (rid,) = body
        if self.is_leader():
            self._note_echo(rid, src)

    def _note_echo(self, rid: tuple, who: str) -> None:
        if who not in self._member_set:
            return  # only current-epoch members count toward echo quorums
        s = self.echoes.get(rid)
        if s is None:
            s = self.echoes[rid] = set()
        s.add(who)
        if rid in self.proposed_rids or rid in self.decided_rids:
            return
        need = self.n  # timely fast path wants everyone on board
        if len(s) >= need and rid in self.pending_req:
            self._enqueue_proposal(self.pending_req[rid])
        elif len(s) == 1:
            # echo timeout: propose with whoever echoed (slow path will cope)
            self.timer(self.cfg.echo_timeout_us,
                       lambda: self._echo_timeout(rid))

    def _echo_timeout(self, rid: tuple) -> None:
        if rid in self.proposed_rids or rid in self.decided_rids:
            return
        if rid in self.pending_req and len(self.echoes.get(rid, ())) >= 1:
            self._enqueue_proposal(self.pending_req[rid])

    def _enqueue_proposal(self, req: tuple) -> None:
        rid = req[0]
        if rid in self.proposed_rids:
            return
        adm = self.cfg.admission
        if (adm is not None and req[1] != "" and
                self._client_backlog > adm.queue_high):
            # over the queue-depth horizon: shed with an agreed BUSY
            # marker instead of feeding the overload into the pipeline
            self.proposed_rids.add(rid)
            self.shed_queue.append(rid)
            self.admission_stats["shed"] += 1
            self._drain_proposals()
            return
        self.proposed_rids.add(rid)
        self.propose_queue.append(req)
        self._drain_proposals()

    # ------------------------------------------------------------------
    # Service-level requests (no client, applied to the app, no reply)
    # ------------------------------------------------------------------
    def propose_internal(self, rid: tuple, payload: bytes) -> None:
        """Route an internally-originated request into the consensus hot
        path: ``rid`` must be a ``("svc", ...)`` tuple, deterministic
        across replicas, so concurrent submissions from every replica
        dedupe into one slot.  The decided request is applied to the app
        like a client request (unlike the no-op ⊥/MEMBERSHIP class) but
        sends no reply — the service layer built on top (cross-shard 2PC
        recovery) observes execution via ``on_execute_hooks``.

        Mirrors ``propose_membership``'s enqueue path: the request rides
        the normal echo/propose machinery, trips the same progress timer
        (a leader that refuses to propose it loses its view), and is
        re-routed across view changes like any pending request."""
        assert isinstance(rid, tuple) and rid and rid[0] == "svc", \
            "service-level rids are ('svc', ...) tuples"
        if self.joining:
            return  # a non-voting joiner neither echoes nor proposes
        if rid in self.decided_rids or rid in self.executed_rids:
            return
        if rid not in self.pending_req:
            self.pending_req[rid] = (rid, "", payload)
        # a slot held back by the svc endorsement gate may now be
        # justified by this very proposal (the validator matches it
        # against pending_req) — re-check immediately instead of waiting
        # for the periodic recheck timer
        for (v, s) in list(self._svc_wait):
            self._svc_recheck(v, s)
        if self.is_leader():
            self._note_echo(rid, self.pid)
        else:
            self.send(self.leader(), "ECHO", (rid,))
        self._arm_progress_timer()

    # ==================================================================
    # Propose (Alg. 2 lines 14-16) — batched + pipelined
    # ==================================================================
    def _slots_in_flight(self) -> int:
        """Slots proposed but not yet executed (the pipeline window)."""
        return max(0, self.next_slot - self.exec_upto - 1)

    def _assemble_batch(self) -> Optional[tuple]:
        """Coalesce pending requests into one batch, bounded by
        ``max_batch`` requests / ``max_batch_bytes`` of payload.  A single
        request may exceed the byte bound (up to max_request_bytes)."""
        batch: List[tuple] = []
        rids: Set[tuple] = set()
        size = 0
        while self.propose_queue and len(batch) < self.cfg.max_batch:
            req = self.propose_queue[0]
            if req[0] in self.decided_rids or req[0] in rids:
                # stale or duplicate enqueue (possible across view changes)
                self.propose_queue.popleft()
                continue
            if batch and size + len(req[2]) > self.cfg.max_batch_bytes:
                break
            self.propose_queue.popleft()
            batch.append(req)
            rids.add(req[0])
            size += len(req[2])
        adm = self.cfg.admission
        if adm is not None and self.shed_queue:
            # shed markers ride along (or form a shed-only slot): agreed,
            # zero-payload, and capped so they never starve real requests
            n_shed = 0
            while self.shed_queue and n_shed < adm.max_shed:
                orig = self.shed_queue.popleft()
                if orig in self.decided_rids or orig in rids:
                    continue  # settled (or racing a real proposal) already
                batch.append((("shed", orig), "", b""))
                rids.add(orig)
                n_shed += 1
        return tuple(batch) if batch else None

    def _full_batch_queued(self) -> bool:
        """O(max_batch) check: is a full batch's worth of requests queued?
        Queue length may overcount by stale (already decided) rids —
        harmless: we propose a slightly smaller batch instead of waiting."""
        if len(self.propose_queue) >= self.cfg.max_batch:
            return True
        size = 0
        for r in self.propose_queue:
            size += len(r[2])
            if size >= self.cfg.max_batch_bytes:
                return True
        return False

    def _drain_proposals(self) -> None:
        if not self.is_leader():
            return
        if self.view > 0 and self.view not in self.new_view_sent:
            return  # NEW_VIEW must precede proposals in this view
        if (self.gap_repair_us is not None and self.view > 0 and
                self.view not in self.reproposed_views):
            # NEW_VIEW is broadcast but not yet FIFO-self-delivered:
            # next_slot is blind until _repropose runs, and proposing a
            # fresh batch now can land on an already-decided slot — a
            # duplicate PREPARE that byz-blocks my own stream everywhere
            return
        while ((self.propose_queue or self.shed_queue) and
               self.next_slot in self.checkpoint.open_slots and
               self._slots_in_flight() < self.cfg.pipeline_depth):
            # drop already-decided heads (stale after view changes)
            while (self.propose_queue and
                   self.propose_queue[0][0] in self.decided_rids):
                self.propose_queue.popleft()
            if not self.propose_queue and not self.shed_queue:
                return
            if (self.propose_queue and
                    self.cfg.batch_timeout_us > 0 and
                    not self._batch_flush_due and
                    not self._full_batch_queued()):
                # wait (bounded) for more requests to coalesce
                if not self._batch_timer_armed:
                    self._batch_timer_armed = True
                    self.timer(self.cfg.batch_timeout_us, self._batch_flush)
                return
            batch = self._assemble_batch()
            if batch is None:
                return
            s = self.next_slot
            self.next_slot += 1
            self._ctb_broadcast(("PREPARE", self.view, s, batch))

    def _batch_flush(self) -> None:
        self._batch_timer_armed = False
        self._batch_flush_due = True
        try:
            self._drain_proposals()
        finally:
            self._batch_flush_due = False

    # ==================================================================
    # CTBcast delivery → FIFO interpretation (Alg. 2 line 1)
    # ==================================================================
    def _ctb_deliver(self, p: str, k: int, m: Any) -> None:
        st = self.state.get(p)
        if st is None or st.blocked or p in self.retired:
            return
        if k < st.fifo_next:
            return
        st.fifo_pending[k] = m
        self._fifo_drain(p)

    def _fifo_drain(self, p: str) -> None:
        st = self.state[p]
        while not st.blocked and st.fifo_next in st.fifo_pending:
            k = st.fifo_next
            m = st.fifo_pending.pop(k)
            st.fifo_next += 1
            st.recent[k] = m
            # ks enter in strictly increasing order, so the dict's first
            # key is the oldest — O(1) expiry instead of an O(t) scan
            while st.recent:
                first = next(iter(st.recent))
                if first > k - self.cfg.t:
                    break
                del st.recent[first]
            if not self._byz_check(p, m):       # Algorithm 5
                if self.gap_repair_us is not None and not st.view_synced:
                    # The peer's view lineage is unknown here (post-join,
                    # or the peer sealed into an epoch we haven't applied
                    # yet): a check against the stale st.view says nothing
                    # about honesty.  Drop instead of block — but still
                    # adopt COMMIT certificates, which carry f+1 certify
                    # signatures and are re-verified on every path.
                    if m[0] == "COMMIT":
                        self._on_commit(p, m)
                    continue
                st.blocked = True               # "block upon a Byzantine message"
                return
            self._process_ctb(p, k, m)
            if (k + 1) % self.my_ctb.summary_interval == 0:
                self._send_certify_summary(p, k)

    # ------------------------------------------------------------------
    # Algorithm 5 — CTBcast's Byzantine checks
    # ------------------------------------------------------------------
    def _byz_check(self, p: str, m: tuple) -> bool:
        st = self.state[p]
        kind = m[0]
        if kind == "PREPARE":
            _, v, s, req = m
            if self._valid_batch(req) is None:  # malformed / oversized batch
                return False
            cp = st.checkpoint or self.checkpoint
            prepared_in_v = s in st.prepares and st.prepares[s][0] == v
            return (st.view == v and self.leader(v) == p and
                    s in cp.open_slots and
                    not prepared_in_v and       # never prepared s before in v
                    (v == 0 or (st.new_view is not None and
                                self._must_propose_ok(s, req, st.new_view))))
        if kind == "COMMIT":
            cert = m[1]
            v, s = cert["view"], cert["slot"]
            cp = st.checkpoint or self.checkpoint
            return (s in cp.open_slots and v == st.view and
                    st.commits.get(s) is not cert)
        if kind == "CHECKPOINT":
            cp = Checkpoint.from_wire(m[1])
            old = st.checkpoint or self.checkpoint
            return cp.supersedes(old) and cp.valid(self.registry, self.quorum)
        if kind == "SEAL_VIEW":
            return st.view < m[1]
        if kind == "NEW_VIEW":
            certs = m[1]
            if self.leader(st.view) != p:
                return False
            if st.noncp_msgs_in_view > 0:
                return False   # must be p's first non-CHECKPOINT msg this view
            seen = set()
            for q, (snap, shares) in certs.items():
                if q in seen:
                    return False
                seen.add(q)
                digest = crypto.fingerprint_cached(snap)
                pids = {pid for pid, _ in shares}
                if len(pids) < self.quorum:
                    return False
                for pid, sig in shares:
                    if not self.registry.verify(
                            pid, ("vc", st.view, q, digest), sig):
                        return False
            return len(seen) >= self.quorum
        return True

    def _valid_batch(self, raw: Any) -> Optional[tuple]:
        """Structural check on a PREPARE payload: a well-formed batch of
        1..max_batch request triples within the byte bounds (a Byzantine
        leader may not smuggle oversized batches past the cost model)."""
        try:
            batch = as_batch(raw)
        except TypeError:
            return None
        adm = self.cfg.admission
        cap = self.cfg.max_batch + (adm.max_shed if adm is not None else 0)
        if not 1 <= len(batch) <= cap:
            return None
        total = 0
        rids = set()
        n_real = 0
        n_shed = 0
        for r in batch:
            if not (isinstance(r, tuple) and len(r) == 3 and
                    isinstance(r[1], str) and isinstance(r[2], bytes)):
                return None
            rid = r[0]
            if (isinstance(rid, tuple) and len(rid) == 2 and
                    rid[0] == "shed" and r[1] == ""):
                # an admission shed marker: only meaningful — and only
                # valid on the wire — when admission control is deployed;
                # the shed's *target* rid joins the duplicate check so a
                # slot can never both apply and shed the same request
                orig = rid[1]
                if (adm is None or r[2] != b"" or
                        not (isinstance(orig, tuple) and orig and
                             isinstance(orig[0], str))):
                    return None
                if orig in rids or rid in rids:
                    return None
                rids.add(orig)
                rids.add(rid)
                n_shed += 1
                continue
            n_real += 1
            if r[1] != "" and not (isinstance(rid, tuple) and rid and
                                   rid[0] == r[1]):
                # a client request's rid leads with the client pid (checked
                # against the network sender at REQ ingress); a batch whose
                # ``client`` field disagrees is a leader forging the caller
                # identity that ``App.apply_from`` will be handed
                return None
            try:
                if rid in rids:   # duplicate rids: one reply per rid
                    return None
                rids.add(rid)  # rids key sets/dicts everywhere downstream
            except TypeError:
                return None
            if len(r[2]) > self.cfg.max_request_bytes:
                return None
            total += len(r[2])
        if n_real > self.cfg.max_batch:
            return None
        if n_shed and (adm is None or n_shed > adm.max_shed):
            return None
        if n_real > 1 and total > self.cfg.max_batch_bytes:
            return None
        return batch

    @staticmethod
    def _needs_execution(r: tuple) -> bool:
        """A request whose execution has effects worth re-proposing across
        a view change: any client request, plus the service-level
        ``("svc", ...)`` class (⊥ fillers and MEMBERSHIP markers are not —
        MEMBERSHIP is re-announced by the control plane's survivors)."""
        return r[1] != "" or (isinstance(r[0], tuple) and bool(r[0]) and
                              r[0][0] == "svc")

    def _must_propose_ok(self, slot: int, req: Any, new_view: Any) -> bool:
        must = self._must_propose(slot, new_view)
        if must is None:        # any request may be proposed
            return True
        return (crypto.encode_cached(as_batch(req)) ==
                crypto.encode_cached(as_batch(must)))

    # ------------------------------------------------------------------
    # FIFO message processing (Alg. 2 / Alg. 3 receive sides)
    # ------------------------------------------------------------------
    def _process_ctb(self, p: str, k: int, m: tuple) -> None:
        kind = m[0]
        st = self.state[p]
        if kind == "PREPARE":
            st.noncp_msgs_in_view += 1
            self._on_prepare(p, m)
        elif kind == "COMMIT":
            st.noncp_msgs_in_view += 1
            self._on_commit(p, m)
        elif kind == "CHECKPOINT":
            self._on_checkpoint_msg(p, m)
        elif kind == "SEAL_VIEW":
            self._on_seal_view(p, m)   # resets the per-view counters
        elif kind == "NEW_VIEW":
            st.noncp_msgs_in_view += 1
            self._on_new_view(p, m)

    # --- PREPARE (lines 18-22) ---
    def _on_prepare(self, p: str, m: tuple) -> None:
        _, v, s, raw = m
        batch = as_batch(raw)
        self.state[p].prepares[s] = (v, batch)
        if v != self.view or s not in self.checkpoint.open_slots:
            return
        for r in batch:
            if (r[1] != "" and r[0] in self.pending_req and
                    self.pending_req[r[0]] != r):
                # the leader's copy contradicts the client's direct copy
                # (§5.4): never adopt or endorse a rewritten request
                return
        if not self._batch_certifiable(raw):
            # an unjustifiable service request is not even *stored*: were it
            # kept in my_prepared, an honest replica leading the next view
            # would faithfully re-propose it (_repropose) and a Byzantine
            # leader's forgery could wedge the slot across view changes.
            # Certification stays gated separately (_endorse/_do_certify)
            # for requests whose justification arrives later.
            self._arm_svc_recheck(v, s)
            return
        self.my_prepared[s] = (v, batch)
        if s > self.exec_upto + 1:
            self._arm_gap_repair()   # leader moved past a stalled slot
        missing = {r[0] for r in batch
                   if r[1] != "" and r[0] not in self.pending_req and
                   r[0] not in self.decided_rids}
        if p == self.pid or not missing:
            self._endorse(v, s)
        else:
            # wait for the clients' direct copies before endorsing (§5.4);
            # a batched slot endorses once every missing rid has arrived
            self.prepare_missing[(v, s)] = missing
            for rid in missing:
                self.waiting_prepare.setdefault(rid, []).append((v, s))
            self._arm_progress_timer()
        if self.cfg.slow_mode == "always":
            self._do_certify(v, s)
        else:
            self.timer(self.cfg.slow_after_us,
                       lambda: self._slow_path_kick(v, s))

    # ------------------------------------------------------------------
    # Service-slot endorsement gating
    # ------------------------------------------------------------------
    def _svc_certifiable(self, raw: Any) -> bool:
        """May this replica vouch (WILL_CERTIFY / CERTIFY signature) for a
        slot containing this batch?  Client requests always qualify —
        their authenticity is carried by the rid/client binding.  A
        ``("svc", kind, ...)`` request is checked against the service
        layer's registered validator: only locally-justified service
        actions get this replica's vote."""
        if not self.svc_validators:
            return True
        for r in as_batch(raw):
            rid = r[0]
            if (r[1] == "" and isinstance(rid, tuple) and len(rid) >= 2 and
                    rid[0] == "svc" and rid not in self.decided_rids and
                    rid not in self.executed_rids):
                val = self.svc_validators.get(rid[1])
                if val is not None and not val(rid, r[2]):
                    return False
        return True

    def _admission_ok(self, raw: Any) -> bool:
        """May this replica vouch for a slot carrying shed markers?  A
        shed is justified only while this replica's *own* client backlog
        confirms the overload (the ``queue_accept`` floor) — a Byzantine
        leader shedding honest requests under light load never collects
        an honest certificate quorum and loses its view to the normal
        progress timer.  Deployments without admission control never see
        shed markers past ``_valid_batch``, so this is a no-op there."""
        adm = self.cfg.admission
        if adm is None:
            return True
        floor = adm.accept_floor()
        for r in as_batch(raw):
            rid = r[0]
            if (r[1] == "" and isinstance(rid, tuple) and len(rid) == 2 and
                    rid[0] == "shed"):
                orig = rid[1]
                if orig in self.decided_rids or orig in self.executed_rids:
                    continue   # settled elsewhere: the shed is a no-op
                if orig not in self.pending_req:
                    # an honest client broadcasts to every replica, so a
                    # rid we never saw has no honest client waiting on it
                    # — shedding it cannot censor anyone we answer to
                    continue
                if self._client_backlog < floor:
                    return False
        return True

    def _batch_certifiable(self, raw: Any) -> bool:
        """All local-justification gates a batch must pass before this
        replica promises or signs for it (svc validators + admission)."""
        return self._admission_ok(raw) and self._svc_certifiable(raw)

    def _arm_svc_recheck(self, v: int, s: int) -> None:
        if (v, s) in self._svc_wait:
            return
        self._svc_wait.add((v, s))
        self.timer(self.cfg.echo_timeout_us,
                   lambda: self._svc_recheck(v, s))
        # a held-back slot stalls execution even when every rid is decided:
        # keep view-change pressure on so a leader proposing unjustifiable
        # svc requests loses its view instead of wedging the log
        self._arm_progress_timer()

    def _svc_recheck(self, v: int, s: int) -> None:
        """A slot was held back because a svc request in it was not yet
        locally justified; re-test (the local recovery probe may have
        verified the outcome and proposed the identical rid, or the
        transaction may have resolved meanwhile) and vote if now safe."""
        self._svc_wait.discard((v, s))
        if (v != self.view or s in self.decided or
                s not in self.checkpoint.open_slots):
            return
        pr = self.my_prepared.get(s)
        if pr is None or pr[0] != v:
            # the prepare was refused storage outright: keep the pressure
            # on (view-change timer) until the slot decides elsewhere or
            # the view moves on
            self._arm_svc_recheck(v, s)
            return
        if not self._batch_certifiable(pr[1]):
            self._arm_svc_recheck(v, s)
            return
        if (v, s) not in self.my_will_certifies:
            self._endorse(v, s)
        self._do_certify(v, s)

    def _endorse(self, v: int, s: int) -> None:
        if self.joining:
            return  # non-voting: observe, never promise
        if v != self.view or s not in self.checkpoint.open_slots:
            return
        pr = self.my_prepared.get(s)
        if pr is not None and pr[0] == v and not self._batch_certifiable(pr[1]):
            self._arm_svc_recheck(v, s)
            return
        if self.cfg.fast_enabled:
            self.my_will_certifies.add((v, s))
            self._tb_slot_broadcast("WILL_CERTIFY", s, (v, s))  # line 21
        else:
            self._do_certify(v, s)

    def _slow_path_kick(self, v: int, s: int) -> None:
        if s in self.decided or v != self.view:
            return
        self._do_certify(v, s)

    # --- CERTIFY (lines 22, 34-36) ---
    def _do_certify(self, v: int, s: int) -> None:
        if self.joining:
            return  # non-voting: a joiner's signature must never complete
        if (v, s) in self.my_certified:  # a certificate quorum
            return
        pr = self.my_prepared.get(s)
        if pr is None or pr[0] != v:
            return
        if not self._batch_certifiable(pr[1]):
            # the slow path reaches here without passing _endorse, so the
            # service-slot gate must sit on the signature itself: no
            # honest certificate for an unjustified svc request (or an
            # unjustified admission shed)
            self._arm_svc_recheck(v, s)
            return
        self.my_certified.add((v, s))
        req = pr[1]
        fp = crypto.fingerprint_cached(req)
        payload = ("certify", v, s, fp)
        self.async_sign(payload, lambda sig: self._tb_slot_broadcast(
            "CERTIFY", s, (v, s, fp, sig)))

    def _on_certify(self, q: str, body: tuple) -> None:
        v, s, fp, sig = body
        # accept certificates for any view ≤ ours (they may be completing a
        # promise from the view we are sealing); the signature binds (v,s,fp)
        if v > self.view or s not in self.checkpoint.open_slots:
            return
        self.async_verify(q, ("certify", v, s, fp), sig,
                          lambda ok: self._certify_verified(ok, q, v, s, fp, sig))

    def _certify_verified(self, ok: bool, q: str, v: int, s: int,
                          fp: bytes, sig: bytes) -> None:
        if not ok:
            return
        sigs = self.certify_sigs.setdefault((v, s, fp), {})
        sigs[q] = sig
        if len(sigs) >= self.quorum and s not in self.my_commits:
            pr = self.my_prepared.get(s)
            if pr is None or pr[0] != v:
                return
            if crypto.fingerprint_cached(pr[1]) != fp:
                return
            if v != self.view:
                return   # never broadcast a COMMIT for a view I have sealed
            cert = {"view": v, "slot": s, "fp": fp, "req": pr[1],
                    "sigs": tuple(sorted(sigs.items()))}
            self.my_commits[s] = cert
            self._ctb_broadcast(("COMMIT", cert))              # line 36

    # --- COMMIT (lines 38-41) ---
    def _on_commit(self, p: str, m: tuple, vouch_only: bool = False) -> None:
        cert = m[1]
        v, s, fp, req = cert["view"], cert["slot"], cert["fp"], cert["req"]
        if crypto.fingerprint_cached(req) != fp:
            return
        items = [(pid, ("certify", v, s, fp), sig) for pid, sig in cert["sigs"]]
        if len({pid for pid, _, _ in items}) < self.quorum:
            return
        self.async_verify_many(items, lambda oks: self._commit_verified(
            oks, p, cert, vouch_only))

    def _commit_verified(self, oks: List[bool], p: str, cert: dict,
                         vouch_only: bool = False) -> None:
        if not all(oks):
            return
        s = cert["slot"]
        if vouch_only:
            # a JOIN_SYNC-attached certificate: the sender vouches it
            # decided s, but the cert was never carried on its CTBcast
            # stream — recording it in st.commits would make my snapshot
            # of that stream diverge from every other replica's (and from
            # the sender's own), wedging view-change certificates forever
            self.vouched_commits.setdefault(s, {})[p] = cert
        else:
            st = self.state[p]
            prev = st.commits.get(s)
            if prev is None or prev["view"] <= cert["view"]:
                st.commits[s] = cert
        # f+1 members vouching (a COMMIT on their stream, or an attached
        # cert) with a matching PREPARE → decide (line 40)
        matching = set()
        for q in self.replicas:
            c = self.state[q].commits.get(s)
            if c is None:
                c = self.vouched_commits.get(s, {}).get(q)
            if (c is not None and c["fp"] == cert["fp"] and
                    c["view"] == cert["view"]):
                matching.add(q)
        if len(matching) >= self.quorum:
            self._decide(s, cert["req"])

    # --- fast path (lines 24-31) ---
    def _on_will_certify(self, origin: str, stream: str, key: int,
                         payload: Any) -> None:
        if origin not in self._member_set:
            return  # promises from outside the current epoch never count
        v, s = payload
        ws = self.will_certify.get((v, s))
        if ws is None:
            ws = self.will_certify[(v, s)] = set()
        ws.add(origin)
        if (len(ws) >= 2 * self.f + 1 and v == self.view and
                not self.joining and
                s in self.checkpoint.open_slots and
                (v, s) not in self.my_will_commits):
            self.my_will_commits.add((v, s))
            self._tb_slot_broadcast("WILL_COMMIT", s, (v, s))   # line 27

    def _on_will_commit(self, origin: str, stream: str, key: int,
                        payload: Any) -> None:
        if origin not in self._member_set:
            return  # promises from outside the current epoch never count
        v, s = payload
        ws = self.will_commit.get((v, s))
        if ws is None:
            ws = self.will_commit[(v, s)] = set()
        ws.add(origin)
        if (len(ws) >= 2 * self.f + 1 and v == self.view and
                s in self.checkpoint.open_slots):
            pr = self.state[self.leader(v)].prepares.get(s)
            if pr is not None and pr[0] == v:
                self._decide(s, pr[1])                         # line 31

    def _on_tb_certify(self, origin: str, stream: str, key: int,
                       payload: Any) -> None:
        if origin not in self._member_set:
            return  # a non-member (joiner / retired pid) casts no votes
        self._on_certify(origin, payload)

    def _on_tb_certify_cp(self, origin: str, stream: str, key: int,
                          payload: Any) -> None:
        if origin not in self._member_set:
            return  # a non-member (joiner / retired pid) casts no votes
        self._on_certify_checkpoint(origin, payload)

    def _on_tb_summary(self, origin: str, stream: str, key: int,
                       payload: Any) -> None:
        self._on_summary(origin, payload)

    def _on_tb_consensus(self, origin: str, stream: str, key: int,
                         payload: Any) -> None:
        """Fallback for unknown cons/ streams (Byzantine noise tolerance)."""
        return

    # ==================================================================
    # Decide → execute → reply
    # ==================================================================
    def _decide(self, s: int, reqs: tuple) -> None:
        if s in self.decided:
            return
        batch = as_batch(reqs)
        self.decided[s] = batch
        self.repair_votes.pop(s, None)
        for r in batch:
            self.decided_rids.add(r[0])
            # a decided rid no longer gates any endorsement: clear its
            # waits so _has_pending() cannot trigger spurious view changes
            # while the client's direct copy is still in flight
            for key in self.waiting_prepare.pop(r[0], []):
                miss = self.prepare_missing.get(key)
                if miss is not None:
                    miss.discard(r[0])
                    if not miss:
                        del self.prepare_missing[key]
        self.progress_deadline = None
        self.view_patience = self.cfg.view_timeout_us  # progress resets patience
        for hook in self.on_decide_hooks:
            hook(s, batch)
        self._execute_ready()
        self._arm_gap_repair()

    def _execute_ready(self) -> None:
        if self._app_has_cost:
            # deferred engine: slots occupy the serial app engine for
            # their summed App.cost_us before applying
            self._exec_pump()
            return
        while self.exec_upto + 1 in self.decided:
            self._execute_slot(self.exec_upto + 1)
        self._maybe_checkpoint_round()
        self._drain_proposals()

    def _execute_slot(self, s: int) -> None:
        results = []
        # the batch executes atomically (one slot), replies per-request
        for rid, client, payload in self.decided[s]:
            if (client == "" and isinstance(rid, tuple) and
                    len(rid) == 4 and rid[0] == "member"):
                # agreed MEMBERSHIP slot: every honest replica applies
                # the epoch bump at the same point of its execution
                # order — the switch is atomic across the group
                self._apply_membership(rid[1], rid[2], rid[3], s)
            if (client == "" and isinstance(rid, tuple) and
                    len(rid) == 2 and rid[0] == "shed"):
                # agreed admission shed: every honest replica sends the
                # identical deterministic BUSY for the target rid, so the
                # client completes on the normal f+1 reply quorum.  The
                # target joins executed_rids — a later slot carrying the
                # real request degrades to a duplicate, so a shed can
                # never be torn against applied state (and vice versa: a
                # shed for an already-applied rid degrades to a no-op)
                adm = self.cfg.admission
                orig = rid[1]
                self.decided_rids.add(orig)
                if adm is None or orig in self.executed_rids:
                    self.admission_stats["dup_sheds"] += 1
                    results.append(b"")
                else:
                    self.executed_rids.add(orig)
                    results.append(adm.reply)
                    self.admission_stats["busy_replies"] += 1
                    if orig[0] in self.sim.processes:
                        self.send(orig[0], "REP", (orig, adm.reply))
                self._pend_pop(orig)
                self.echoes.pop(orig, None)
                continue
            if (client == "" and isinstance(rid, tuple) and rid and
                    rid[0] == "svc" and rid not in self.executed_rids):
                # service-level request (cross-shard 2PC recovery):
                # applied to the app like a client request, but with no
                # reply — there is no client waiting, the effect IS the
                # point (e.g. a presumed-abort FINISH releasing locks)
                result = self.app.apply_from("", payload)
                self.executed_rids.add(rid)
                results.append(result)
                self._pend_pop(rid)
                self.echoes.pop(rid, None)
                for hook in self.on_execute_hooks:
                    hook(s, rid, payload, result)
                continue
            if client == "" or rid in self.executed_rids:
                # no-op / duplicate: does not touch the app and sends
                # no reply (a duplicate's real reply came from the slot
                # that executed it; a second b"" REP could otherwise
                # outvote it at the client)
                results.append(b"")
                self._pend_pop(rid)
                self.echoes.pop(rid, None)
                continue
            result = self.app.apply_from(client, payload)
            self.executed_rids.add(rid)
            results.append(result)
            self._pend_pop(rid)
            self.echoes.pop(rid, None)
            if client in self.sim.processes:
                self.send(client, "REP", (rid, result))
            for hook in self.on_execute_hooks:
                hook(s, rid, payload, result)
        self.results[s] = tuple(results)
        self.exec_upto = s

    # ------------------------------------------------------------------
    # Deferred execution engine (App.cost_us > 0; the serving plane)
    # ------------------------------------------------------------------
    def _slot_cost_us(self, s: int) -> float:
        """Summed service cost of the entries that will actually execute
        in slot ``s`` — computed at the slot's execution point, where
        every honest replica holds the identical app state."""
        cost = 0.0
        for rid, client, payload in self.decided[s]:
            if rid in self.executed_rids:
                continue   # duplicate: executes as a free no-op
            if client != "" or (isinstance(rid, tuple) and rid and
                                rid[0] == "svc"):
                cost += self.app.cost_us(payload)
        return cost

    def _exec_pump(self) -> None:
        """Serial engine: the next ready slot applies only after its
        summed per-request cost has elapsed on this replica's (single)
        app engine.  ``exec_upto`` lags the decided frontier by the true
        service backlog, so the pipeline cap and the leader's admission
        backlog both measure the real execution horizon."""
        if self._exec_inflight is not None or self.crashed:
            return
        while self.exec_upto + 1 in self.decided:
            s = self.exec_upto + 1
            cost = self._slot_cost_us(s)
            if cost > 0.0:
                self._exec_inflight = s
                gen = self._exec_gen
                self.timer(cost, lambda: self._exec_fire(gen))
                break
            self._execute_slot(s)   # free slots apply immediately
        self._maybe_checkpoint_round()
        self._drain_proposals()

    def _exec_fire(self, gen: int) -> None:
        if gen != self._exec_gen:
            return   # stale completion from before a crash/recover cycle
        s = self._exec_inflight
        self._exec_inflight = None
        if s is not None and s == self.exec_upto + 1 and s in self.decided:
            self._execute_slot(s)
        self._exec_pump()

    def _exec_recover(self) -> None:
        # a crash swallowed the in-flight service completion timer
        # (Node.timer drops callbacks that fire while crashed): the slot
        # re-enters service from scratch after recovery
        self._exec_gen += 1
        self._exec_inflight = None
        self._exec_pump()

    # ==================================================================
    # Decision gap repair (self-healing deployments; cfg.gap_repair_us)
    # ==================================================================
    def _arm_gap_repair(self) -> None:
        """Arm (once) a timer that pulls missing decisions from members.

        Fires only while execution is stalled behind undecided slots that
        some *later* decided/prepared slot proves the group moved past.
        Each firing requests ALL such holes at once — a joiner that came
        up short of vouchers for a window of slots heals in one round
        trip, not one slot per timer period.  The per-response trust
        model is the JOIN_SYNC vouched-certificate one: a responder
        attests "I decided s" with a re-verified f+1-signed commit
        certificate, and f+1 current members agreeing on the value decide
        it here (≥1 of them is honest, and honest decisions for a slot
        are unique)."""
        if (self.gap_repair_us is None or self._gap_repair_armed or
                self.crashed or self.joining):
            return
        if not self._gap_slots():
            return
        self._gap_repair_armed = True

        def _fire() -> None:
            self._gap_repair_armed = False
            if self.crashed or self.joining:
                return
            gaps = self._gap_slots()
            if not gaps:
                return
            for q in self.replicas:
                if q != self.pid:
                    self.send(q, "GAP_REPAIR_REQ", (tuple(gaps),))
            self._arm_gap_repair()       # retry cadence while stalled

        self.timer(self.gap_repair_us, _fire)

    def _gap_slots(self) -> List[int]:
        """Undecided slots below the highest slot this replica has seen
        decided or prepared.  A bare stall with nothing beyond is normal
        pipeline state — the progress timer, not repair, owns that case."""
        known = max(max(self.decided, default=-1),
                    max(self.my_prepared, default=-1))
        lo = max(self.exec_upto + 1, self.checkpoint.start)
        return [s for s in range(lo, known)
                if s not in self.decided][:self.cfg.window]

    def _on_gap_repair_req(self, src: str, body: tuple) -> None:
        if self.gap_repair_us is None:
            return
        slots = body[0]
        if not isinstance(slots, tuple):
            return
        certs = []
        for s in slots[:self.cfg.window]:
            if not isinstance(s, int) or s not in self.decided:
                continue
            cert = self.my_commits.get(s)
            if cert is None:
                # scan ALL tracked streams (retired peers may be the only
                # holders of certs for slots decided around a rotation)
                for ps in self.state.values():
                    cert = ps.commits.get(s)
                    if cert is not None:
                        break
            if cert is None:
                for c in self.vouched_commits.get(s, {}).values():
                    cert = c
                    break
            if cert is not None:
                certs.append(cert)
        if certs:
            self.send(src, "GAP_REPAIR", (tuple(certs),),
                      extra_bytes=64 * len(certs))

    def _on_gap_repair(self, src: str, body: tuple) -> None:
        if self.gap_repair_us is None or src not in self._member_set:
            return
        certs = body[0]
        if not isinstance(certs, tuple):
            return
        items: List[tuple] = []
        parsed: List[dict] = []
        for cert in certs[:self.cfg.window]:
            try:
                v, s, fp, req = (cert["view"], cert["slot"], cert["fp"],
                                 cert["req"])
            except (TypeError, KeyError):
                return
            if s in self.decided:
                continue
            if crypto.fingerprint_cached(req) != fp:
                return
            sub = [(pid, ("certify", v, s, fp), sig)
                   for pid, sig in cert["sigs"]]
            if len({pid for pid, _, _ in sub}) < self.quorum:
                return
            parsed.append(cert)
            items.extend(sub)
        if parsed:
            self.async_verify_many(
                items, lambda oks: self._gap_repair_verified(oks, src,
                                                             parsed))

    def _gap_repair_verified(self, oks: List[bool], src: str,
                             parsed: List[dict]) -> None:
        if not all(oks):
            return
        for cert in parsed:
            s = cert["slot"]
            if s in self.decided:
                continue
            votes = self.repair_votes.setdefault(s, {})
            votes[src] = cert
            # f+1 current members attesting the same value (view-agnostic:
            # across a view change honest members may hold certificates
            # from different views for the one decided value)
            matching = {q for q, c in votes.items()
                        if c["fp"] == cert["fp"] and q in self._member_set}
            if len(matching) >= self.quorum:
                del self.repair_votes[s]
                self.gap_repairs += 1
                self._decide(s, cert["req"])

    # ==================================================================
    # Checkpoints (Alg. 2 lines 43-61)
    # ==================================================================
    def _maybe_checkpoint_round(self) -> None:
        last = self.checkpoint.open_slots[-1]
        if self.exec_upto >= last:
            # the boundary snapshot is the only one a signed checkpoint can
            # vouch for — retained (bounded) for joiner state transfer
            self._boundary_snaps[last + 1] = self.app.snapshot()
            for old in [k for k in self._boundary_snaps
                        if k < last + 1 - self.cfg.window]:
                del self._boundary_snaps[old]
            if self.joining:
                return  # non-voting: no checkpoint certificate shares
            payload = _cp_payload(last + 1, self.cfg.window, self.app.snapshot_fp())
            self.async_sign(payload, lambda sig: self._tb_broadcast(
                "CERTIFY_CHECKPOINT", last + 1, (payload, sig)))

    def _on_certify_checkpoint(self, q: str, body: tuple) -> None:
        payload, sig = body
        self.async_verify(q, payload, sig,
                          lambda ok: self._cp_sig_verified(ok, q, payload, sig))

    def _cp_sig_verified(self, ok: bool, q: str, payload: tuple,
                         sig: bytes) -> None:
        if not ok:
            return
        sigs = self.cp_sigs.setdefault(payload, {})
        sigs[q] = sig
        if len(sigs) >= self.quorum:
            _tag, start, window, app_fp = payload
            cp = Checkpoint(start, window, app_fp, tuple(sorted(sigs.items())))
            self._maybe_checkpoint(cp)

    def _on_checkpoint_msg(self, p: str, m: tuple) -> None:
        cp = Checkpoint.from_wire(m[1])
        st = self.state[p]
        st.checkpoint = cp
        # forget this peer's prepares/commits outside the window (line 54)
        for s in [s for s in st.prepares if s not in cp.open_slots]:
            del st.prepares[s]
        for s in [s for s in st.commits if s not in cp.open_slots]:
            del st.commits[s]
        self._maybe_checkpoint(cp)

    def _maybe_checkpoint(self, cp: Checkpoint) -> bool:
        if not cp.supersedes(self.checkpoint):
            return False
        if not cp.valid(self.registry, self.quorum):
            return False
        self.checkpoint = cp
        # Re-broadcast the checkpoint on MY OWN CTBcast stream *before* any
        # proposal into the new window: peers validate my PREPAREs against
        # state[me].checkpoint (Alg. 5), which only advances when they
        # FIFO-process my CHECKPOINT.  This is also the liveness relay of
        # §B.3 ("re-broadcast by the potentially single correct process").
        if cp.start > self._last_cp_broadcast:
            self._last_cp_broadcast = cp.start
            self._ctb_broadcast(("CHECKPOINT", cp.to_wire()))
        # drop fast-path promises outside the window (§5.4)
        for d in (self.will_certify, self.will_commit):
            for key in [k for k in d if k[1] not in cp.open_slots]:
                del d[key]
        self.my_will_commits = {k for k in self.my_will_commits
                                if k[1] in cp.open_slots}
        self.my_will_certifies = {k for k in self.my_will_certifies
                                  if k[1] in cp.open_slots}
        self.my_certified = {k for k in self.my_certified
                             if k[1] in cp.open_slots}
        # decided/results are the execution queue, not just agreement
        # bookkeeping: with a costed app (deferred execution engine) the
        # decode backlog can lag a checkpoint boundary, and pruning a
        # decided-but-unexecuted slot would strand this replica on the
        # state-transfer path mid-service.  Keep everything the engine
        # still has to walk; prune only what is both settled and executed.
        exec_floor = min(cp.start, self.exec_upto + 1)
        for d2 in (self.my_prepared, self.my_commits, self.vouched_commits):
            for s in [s for s in d2 if s < cp.start]:
                del d2[s]
        for d2 in (self.decided, self.results):
            for s in [s for s in d2 if s < exec_floor]:
                del d2[s]
        for key in [k for k in self.certify_sigs if k[1] < cp.start]:
            del self.certify_sigs[key]
        for key in [k for k in self.cp_sigs if k[1] < cp.start]:
            del self.cp_sigs[key]
        for key in [k for k in self.prepare_missing if k[1] < cp.start]:
            del self.prepare_missing[key]
        for rid in list(self.waiting_prepare):
            live = [(v, s) for (v, s) in self.waiting_prepare[rid]
                    if s >= cp.start]
            if live:
                self.waiting_prepare[rid] = live
            else:
                del self.waiting_prepare[rid]
        if self.exec_upto < cp.start - 1:
            if any(s not in self.decided
                   for s in range(self.exec_upto + 1, cp.start)):
                # behind with missing decisions: adopt via state transfer
                # (fp-verified)
                self._request_state(cp)
            else:
                # behind but holding every decision up to the boundary:
                # the (possibly deferred) execution engine walks there on
                # its own — adopting a snapshot would skip the costed
                # slots' service time and replies
                self._execute_ready()
        self.next_slot = max(self.next_slot, cp.start)
        self._drain_proposals()
        return True

    # --- state transfer (checkpoint adoption) ---
    def _request_state(self, cp: Checkpoint) -> None:
        # epoch-0 groups keep the historical STATE_REQ path bit-for-bit;
        # reconfigured groups use the boundary-snapshot path (XFER_REQ),
        # which verifies against the signed checkpoint unconditionally
        kind = "STATE_REQ" if self.membership.epoch == 0 else "XFER_REQ"
        for q in self.replicas:
            if q != self.pid:
                self.send(q, kind, (cp.start,))

    def _on_state_req(self, src: str, body: tuple) -> None:
        (start,) = body
        if self.checkpoint.start >= start and self.exec_upto >= start - 1:
            snap = self.app.snapshot()
            self.send(src, "STATE_RESP",
                      (start, snap, self.exec_upto),
                      extra_bytes=256)

    def _on_state_resp(self, src: str, body: tuple) -> None:
        start, snap, upto = body
        if self.exec_upto >= start - 1:
            return
        fp = crypto.fingerprint_cached(snap)
        if fp != self.checkpoint.app_fp:
            return  # unverifiable snapshot — ignore
        self.app.adopt(snap)
        self.exec_upto = max(self.exec_upto, self.checkpoint.start - 1)
        self._execute_ready()

    # --- boundary-snapshot state transfer (post-epoch-0 deployments) ---
    # STATE_RESP ships the responder's *current* snapshot, which only
    # verifies against the checkpoint fingerprint when the responder sits
    # exactly at the boundary.  Reconfigurable deployments instead serve
    # the retained boundary snapshot (``_boundary_snaps``), whose
    # fingerprint the f+1-signed checkpoint vouches for unconditionally —
    # a joiner that lags the window always converges.  Epoch-0 groups keep
    # the historical STATE_REQ wire path bit-for-bit.
    def _on_xfer_req(self, src: str, body: tuple) -> None:
        (start,) = body
        snap = self._boundary_snaps.get(start)
        if snap is None or self.checkpoint.start < start:
            return
        self.send(src, "XFER_RESP", (start, snap), extra_bytes=256)

    def _on_xfer_resp(self, src: str, body: tuple) -> None:
        start, snap = body
        if self.exec_upto >= start - 1 or start != self.checkpoint.start:
            return
        if crypto.fingerprint_cached(snap) != self.checkpoint.app_fp:
            return  # unverifiable snapshot — ignore
        self.app.adopt(snap)
        self._boundary_snaps[start] = snap
        self.exec_upto = max(self.exec_upto, start - 1)
        self._execute_ready()

    # ==================================================================
    # Membership epochs — live replica replacement
    # ==================================================================
    def publish_xfer(self, new_epoch: int) -> None:
        """Survivor side of joiner state transfer: WRITE my latest signed
        checkpoint + its boundary snapshot + prepared-slot state into my
        own SWMR register ``xfer/<epoch>`` — the transfer travels through
        the disaggregated-memory pools (the same machinery PR 2 built for
        memory-node replacement), never through a trusted side channel."""
        cp = self.checkpoint
        snap = self._boundary_snaps.get(cp.start)
        prepared = tuple(sorted(
            (s, v, batch) for s, (v, batch) in self.my_prepared.items()
            if s in cp.open_slots))
        payload = (cp.to_wire(),
                   snap if snap is not None else (),
                   self.exec_upto, self.view, prepared)
        self.regs.write(f"xfer/{new_epoch}", crypto.encode(payload),
                        lambda: None)

    def propose_membership(self, new_epoch: int, old_pid: str,
                           new_pid: str) -> None:
        """Arm the epoch bump: record the control plane's announcement and
        route a MEMBERSHIP request into the consensus hot path (it rides a
        normal slot, so the switch is *agreed*, not merely broadcast).  A
        Byzantine leader that refuses to propose it loses its view: the
        pending request trips the same progress timer as any client
        request, and the next honest leader proposes it."""
        if new_epoch <= self.membership.epoch or self.joining:
            return
        self.pending_membership[new_epoch] = (old_pid, new_pid)
        # interpretation state for the joiner's stream exists *before* its
        # first broadcast can arrive (its pre-switch messages are dropped
        # by the epoch checks, not lost at the wire layer)
        self._ensure_participant(new_pid)
        rid = ("member", new_epoch, old_pid, new_pid)
        if rid in self.decided_rids or rid in self.proposed_rids:
            return
        self.pending_req[rid] = (rid, "", b"")
        if self.is_leader():
            self._note_echo(rid, self.pid)
        else:
            self.send(self.leader(), "ECHO", (rid,))
        self._arm_progress_timer()

    def _switch_epoch(self, membership: MembershipEpoch, old: str,
                      new: str) -> None:
        """The one epoch-switch mutation sequence, shared by the member
        path (executing a MEMBERSHIP slot) and the joiner path
        (activation): install the new member set, retire everyone who
        left, create interpretation state for everyone who arrived, and
        re-derive every membership-dependent structure."""
        self.membership = membership
        self.replicas = list(membership.replicas)
        self._member_set = frozenset(self.replicas)
        for p in list(self.state):
            if p not in self._member_set and p != self.pid:
                self.state[p].blocked = True   # stop interpreting it
                self.retired.add(p)
                self.tb.drop_peer(p)   # free retired wire buffers (Table 2)
        # fresh interpretation state for arrivals (the joiner's broadcasts)
        for p in self.replicas:
            self._ensure_participant(p)
        # quorums (LOCKED unanimity, summary groups) follow the new epoch
        for c in self.ctb.values():
            c.set_group(self.replicas)
        self._leader_pid = self.replicas[self.view % self.n]
        self.epoch_switches.append((self.sim.now, membership.epoch, old,
                                    new))

    def _ensure_participant(self, p: str) -> None:
        """Interpretation state (PeerState + a receiver CTBcast instance)
        for a broadcaster that is not yet / no longer in the member list."""
        if p not in self.state:
            st = PeerState()
            st.checkpoint = self.checkpoint
            self.state[p] = st
        if p not in self.ctb:
            self.ctb[p] = CTBcast(
                self, self.tb, self.regs, broadcaster=p,
                group=self.replicas, t=self.cfg.t,
                deliver=(lambda k, m, p=p: self._ctb_deliver(p, k, m)),
                auto_slow_after_us=(0.0 if self.cfg.slow_mode == "always"
                                    else self.cfg.slow_after_us),
                fast_enabled=self.cfg.ctb_fast_enabled,
            )

    def _apply_membership(self, e: int, old: str, new: str,
                          slot: int) -> None:
        """Execute an agreed MEMBERSHIP slot: switch to the next epoch.

        Applied only when it matches the control plane's announcement
        (``pending_membership``) — a forged MEMBERSHIP request decided by a
        Byzantine leader is a no-op at every honest replica, identically.
        """
        if e != self.membership.epoch + 1:
            return  # stale or out-of-order bump
        if self.pending_membership.get(e) != (old, new):
            return  # unannounced (forged) membership change
        if old not in self._member_set or new in self._member_set:
            return
        self._switch_epoch(self.membership.replace(old, new), old, new)
        # the joiner could not see this slot (it was outside the old
        # group's broadcast set): f+1 members vouching for the switch
        # activate it
        if new != self.pid:
            # replay my own recent stream first, so the joiner's view of
            # *my* broadcasts (commits, seals) converges with everyone
            # else's — without this, view-change certificates about my
            # stream could never match the joiner's share (liveness); the
            # EPOCH confirmation follows so the replay lands while the
            # joiner is still in its observer-only phase
            history = tuple(sorted(self.my_ctb.buf.items()))
            # a member that itself joined recently decided open slots from
            # *replayed* certificates without ever broadcasting COMMIT for
            # them — its own stream cannot vouch for those decisions, and
            # a second-generation joiner counting f+1 vouching members
            # would come up short once the originals are gone.  Attach the
            # stored certificates explicitly: the receiver re-verifies the
            # f+1 certify signatures and counts this sender as one of the
            # vouching members.  Members whose stream already carries every
            # COMMIT (the common case) attach nothing, bit-identically.
            have = {m[1]["slot"] for _k, m in history
                    if isinstance(m, tuple) and m and m[0] == "COMMIT"}
            extra = []
            for s in sorted(self.decided):
                if s in have or s not in self.checkpoint.open_slots:
                    continue
                cert = self.my_commits.get(s)
                if cert is None and self.gap_repair_us is None:
                    for q in self.replicas:
                        cert = self.state[q].commits.get(s)
                        if cert is not None:
                            break
                elif cert is None:
                    # scan ALL tracked streams, not just current members:
                    # after a rotation the only holder of an old cert may
                    # be a retired peer's state
                    for ps in self.state.values():
                        cert = ps.commits.get(s)
                        if cert is not None:
                            break
                    if cert is None:
                        for c in self.vouched_commits.get(s, {}).values():
                            cert = c
                            break
                if cert is not None:
                    extra.append(cert)
            body = (history, tuple(extra)) if extra else (history,)
            if history or extra:
                self.send(new, "JOIN_SYNC", body, extra_bytes=64)
            self.send(new, "EPOCH",
                      (e, tuple(self.replicas), slot, self.view))
        elif self.joining:
            # the joiner decided the MEMBERSHIP slot itself (JOIN_SYNC
            # replays can carry it): it just activated along with everyone
            self.joining = False
            self._after_view_entered()
            if self.leader() == self.pid:
                # same blind-next_slot hazard as _activate: hand the seat
                # on through the certified view-change machinery instead
                # of proposing into already-decided slots
                self.change_view()
            for hook in self.on_activate_hooks:
                hook()

    # ----------------------------------------------------- joiner side
    def begin_join(self, new_epoch: int, survivors: List[str],
                   expected: Tuple[str, str]) -> None:
        """Joiner side of the replacement: pull the survivors' published
        ``xfer/<epoch>`` registers (f+1 needed), adopt the best signed
        checkpoint + snapshot, then wait for the agreed epoch bump."""
        assert self.joining
        self.pending_membership[new_epoch] = expected
        self._join_state = {"e": new_epoch, "survivors": list(survivors),
                            "done": False}
        self._poll_xfer()

    def _poll_xfer(self) -> None:
        js = self._join_state
        if js is None or js["done"]:
            return
        reg = f"xfer/{js['e']}"
        results: Dict[str, Any] = {}
        remaining = set(js["survivors"])

        def on_read(q: str, val, _byz: bool) -> None:
            results[q] = val
            remaining.discard(q)
            if remaining:
                return
            good = {q: v for q, v in results.items() if v is not None}
            if len(good) >= self.quorum and self._adopt_xfer(good):
                js["done"] = True
            else:
                self.timer(200.0, self._poll_xfer)

        for q in js["survivors"]:
            self.regs.read(q, reg, lambda val, byz, q=q: on_read(q, val, byz))

    def _adopt_xfer(self, good: Dict[str, tuple]) -> bool:
        """Adopt transferred state.  Only quorum-verifiable pieces are
        trusted unconditionally: the checkpoint must carry f+1 signatures
        and the snapshot must match its fingerprint.  Prepared-slot state
        is adopted only when f+1 survivors agree on a slot's (view, batch)
        — a single Byzantine survivor cannot plant a proposal."""
        best: Optional[Tuple[Checkpoint, Any]] = None
        views: List[int] = []
        prep_votes: Dict[Tuple[int, int, bytes], List[tuple]] = {}
        for q in sorted(good):
            _ts, raw = good[q]
            try:
                cp_wire, snap, _upto, view, prepared = crypto.decode(raw)
                cp = Checkpoint.from_wire(cp_wire)
            except Exception:
                continue
            views.append(view)
            if (cp.valid(self.registry, self.quorum) and
                    (best is None or cp.supersedes(best[0]))):
                if (cp.start == 0 or
                        crypto.fingerprint_cached(snap) == cp.app_fp):
                    best = (cp, snap)
            for (s, v, batch) in prepared:
                key = (s, v, crypto.fingerprint_cached(batch))
                prep_votes.setdefault(key, []).append(batch)
        if best is None:
            return False
        cp, snap = best
        if cp.start > 0:
            self.app.adopt(snap)
            self._boundary_snaps[cp.start] = snap
            self.exec_upto = max(self.exec_upto, cp.start - 1)
            self._maybe_checkpoint(cp)
        for (s, v, _fp), batches in sorted(prep_votes.items()):
            if len(batches) >= self.quorum and s not in self.my_prepared:
                self.my_prepared[s] = (v, as_batch(batches[0]))
        target = max(views, default=0)
        self._join_view_hint = target
        return True

    def _on_join_sync(self, src: str, body: tuple) -> None:
        """A member replays its own recent CTBcast stream to me (I joined
        after those broadcasts left the tail).  The broadcaster vouching
        for its own stream is exactly what a broadcast is — a Byzantine
        sender can only mis-describe *its own* history, which at worst
        keeps its view-change certificates from forming (liveness), never
        alters what verified certificates let me adopt (COMMITs are
        f+1-signed and re-verified on this path like on any other).

        Full replay is gated to the observer-only joining phase: a voting
        replica accepting replays would let a Byzantine leader equivocate
        around CTBcast (send one PREPARE on its stream, a different one as
        a replay) — the joiner casts no votes, so nothing it interprets
        here can complete any quorum.  Once voting (a replay can race the
        activation), only the self-authenticating part is salvaged: COMMIT
        certificates carry f+1 certify signatures and are re-verified, so
        adopting one is safe on any path at any time."""
        st = self.state.get(src)
        if st is None or st.blocked or src in self.retired:
            return
        history = body[0]
        certs = body[1] if len(body) > 1 else ()
        for cert in certs:
            # explicitly attached decided-slot certificates (the sender's
            # own stream never carried a COMMIT for them): re-verified and
            # attributed to the sender as one vouching member
            self._on_commit(src, ("COMMIT", cert), vouch_only=True)
        if not self.joining and self.gap_repair_us is None:
            # historical salvage (recorded deployments): adopt COMMITs,
            # and consume the replayed keys only for a recent-joiner
            # sender whose short stream nobody else can vouch for
            for kk, m in history:
                if certs and kk >= st.fifo_next:
                    st.fifo_next = kk + 1
                    st.recent[kk] = m
                if isinstance(m, tuple) and m and m[0] == "COMMIT":
                    self._on_commit(src, m)
            if certs:
                self._fifo_drain(src)
            return
        if not self.joining:
            # salvage the self-authenticating part, and *consume* the
            # replayed FIFO keys: the EPOCH confirmations that activate a
            # joiner are small and routinely overtake the (much larger)
            # JOIN_SYNC replays on the wire, so this branch is the common
            # landing spot for a freshly activated replica.  Without
            # advancing fifo_next, every later live broadcast from the
            # sender would wait forever on pre-join keys that are never
            # resent — the replica stays deaf to that stream until the
            # sender's next summary boundary, which under a quiet stream
            # (view-change churn only) is unboundedly far away.  The
            # skipped messages are still not interpreted (a replay racing
            # the activation must not complete any live quorum); COMMITs
            # carry f+1 re-verified signatures and are safe to adopt on
            # any path.
            for kk, m in history:
                fresh = kk >= st.fifo_next
                if fresh:
                    st.fifo_next = kk + 1
                    st.recent[kk] = m
                if not isinstance(m, tuple) or not m:
                    continue
                kind = m[0]
                if kind == "COMMIT":
                    if fresh:
                        st.noncp_msgs_in_view += 1
                    self._on_commit(src, m)
                elif not fresh:
                    continue
                elif kind == "SEAL_VIEW":
                    # mirror _on_seal_view's per-peer bookkeeping (minus
                    # the live actions: no CRTFY_VC share, no catch-up of
                    # our own view).  Skipping this leaves st.view stale,
                    # and the sender's first live COMMIT/PREPARE in its
                    # current view would fail _byz_check — permanently
                    # blocking an honest peer.
                    e2 = m[2] if len(m) > 2 else 0
                    if e2 == self.membership.epoch:
                        st.seal_view = m[1]
                        st.view = m[1]
                        st.view_synced = True
                        st.noncp_msgs_in_view = 0
                        st.new_view = None
                    elif e2 > self.membership.epoch:
                        st.view_synced = False
                elif kind == "NEW_VIEW":
                    st.noncp_msgs_in_view += 1
                    e2 = m[2] if len(m) > 2 else 0
                    if e2 == self.membership.epoch:
                        st.new_view = m[1]
                elif kind == "CHECKPOINT":
                    # self-authenticating (f+1 signatures): verify before
                    # trusting, then track like _on_checkpoint_msg so live
                    # PREPAREs against the new window aren't rejected
                    cp = Checkpoint.from_wire(m[1])
                    old_cp = st.checkpoint or self.checkpoint
                    if (cp.supersedes(old_cp) and
                            cp.valid(self.registry, self.quorum)):
                        st.checkpoint = cp
                        self._maybe_checkpoint(cp)
                elif kind == "PREPARE":
                    # counted but NOT recorded into st.prepares: replays
                    # skip _byz_check, and recorded prepares feed the
                    # fast-path decision logic
                    st.noncp_msgs_in_view += 1
            self._fifo_drain(src)
            return
        for kk, m in history:
            if kk >= st.fifo_next:
                st.fifo_next = kk + 1
                st.recent[kk] = m
                self._process_ctb(src, kk, m)
        self._fifo_drain(src)

    def _on_epoch(self, src: str, body: tuple) -> None:
        """f+1 members of the new epoch confirm the agreed switch — the
        joiner becomes a voting member."""
        e, members, _slot, view = body
        if not self.joining or self.pid not in members:
            return
        key = (e, members)
        votes = self._epoch_votes.setdefault(key, set())
        votes.add(src)
        self._epoch_view[key] = max(self._epoch_view.get(key, 0), view)
        if len(votes & set(members)) >= self.quorum:
            self._activate(e, members, self._epoch_view[key])

    def _activate(self, e: int, members: Tuple[str, ...],
                  view_hint: int) -> None:
        if not self.joining or e <= self.membership.epoch:
            return
        self.joining = False
        self._switch_epoch(MembershipEpoch(e, tuple(members)), "", self.pid)
        # catch the group's view up loudly (peers track my view through my
        # SEAL_VIEWs) and re-route anything a client already sent me
        target = max(view_hint, getattr(self, "_join_view_hint", 0))
        if target > self.view:
            self._catch_up_view(target)
        else:
            self._after_view_entered()
        if self.leader() == self.pid:
            # Activated straight into the seated-leader chair, but without
            # NEW_VIEW certificates the log position (next_slot) is blind —
            # proposing would land on already-decided slots and stall the
            # group for a full patience window.  Hand leadership on through
            # the certified view-change machinery instead.
            self.change_view()
        for hook in self.on_activate_hooks:
            hook()

    # ==================================================================
    # View change (Algorithm 3)
    # ==================================================================
    def _arm_progress_timer(self) -> None:
        if self.progress_deadline is None:
            self.progress_deadline = self.sim.now + self.view_patience
        if self._progress_timer_armed:
            return
        self._progress_timer_armed = True

        def _check() -> None:
            self._progress_timer_armed = False
            if not self._has_pending():
                self.progress_deadline = None
                return
            if (self.progress_deadline is not None and
                    self.sim.now >= self.progress_deadline):
                # starvation episode: pending work outlived the deadline
                # under the current leader's seat — record it against that
                # seat before rotating (the suspicion signal feed)
                hc = self.health_counters
                hc["starvations"] += 1
                stale = self._leader_pid
                if stale != self.pid:
                    sp = hc["seated_past"]
                    sp[stale] = sp.get(stale, 0) + 1
                for hook in self.on_starvation_hooks:
                    hook(stale)
                # patience for the next leader starts now, doubled (liveness
                # under eventual synchrony: a view must outlast the slow path)
                self.view_patience = min(self.view_patience * 2,
                                         64 * self.cfg.view_timeout_us)
                self.progress_deadline = self.sim.now + self.view_patience
                self.change_view()
            self._arm_progress_timer()

        self.timer(self.cfg.view_timeout_us / 4, _check)

    def _has_pending(self) -> bool:
        undecided = any(rid not in self.decided_rids for rid in self.pending_req)
        return (undecided or bool(self.waiting_prepare)
                or bool(self._svc_wait))

    def change_view(self) -> None:
        if self.changing_view or self.joining:
            return
        self.changing_view = True
        self._fulfill_promises_then_seal()

    def _seal_view_msg(self) -> tuple:
        """SEAL_VIEW carries the membership epoch once it is non-zero;
        epoch-0 messages keep the historical 2-tuple shape (bit-identical
        static deployments)."""
        e = self.membership.epoch
        if e == 0:
            return ("SEAL_VIEW", self.view)
        return ("SEAL_VIEW", self.view, e)

    def _fulfill_promises_then_seal(self) -> None:
        """Alg. 3 lines 4-5 + §5.4 promises.

        Before SEAL_VIEW: (1) every WILL_CERTIFY promise of this view is
        fulfilled by broadcasting CERTIFY (unconditional — this is what makes
        the WILL_COMMIT waits below live at *other* replicas), and (2) every
        WILL_COMMIT promise is fulfilled by broadcasting a COMMIT certificate
        (or the slot is covered by a checkpoint).  fast-path decisions
        survive the view change exactly because of these waits.
        """
        for (v, s) in sorted(self.my_will_certifies):
            if v == self.view and s in self.checkpoint.open_slots:
                self._do_certify(v, s)
        pending = [s for (v, s) in self.my_will_commits
                   if v == self.view and s not in self.my_commits
                   and s in self.checkpoint.open_slots]
        if pending:
            self.timer(50.0, self._fulfill_promises_then_seal)
            return
        self.view += 1
        self._leader_pid = self.replicas[self.view % self.n]
        self.health_counters["view_changes"] += 1
        self._ctb_broadcast(self._seal_view_msg())
        self.changing_view = False
        self._after_view_entered()

    def _after_view_entered(self) -> None:
        """RPC re-routing after a view change: followers re-echo pending
        requests to the new leader; the new leader re-notes them."""
        # requests proposed in dead views may be proposed again
        self.proposed_rids = {rid for rid in self.proposed_rids
                              if rid in self.decided_rids}
        # rids with a live PREPARE in an open slot will be re-proposed by
        # _repropose — don't also queue them (double assignment)
        prepared_rids = {r[0] for s, (_v, batch) in self.my_prepared.items()
                         if s > self.exec_upto for r in batch}
        for rid, req in list(self.pending_req.items()):
            if rid in self.decided_rids or rid in prepared_rids:
                continue
            if self.is_leader():
                self._note_echo(rid, self.pid)
            else:
                self.send(self.leader(), "ECHO", (rid,))
        if self._has_pending():
            self._arm_progress_timer()

    def _on_seal_view(self, p: str, m: tuple) -> None:
        v = m[1]
        e = m[2] if len(m) > 2 else 0
        if e != self.membership.epoch:
            # Wrong-epoch SEAL_VIEW: rejected like a stale view.  The
            # drop is permanent (the FIFO slot is consumed) — recovery is
            # by *fresh* seals, not resends: a replica whose pending work
            # stalls re-seals through its own progress timer, and later
            # same-epoch SEAL_VIEWs re-establish the peer's view.  Worst
            # case is a bounded liveness delay around the switch window.
            if e > self.membership.epoch:
                # the peer advanced past my epoch: its views are now
                # unknowable until I catch up and it seals afresh — relax
                # the strict per-view checks so I don't block an honest
                # peer on its post-switch traffic
                self.state[p].view_synced = False
            return
        st = self.state[p]
        st.seal_view = v
        st.view = v
        st.view_synced = True
        st.noncp_msgs_in_view = 0
        st.new_view = None
        if not self.joining:
            # certificate share attesting q's state (as of this FIFO point)
            snap = self._peer_snapshot(p)
            digest = crypto.fingerprint_cached(snap)
            self.vc_snapshots[(v, p)] = snap
            ldr = self.leader(v)
            self.async_sign(("vc", v, p, digest), lambda sig: self.send(
                ldr, "CRTFY_VC", (v, p, digest, sig)))
        if v > self.view:
            # peer is ahead: join the view change
            self._catch_up_view(v)

    def _catch_up_view(self, v: int) -> None:
        while self.view < v:
            self.view += 1
            self._leader_pid = self.replicas[self.view % self.n]
            self.health_counters["view_changes"] += 1
            self._ctb_broadcast(self._seal_view_msg())
        self._after_view_entered()

    def _peer_snapshot(self, p: str) -> tuple:
        st = self.state[p]
        cp = st.checkpoint or self.checkpoint
        commits = tuple(sorted(
            (s, self._cert_wire(c)) for s, c in st.commits.items()
            if s in cp.open_slots))
        return ("snap", p, st.view, cp.to_wire(), commits)

    @staticmethod
    def _cert_wire(c: dict) -> tuple:
        return (c["view"], c["slot"], c["fp"], c["req"], tuple(c["sigs"]))

    def _on_crtfy_vc(self, src: str, body: tuple) -> None:
        v, q, digest, sig = body
        if src not in self._member_set:
            return  # view-change shares come from current-epoch members
        if self.leader(v) != self.pid:
            return
        self.async_verify(src, ("vc", v, q, digest), sig,
                          lambda ok: self._vc_share_verified(ok, src, v, q,
                                                             digest, sig))

    def _vc_share_verified(self, ok: bool, src: str, v: int, q: str,
                           digest: bytes, sig: bytes) -> None:
        if not ok:
            return
        shares = self.vc_shares.setdefault((v, q), {})
        shares[src] = (digest, sig)
        self._try_new_view(v)

    def _try_new_view(self, v: int) -> None:
        if (self.leader(v) != self.pid or v in self.new_view_sent or
                self.view != v):
            return
        certs: Dict[str, tuple] = {}
        for q in self.replicas:
            shares = self.vc_shares.get((v, q), {})
            snap = self.vc_snapshots.get((v, q))
            if snap is None:
                continue
            my_digest = crypto.fingerprint_cached(snap)
            matching = tuple((pid, sig) for pid, (dg, sig) in sorted(shares.items())
                             if dg == my_digest)
            if len({pid for pid, _ in matching}) >= self.quorum:
                certs[q] = (snap, matching)
        if len(certs) < self.quorum:
            return
        self.new_view_sent.add(v)
        e = self.membership.epoch
        self._ctb_broadcast(("NEW_VIEW", certs) if e == 0
                            else ("NEW_VIEW", certs, e))
        # leader applies its own NEW_VIEW when it FIFO-delivers it

    def _on_new_view(self, p: str, m: tuple) -> None:
        certs = m[1]
        e = m[2] if len(m) > 2 else 0
        if e != self.membership.epoch:
            return  # stale-epoch NEW_VIEW: rejected like a stale view
        st = self.state[p]
        st.new_view = certs
        v = st.view
        while self.view < v:
            self.view += 1
            self._leader_pid = self.replicas[self.view % self.n]
            self._ctb_broadcast(self._seal_view_msg())
        # adopt the highest checkpoint in the certificates
        best_cp = self.checkpoint
        for q, (snap, _shares) in certs.items():
            cp = Checkpoint.from_wire(snap[3])
            if cp.supersedes(best_cp):
                best_cp = cp
        self._maybe_checkpoint(best_cp)
        if self.leader(v) == self.pid:
            self._repropose(v, certs)

    def _repropose(self, v: int, certs: Dict[str, tuple]) -> None:
        """Alg. 3 lines 17-19: transfer constrained slots, no-op the holes,
        then open the remaining slots for new requests."""
        committed_slots = [s for _q, (snap, _sh) in certs.items()
                           for s, _cw in snap[4]]
        max_committed = max(committed_slots, default=self.checkpoint.start - 1)
        proposed_upto = self.checkpoint.start - 1
        for s in self.checkpoint.open_slots:
            if (self.gap_repair_us is not None and s in self.decided and
                    s <= self.exec_upto):
                # Already decided AND executed here: a fresh PREPARE round
                # would re-run the full certify/commit machinery for a
                # settled slot, and a rotation's worth of them in one
                # burst saturates the event loop for the slots that
                # actually need agreement.  A member missing the decision
                # heals from stored commits or the batch gap repair —
                # which is exactly the feature this skip is gated on,
                # keeping non-self-healing deployments bit-identical.
                proposed_upto = s
                continue
            must = self._must_propose(s, certs)
            prior = self.my_prepared.get(s)
            if must is not None:
                req = must
            elif (prior is not None and s > self.exec_upto and
                  any(self._needs_execution(r) and
                      r[0] not in self.executed_rids
                      for r in prior[1])):
                req = prior[1]              # re-propose the in-flight batch
            elif s <= max_committed or s <= self.exec_upto:
                req = _noop_request(v, s)   # ⊥ slot below a committed one
            elif self.propose_queue:
                req = self._assemble_batch()
                if req is None:
                    break
            else:
                break
            proposed_upto = s
            self._ctb_broadcast(("PREPARE", v, s, req))
        self.next_slot = max(self.next_slot, proposed_upto + 1,
                             self.checkpoint.start)
        self.reproposed_views.add(v)
        self._drain_proposals()

    def _must_propose(self, slot: int, certs: Dict[str, tuple]) -> Optional[tuple]:
        """Latest committed request for slot among the certificates, or None."""
        best: Optional[Tuple[int, tuple]] = None
        for q, (snap, _shares) in certs.items():
            commits = snap[4]
            for s, cw in commits:
                if s != slot:
                    continue
                cv, cs, cfp, creq, csigs = cw
                if best is None or cv > best[0]:
                    best = (cv, creq)
        return None if best is None else best[1]

    # ==================================================================
    # CTBcast summaries (Algorithm 4)
    # ==================================================================
    def _need_summary(self, seg: int) -> None:
        """My CTBcast finished segment ``seg`` — gather f+1 certificates."""
        # Receivers send CERTIFY_SUMMARY when their FIFO pointer passes the
        # segment end (see _fifo_drain); nothing to send here — we simply
        # wait.  Self-certify immediately (we trivially know our own stream).
        k_end = (seg + 1) * self.my_ctb.summary_interval - 1
        self._send_certify_summary(self.pid, k_end)

    def _send_certify_summary(self, p: str, k: int) -> None:
        """I have FIFO-processed p's stream up to k (a segment boundary) —
        sign a certificate share of p's recent window (Alg. 4 line 2)."""
        if self.joining:
            return  # summary quorums are drawn from the current epoch
        if p == self.pid:
            recent = dict(self.my_ctb.buf)
        else:
            recent = self.state[p].recent
        # batch-digest the window (t entries; overlapping segment windows
        # hit the memo) and digest the one-shot wrapper cache-free
        lo = k - self.cfg.t
        kks = sorted(kk for kk in recent if lo < kk <= k)
        fps = crypto.fingerprint_batch_cached([recent[kk] for kk in kks])
        window = tuple(zip(kks, fps))
        digest = crypto.fingerprint_fresh(("sum", p, k, window))
        # bookkeeping signature → background task (§3), not the critical path
        self.background(lambda: self.async_sign(
            ("sum", p, k, digest),
            lambda sig: self.send(p, "CERTIFY_SUMMARY", (k, digest, sig))))

    def _on_certify_summary(self, src: str, body: tuple) -> None:
        k, digest, sig = body
        if src not in self._member_set:
            return  # summary quorums are drawn from the current epoch
        si = self.my_ctb.summary_interval
        if (k + 1) % si != 0:
            return
        # one digest per segment end, not one per incoming share: buf is
        # append-only below k at this point, so the window is stable
        my_digest = self._summary_digests.get(k)
        if my_digest is None:
            buf = self.my_ctb.buf
            lo = k - self.cfg.t
            kks = sorted(kk for kk in buf if lo < kk <= k)
            fps = crypto.fingerprint_batch_cached([buf[kk] for kk in kks])
            my_digest = crypto.fingerprint_fresh(
                ("sum", self.pid, k, tuple(zip(kks, fps))))
            self._summary_digests[k] = my_digest
            for old in [kk for kk in self._summary_digests
                        if kk <= k - self.cfg.t]:
                del self._summary_digests[old]
        if digest != my_digest:
            return
        self.background(lambda: self.async_verify(
            src, ("sum", self.pid, k, digest), sig,
            lambda ok: self._summary_sig_ok(ok, src, k, digest, sig)))

    def _summary_sig_ok(self, ok: bool, src: str, k: int, digest: bytes,
                        sig: bytes) -> None:
        if not ok:
            return
        sigs = self.summary_sigs.setdefault(k, {})
        sigs[src] = sig
        si = self.my_ctb.summary_interval
        seg = k // si
        # quorum drawn from the *current* epoch's membership (shares from
        # since-retired replicas must not certify a summary on their own)
        live = sum(1 for q in sigs if q in self._member_set)
        if live >= self.quorum and seg > self.my_ctb.summaries_ok:
            history = tuple(sorted((kk, m) for kk, m in self.my_ctb.buf.items()
                                   if k - self.cfg.t < kk <= k))
            bundle = (k, digest, tuple(sorted(sigs.items())), history)
            self._tb_broadcast("SUMMARY", k, bundle)
            self.my_ctb.summary_certified(seg)

    def _on_summary(self, origin: str, payload: tuple) -> None:
        k, digest, sigs, history = payload
        window = tuple(zip(
            (kk for kk, _ in history),
            crypto.fingerprint_batch_cached([m for _, m in history])))
        if crypto.fingerprint_fresh(("sum", origin, k, window)) != digest:
            return
        pids = {pid for pid, _ in sigs}
        if len(pids) < self.quorum:
            return
        share = ("sum", origin, k, digest)
        if not all(self.registry.verify_batch(
                [(pid, share, sig) for pid, sig in sigs])):
            return
        st = self.state.get(origin)
        if st is None or st.blocked or origin in self.retired:
            return
        if st.fifo_next > k:
            return  # no gap — nothing to heal
        # Heal the gap: apply missed messages in order WITHOUT the Byzantine
        # checks (Alg. 4 line 14 — the f+1 certificate vouches for them).
        start = max(st.fifo_next, k - self.cfg.t + 1)
        for kk, m in history:
            if start <= kk <= k and kk >= st.fifo_next:
                st.fifo_next = kk + 1
                st.recent[kk] = m
                self._process_ctb(origin, kk, m)
        st.fifo_next = max(st.fifo_next, k + 1)
        self._fifo_drain(origin)

    # ==================================================================
    # accounting (Table 2)
    # ==================================================================
    def memory_bytes(self) -> dict:
        tb = self.tb.memory_bytes()
        ctb = sum(c.memory_bytes() for c in self.ctb.values())
        # Per-slot buffers are sized for what a slot can hold: one request
        # in the paper's configuration, up to max_batch requests (bounded
        # by max_batch_bytes) with batching — still O(window), per Table 2.
        slot_cap = 64 + (max(self.cfg.max_batch_bytes +
                             self.cfg.max_batch * self._REQ_FRAMING,
                             self.cfg.max_request_bytes)
                         if self.cfg.max_batch > 1
                         else self.cfg.max_request_bytes)
        window_slots = (len(self.decided) + len(self.my_prepared))
        window_bufs = window_slots * slot_cap
        # executed results are retained at their actual (batched) size
        result_bufs = sum(64 + sum(len(r) for r in res)
                          for res in self.results.values())
        # actual occupancy of the retained batches (≤ the preallocated cap)
        window_actual = (
            sum(crypto.batch_wire_size(b) for b in self.decided.values()) +
            sum(crypto.batch_wire_size(b) for _v, b in self.my_prepared.values()))
        return {"tbcast_buffers": tb, "ctbcast_arrays": ctb,
                "window_state": window_bufs + result_bufs,
                "window_actual": window_actual + result_bufs,
                "total": tb + ctb + window_bufs + result_bufs}
