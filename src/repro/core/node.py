"""Base class for protocol participants (replicas, clients, memory nodes).

Bundles the simulator process model with the substrate every uBFT node needs:
network handle, key material, asynchronous-crypto helpers (thread-pool cost
model), and a message dispatch table.
"""

from __future__ import annotations

from heapq import heappush as _heappush
from typing import Any, Callable, Dict, List, Optional

from repro.core import crypto
from repro.sim.events import Process, Simulator
from repro.sim.net import NetworkModel


class Node(Process):
    def __init__(self, sim: Simulator, net: NetworkModel, registry: crypto.KeyRegistry,
                 pid: str):
        super().__init__(sim, pid)
        self.net = net
        self.netp = net.p
        self._net_send = net.send   # bound once; send() is the hot path
        self.registry = registry
        self.signer = registry.keygen(pid)
        self._dispatch: Dict[str, Callable[[str, Any], None]] = {}
        #: called (in registration order) every time this node transitions
        #: crashed -> recovered.  Timer-driven subsystems register here to
        #: re-arm state whose pending timers fired (and were swallowed, or
        #: deliberately no-op'd) while the node was down — e.g. TBcast's
        #: ack/RTO flags, which would otherwise strand retransmission
        #: forever (see core/tbcast.py).
        self.recover_hooks: List[Callable[[], None]] = []
        # Subclasses overriding on_message (interceptors, Byzantine
        # adversaries) must keep receiving messages even though the fast
        # deliver() path below inlines the dispatch-table lookup.
        self._custom_on_message = (type(self).on_message
                                   is not Node.on_message)

    # -- message plumbing --------------------------------------------------
    def send(self, dst: str, kind: str, body: Any, extra_bytes: int = 0,
             size: Optional[int] = None) -> None:
        # Cached sizing: shared payload subtrees (batches, certs) are sized
        # once per lifetime — see the wire-cache invariant in core/crypto.py.
        # Fan-out senders that ship one body to many peers precompute the
        # full wire size once and pass it via ``size``.
        if size is None:
            size = crypto.wire_size_shallow(body) + len(kind) + 16 + extra_bytes
        self._net_send(self.pid, dst, (kind, body), size)

    def send_fanout(self, dsts: List[str], kind: str, body: Any,
                    extra_bytes: int = 0, size: Optional[int] = None) -> None:
        """Ship one body to many peers: size once, encode once, price and
        schedule all deliveries in one network call (bit-identical to a
        per-dst ``send`` loop — see ``NetworkModel.send_fanout``)."""
        if size is None:
            size = crypto.wire_size_shallow(body) + len(kind) + 16 + extra_bytes
        self.net.send_fanout(self.pid, dsts, (kind, body), size)

    def handle(self, kind: str, fn: Callable[[str, Any], None]) -> None:
        self._dispatch[kind] = fn

    def deliver(self, src: str, msg: Any, size: int) -> None:
        # Hot-path override of Process.deliver: same busy-server semantics,
        # but the dispatch-table lookup happens inside the single closure —
        # no intermediate on_message frame per message.
        if self.crashed:
            return
        sim = self.sim
        start = sim.now
        if self.busy_until > start:
            start = self.busy_until
        done = start + self.handling_cost
        self.busy_until = done

        def _handle() -> None:
            if self.crashed:
                return
            if self._custom_on_message:
                self.on_message(src, msg)
                return
            kind, body = msg
            fn = self._dispatch.get(kind)
            if fn is None:
                self.on_unhandled(src, kind, body)
            else:
                fn(src, body)

        sim._seq += 1
        _heappush(sim._heap, (done, sim._seq, _handle))

    def on_message(self, src: str, msg: Any) -> None:
        kind, body = msg
        fn = self._dispatch.get(kind)
        if fn is None:
            self.on_unhandled(src, kind, body)
        else:
            fn(src, body)

    def on_unhandled(self, src: str, kind: str, body: Any) -> None:
        pass  # unknown messages are ignored (Byzantine noise tolerance)

    # -- asynchronous crypto (thread-pool model) ----------------------------
    # The paper dispatches signatures/verifications to a pool (Fig 9's Crypto
    # bucket includes dispatch+sync).  We occupy the event loop thread only
    # for the dispatch cost; the op completes after its latency in parallel.
    def async_sign(self, payload: Any, cb: Callable[[bytes], None]) -> None:
        sig = self.signer.sign(payload)
        self._async_done(self.netp.sign_us, lambda: cb(sig))

    def async_verify(self, pid: str, payload: Any, sig: bytes,
                     cb: Callable[[bool], None]) -> None:
        ok = self.registry.verify(pid, payload, sig)
        self._async_done(self.netp.verify_us, lambda: cb(ok))

    def async_verify_many(self, items, cb: Callable[[list], None]) -> None:
        """Verify [(pid, payload, sig)] in parallel on the pool.

        Cost model: dispatch + one verify latency + 3 µs per extra item
        (pool contention), not n×verify — matches the paper's slow path
        adding ~30 µs per round, not ~90 µs.
        """
        oks = self.registry.verify_batch(items)
        extra = 3.0 * max(0, len(oks) - 1)
        self._async_done(self.netp.verify_us + extra, lambda: cb(oks))

    def _async_done(self, latency: float, cb: Callable[[], None]) -> None:
        if self.crashed:
            return
        start = self.sim.now
        done = self.occupy(self.netp.crypto_dispatch_us)
        if self.sim.tracing:
            self.sim.trace.append(("crypto", start, done + latency))

        def _fire() -> None:
            if not self.crashed:
                # completion handling costs a dispatch on the event thread
                self.execute(cb, cost=self.handling_cost)

        self.sim.at(done + latency, _fire)

    def background(self, cb: Callable[[], None]) -> None:
        """Run ``cb`` at the next background-task quantum boundary (the
        paper's bookkeeping-signature path, off the critical path)."""
        q = self.netp.bg_quantum_us
        delay = q - (self.sim.now % q)
        self.timer(delay, cb)

    # -- crash / recovery ----------------------------------------------------
    def recover(self) -> None:
        was_crashed = self.crashed
        super().recover()
        if was_crashed:
            for hook in list(self.recover_hooks):
                hook()

    # -- timers --------------------------------------------------------------
    def timer(self, delay: float, cb: Callable[[], None], note: str = "") -> None:
        def _fire() -> None:
            if not self.crashed:
                cb()
        self.sim.after(delay, _fire)
