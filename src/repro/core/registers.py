"""Reliable SWMR regular registers over disaggregated memory (§6.1).

Faithful to the paper's construction:

* **SWMR** — memory nodes enforce single-writer access control (the RDMA
  permission tokens of §6.1 become an owner check at the node).
* **Regular** — RDMA is atomic only at 8-byte granularity, so a READ that
  overlaps a WRITE may return torn data.  The simulation models torn reads
  explicitly (8-byte splicing during the write window); the register layer
  recovers regularity via checksums + double-buffering (two sub-registers,
  round-robin) + a δ cooldown between WRITEs, exactly as in the paper.
* **Reliable** — each register is replicated on 2f_m+1 memory nodes; WRITEs
  and READs complete at a majority (f_m+1); the highest valid timestamp wins.
* **Byzantine-writer detection** — if both sub-registers have invalid
  checksums and the READ took < δ, or both carry the same timestamp, the
  owner is exposed as Byzantine and a default value is returned.

Memory nodes are *trusted to crash only* — they are the paper's TCB.  They
are application-oblivious: they store opaque blobs under (owner, register)
keys and can be shared by many replicated applications.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import crypto
from repro.core.node import Node
from repro.sim.events import Simulator
from repro.sim.net import NetworkModel

#: sub-register blob layout: ts(8) + checksum(8) + len(4) + value
BLOB_HEADER = 20


def _pack(ts: int, value: bytes) -> bytes:
    body = struct.pack("<qI", ts, len(value)) + value
    return crypto.checksum_bytes(body) + body


def _unpack(blob: Optional[bytes]) -> Optional[Tuple[int, bytes]]:
    """Returns (ts, value) if the checksum validates, else None."""
    if not blob or len(blob) < BLOB_HEADER:
        return None
    csum, body = blob[:8], blob[8:]
    if crypto.checksum_bytes(body) != csum:
        return None
    ts, ln = struct.unpack_from("<qI", body, 0)
    value = body[12:12 + ln]
    if len(value) != ln:
        return None
    return ts, value


@dataclass
class _Cell:
    """One sub-register replica at one memory node, with write-window
    modeling for torn reads (8-byte atomicity)."""
    blob: bytes = b""
    prev: bytes = b""
    t_start: float = -1.0
    t_end: float = -1.0

    def write(self, blob: bytes, now: float, dur: float) -> None:
        self.prev = self.blob if now >= self.t_end else self.read(now)
        self.blob = blob
        self.t_start, self.t_end = now, now + dur

    def read(self, now: float) -> bytes:
        if now >= self.t_end or self.t_start < 0:
            return self.blob
        if now <= self.t_start:
            return self.prev
        # torn read: new prefix + old suffix at 8-byte granularity
        frac = (now - self.t_start) / max(self.t_end - self.t_start, 1e-9)
        cut = int(frac * max(len(self.blob), len(self.prev)) / 8) * 8
        new = self.blob[:cut]
        old = self.prev[cut:] if len(self.prev) > cut else b"\x00" * 8
        return new + old


class MemoryNode(Node):
    """Disaggregated memory node: READ/WRITE with access control.  Part of
    the trusted computing base — fails only by crashing."""

    handling_cost = 0.3  # memnode service time (µs)

    def __init__(self, sim: Simulator, net: NetworkModel, registry, pid: str,
                 write_duration_us: float = 0.4):
        super().__init__(sim, net, registry, pid)
        self.cells: Dict[Tuple[str, str, int], _Cell] = {}
        self.write_duration_us = write_duration_us
        self.handle("REG_WRITE", self._on_write)
        self.handle("REG_READ", self._on_read)

    def _on_write(self, src: str, body: Any) -> None:
        owner, reg, sub, blob, token = body
        if owner != src:
            return  # permission violation: only the owner may write (SWMR)
        cell = self.cells.setdefault((owner, reg, sub), _Cell())
        cell.write(blob, self.sim.now, self.write_duration_us)
        self.send(src, "REG_WRITE_ACK", (reg, sub, token))

    def _on_read(self, src: str, body: Any) -> None:
        owner, reg, token = body
        blobs = tuple(
            self.cells.setdefault((owner, reg, sub), _Cell()).read(self.sim.now)
            for sub in (0, 1)
        )
        self.send(src, "REG_READ_ACK", (owner, reg, token, blobs))

    def memory_bytes(self) -> int:
        return sum(len(c.blob) + len(c.prev) for c in self.cells.values())


class RegisterClient:
    """Reliable SWMR regular register operations for one node (§6.1)."""

    def __init__(self, node: Node, mem_nodes: List[str], f_m: int,
                 slot_bytes: int = 128):
        assert len(mem_nodes) >= 2 * f_m + 1
        self.node = node
        self.mem_nodes = mem_nodes
        self.quorum = f_m + 1
        self.slot_bytes = slot_bytes
        self._wts: Dict[str, int] = {}
        self._last_write: Dict[str, float] = {}
        self._pending: Dict[int, dict] = {}
        self._token = 0
        node.handle("REG_WRITE_ACK", self._on_write_ack)
        node.handle("REG_READ_ACK", self._on_read_ack)

    # ------------------------------------------------------------- WRITE
    def write(self, reg: str, value: bytes, cb: Callable[[], None]) -> None:
        """WRITE my register ``reg`` (owner = this node).  Completes at a
        majority of memory nodes.  Enforces the δ cooldown between WRITEs to
        the same register (§6.1) so readers can always find a complete
        sub-register."""
        now = self.node.sim.now
        delta = self.node.netp.delta_us
        earliest = self._last_write.get(reg, -delta) + delta
        if now < earliest:
            self.node.timer(earliest - now, lambda: self.write(reg, value, cb))
            return
        self._last_write[reg] = now
        if self.node.sim.tracing:
            t0 = now
            inner_cb = cb
            def cb():
                self.node.sim.trace.append(("smwr", t0, self.node.sim.now))
                inner_cb()
        ts = self._wts.get(reg, 0) + 1
        self._wts[reg] = ts
        blob = _pack(ts, value)
        sub = ts % 2  # round-robin double buffering
        self._token += 1
        tok = self._token
        self._pending[tok] = {"kind": "w", "acks": 0, "cb": cb, "done": False}
        for m in self.mem_nodes:
            self.node.send(m, "REG_WRITE", (self.node.pid, reg, sub, blob, tok))

    def _on_write_ack(self, src: str, body: Any) -> None:
        _reg, _sub, tok = body
        st = self._pending.get(tok)
        if st is None or st["kind"] != "w" or st["done"]:
            return
        st["acks"] += 1
        if st["acks"] >= self.quorum:
            st["done"] = True
            del self._pending[tok]
            st["cb"]()

    # -------------------------------------------------------------- READ
    def read(self, owner: str, reg: str,
             cb: Callable[[Optional[Tuple[int, bytes]], bool], None]) -> None:
        """READ ``owner``'s register.  cb(value, owner_is_byzantine) where
        value is (ts, bytes) or None (default value ⊥)."""
        if self.node.sim.tracing:
            t0 = self.node.sim.now
            inner_cb = cb
            def cb(val, byz):
                self.node.sim.trace.append(("smwr", t0, self.node.sim.now))
                inner_cb(val, byz)
        self._token += 1
        tok = self._token
        self._pending[tok] = {
            "kind": "r", "resps": [], "cb": cb, "done": False,
            "start": self.node.sim.now, "owner": owner, "reg": reg,
            "attempt": 1,
        }
        for m in self.mem_nodes:
            self.node.send(m, "REG_READ", (owner, reg, tok))

    def _on_read_ack(self, src: str, body: Any) -> None:
        owner, reg, tok, blobs = body
        st = self._pending.get(tok)
        if st is None or st["kind"] != "r" or st["done"]:
            return
        st["resps"].append(blobs)
        if len(st["resps"]) < self.quorum:
            return
        st["done"] = True
        del self._pending[tok]
        self._conclude_read(st)

    def _conclude_read(self, st: dict) -> None:
        took = self.node.sim.now - st["start"]
        delta = self.node.netp.delta_us
        best: Optional[Tuple[int, bytes]] = None
        byz = False
        for blobs in st["resps"]:
            vals = [_unpack(b) for b in blobs]
            ok = [v for v in vals if v is not None]
            if len(ok) == 2 and ok[0][0] == ok[1][0]:
                byz = True  # both sub-registers with the same timestamp
            if not ok and took < delta and any(len(b) >= BLOB_HEADER for b in blobs):
                byz = True  # torn/bogus on both subs within δ → Byzantine
            for v in ok:
                if best is None or v[0] > best[0]:
                    best = v
        if best is None and not byz:
            blank = all(not b for blobs in st["resps"] for b in blobs)
            if took >= delta and not blank:
                # inconclusive slow read — retry (§6.1)
                self.read(st["owner"], st["reg"],
                          st["cb"]) if st["attempt"] < 8 else st["cb"](None, False)
                return
        st["cb"](best, byz)

    # --------------------------------------------------------- accounting
    def disaggregated_bytes_per_register(self) -> int:
        """Table 2 model: 2 sub-registers × (checksum 8 + header 12 + value)."""
        return 2 * (8 + 12 + self.slot_bytes)
