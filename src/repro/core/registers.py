"""Reliable SWMR regular registers over disaggregated memory (§6.1).

Faithful to the paper's construction:

* **SWMR** — memory nodes enforce single-writer access control (the RDMA
  permission tokens of §6.1 become an owner check at the node).
* **Regular** — RDMA is atomic only at 8-byte granularity, so a READ that
  overlaps a WRITE may return torn data.  The simulation models torn reads
  explicitly (8-byte splicing during the write window); the register layer
  recovers regularity via checksums + double-buffering (two sub-registers,
  round-robin) + a δ cooldown between WRITEs, exactly as in the paper.
* **Reliable** — each register is replicated on 2f_m+1 memory nodes; WRITEs
  and READs complete at a majority (f_m+1); the highest valid timestamp wins.
* **Byzantine-writer detection** — if both sub-registers carry *data-sized*
  blobs with invalid checksums and the READ took < δ, or both carry the same
  timestamp, the owner is exposed as Byzantine and a default value is
  returned.  (An empty sub-register next to a torn one is *not* Byzantine —
  it is simply a READ overlapping the very first WRITE, which regularity
  allows to return ⊥.)
* **Inconclusive slow reads** retry, but at most :data:`MAX_READ_ATTEMPTS`
  times end-to-end; a permanently torn register yields ⊥ rather than an
  unbounded retry loop.

Memory nodes are *trusted to crash only* — they are the paper's TCB.  They
are application-oblivious: they store opaque blobs under (owner, register)
keys and can be shared by many replicated applications.

Memory pools (reconfiguration + sharding)
-----------------------------------------
The TCB is organised into :class:`MemoryPool`\\ s.  A pool owns 2f_m+1
:class:`MemoryNode` processes plus a tiny :class:`_PoolManager` (the paper's
external membership/lease service, e.g. the provider's control plane):

* **Leases** — each member must answer the manager's periodic ``LEASE_PING``
  within ``lease_us``; a member whose lease expires is *suspected* and (when
  ``auto_reconfigure`` is on) replaced.
* **Reconfiguration** — the manager installs a fresh memory node, pulls the
  cell state from f_m+1 surviving members (any such quorum intersects every
  completed WRITE's ack quorum), re-replicates the highest-valid-timestamp
  blob per (owner, register, sub-register) to the fresh node, and only then
  swaps it into the membership — a fresh node never serves READs before it
  has been synced (``serving`` flag), so quorum intersection is preserved
  across configuration changes.
* **Sharding** — a :class:`RegisterClient` may be given several pools;
  register keys are hashed ``crc32(owner:reg) % n_pools`` so many streams /
  replicated applications share disaggregated memory without one pool
  becoming the bottleneck ("shared by many replicated applications", §6.1).
  A client attached under an application *namespace* (see
  :mod:`repro.core.substrate`) hashes ``crc32(app:owner:reg)`` instead, so
  each app's register keys spread over the shared pools independently; the
  empty namespace preserves the legacy layout bit-for-bit.  Each pool
  independently satisfies the < 1 MiB Table 2 budget — accounted *per app*
  when pools are shared (:meth:`MemoryPool.memory_bytes_by_owner`).

Clients read the pool's *current* membership at each operation (epoch bumps
on every reconfiguration); in-flight operations started against the previous
membership still complete because at most f_m members change at once.
"""

from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import crypto
from repro.core.node import Node
from repro.sim.events import Simulator
from repro.sim.net import NetworkModel

#: sub-register blob layout: ts(8) + checksum(8) + len(4) + value
BLOB_HEADER = 20

#: end-to-end cap on inconclusive-slow-read retries (§6.1): a permanently
#: torn register yields ⊥ after this many attempts instead of looping.
MAX_READ_ATTEMPTS = 8

#: Table 2 budget: occupied disaggregated memory per pool must stay under
#: 1 MiB (enforced by benchmarks/table2_memory.py and the fault tests).
POOL_MEMORY_BUDGET = 2**20


def _pack(ts: int, value: bytes) -> bytes:
    body = struct.pack("<qI", ts, len(value)) + value
    return crypto.checksum_bytes(body) + body


def _unpack(blob: Optional[bytes]) -> Optional[Tuple[int, bytes]]:
    """Returns (ts, value) if the checksum validates, else None."""
    if not blob or len(blob) < BLOB_HEADER:
        return None
    csum, body = blob[:8], blob[8:]
    if crypto.checksum_bytes(body) != csum:
        return None
    ts, ln = struct.unpack_from("<qI", body, 0)
    value = body[12:12 + ln]
    if len(value) != ln:
        return None
    return ts, value


def _unpack_batch(blobs: List[Optional[bytes]]
                  ) -> List[Optional[Tuple[int, bytes]]]:
    """Batch :func:`_unpack`: validate a read quorum's worth of
    sub-register blobs through one :func:`crypto.checksum_bytes_batch`
    call.  Element-wise identical to mapping ``_unpack``."""
    out: List[Optional[Tuple[int, bytes]]] = [None] * len(blobs)
    idx: List[int] = []
    bodies: List[bytes] = []
    for i, blob in enumerate(blobs):
        if blob and len(blob) >= BLOB_HEADER:
            idx.append(i)
            bodies.append(blob[8:])
    if not idx:
        return out
    for i, body, csum in zip(idx, bodies,
                             crypto.checksum_bytes_batch(bodies)):
        if blobs[i][:8] != csum:
            continue
        ts, ln = struct.unpack_from("<qI", body, 0)
        value = body[12:12 + ln]
        if len(value) == ln:
            out[i] = (ts, value)
    return out


@dataclass
class _Cell:
    """One sub-register replica at one memory node, with write-window
    modeling for torn reads (8-byte atomicity)."""
    blob: bytes = b""
    prev: bytes = b""
    t_start: float = -1.0
    t_end: float = -1.0

    def write(self, blob: bytes, now: float, dur: float) -> None:
        self.prev = self.blob if now >= self.t_end else self.read(now)
        self.blob = blob
        self.t_start, self.t_end = now, now + dur

    def read(self, now: float) -> bytes:
        if now >= self.t_end or self.t_start < 0:
            return self.blob
        if now <= self.t_start:
            return self.prev
        # torn read: new prefix + old suffix at 8-byte granularity
        frac = (now - self.t_start) / max(self.t_end - self.t_start, 1e-9)
        cut = int(frac * max(len(self.blob), len(self.prev)) / 8) * 8
        new = self.blob[:cut]
        old = self.prev[cut:] if len(self.prev) > cut else b"\x00" * 8
        return new + old


class MemoryNode(Node):
    """Disaggregated memory node: READ/WRITE with access control.  Part of
    the trusted computing base — fails only by crashing.

    A node installed as a *replacement* starts with ``serving=False`` and
    drops READs until its pool manager has pushed the re-replicated state
    (``POOL_PUSH``); WRITEs are always accepted so no new data is lost
    during the sync window.
    """

    handling_cost = 0.3  # memnode service time (µs)

    def __init__(self, sim: Simulator, net: NetworkModel, registry, pid: str,
                 write_duration_us: float = 0.4,
                 manager: Optional[str] = None, serving: bool = True):
        super().__init__(sim, net, registry, pid)
        self.cells: Dict[Tuple[str, str, int], _Cell] = {}
        self.write_duration_us = write_duration_us
        self.manager = manager
        self.serving = serving
        #: owners whose write permission was re-keyed away (replica
        #: replacement): their WRITEs are dropped like any permission
        #: violation — a Byzantine replaced replica cannot keep writing
        self.revoked: set = set()
        #: Byzantine memory-side adversary (beyond the crash-only TCB
        #: contract): serve old-but-well-formed blobs — valid checksums,
        #: stale timestamps (see ``set_stale_serve``)
        self.stale_serve = False
        self._stale_cells: Dict[Tuple[str, str, int], bytes] = {}
        self.handle("REG_WRITE", self._on_write)
        self.handle("REG_READ", self._on_read)
        self.handle("LEASE_PING", self._on_lease_ping)
        self.handle("POOL_PULL", self._on_pool_pull)
        self.handle("POOL_PUSH", self._on_pool_push)
        self.handle("POOL_REKEY", self._on_pool_rekey)

    def _on_write(self, src: str, body: Any) -> None:
        owner, reg, sub, blob, token = body
        if owner != src or owner in self.revoked:
            return  # permission violation: only the owner may write (SWMR)
        cell = self.cells.setdefault((owner, reg, sub), _Cell())
        cell.write(blob, self.sim.now, self.write_duration_us)
        self.send(src, "REG_WRITE_ACK", (reg, sub, token))

    def _on_read(self, src: str, body: Any) -> None:
        if not self.serving:
            return  # replacement node: no READs before re-replication
        owner, reg, token = body
        if self.stale_serve:
            # adversarial mode: answer from the frozen snapshot — complete,
            # checksum-valid blobs whose timestamps have fallen behind
            blobs = tuple(self._stale_cells.get((owner, reg, sub), b"")
                          for sub in (0, 1))
        else:
            blobs = tuple(
                self.cells.setdefault((owner, reg, sub), _Cell()).read(self.sim.now)
                for sub in (0, 1)
            )
        self.send(src, "REG_READ_ACK", (owner, reg, token, blobs))

    def set_stale_serve(self, on: bool = True) -> None:
        """Toggle the stale-serve adversary.  On enable, the node freezes
        its current committed blobs and serves those for every subsequent
        READ (it keeps *applying and acking* WRITEs, so its stored state
        stays fresh — only what it serves is stale).  This is strictly
        outside the paper's crash-only TCB contract.  What the
        fault-schedule tests show: once a completed write has propagated
        to the other live members (the steady state — WRITEs go to every
        member, only the ack quorum is f_m+1), ≤ f_m such nodes cannot
        break regularity, because READs take the highest valid timestamp
        over f_m+1 responses and some fresh responder outbids the stale
        one.  The residual hole is the propagation race: a stale server
        still *acks* writes, so it can transiently be the only write-acker
        inside a read quorum whose other members have not yet applied the
        write — that schedule can return stale data, and it is exactly
        where the crash-only boundary of §3 sits (see ROADMAP: locating
        it with a negative test is queued work)."""
        if on and not self.stale_serve:
            self._stale_cells = {key: c.blob for key, c in self.cells.items()
                                 if c.blob}
        if not on:
            self._stale_cells = {}
        self.stale_serve = on

    # ---------------------------------------------- pool-management plane
    def _on_lease_ping(self, src: str, body: Any) -> None:
        if self.manager is not None and src != self.manager:
            return
        self.send(src, "LEASE_ACK", body)

    def _on_pool_pull(self, src: str, body: Any) -> None:
        """State transfer for reconfiguration: ship the committed blob of
        every cell to the pool manager (only complete blobs — ``cell.blob``
        holds the final value; tearing is a read-time artifact)."""
        if self.manager is not None and src != self.manager:
            return
        token = body
        cells = [((owner, reg, sub), c.blob)
                 for (owner, reg, sub), c in self.cells.items() if c.blob]
        self.send(src, "POOL_PULL_ACK", (token, cells))

    def _on_pool_push(self, src: str, body: Any) -> None:
        """Install re-replicated state (highest valid ts wins) and start
        serving READs."""
        if self.manager is not None and src != self.manager:
            return
        token, cells = body
        for key, blob in cells:
            key = tuple(key)
            new = _unpack(blob)
            if new is None:
                continue
            cur = _unpack(self.cells.get(key, _Cell()).blob)
            if cur is None or new[0] > cur[0]:
                cell = self.cells.setdefault(key, _Cell())
                cell.write(blob, self.sim.now, 0.0)
        self.serving = True
        self.send(src, "POOL_PUSH_ACK", token)

    def _on_pool_rekey(self, src: str, body: Any) -> None:
        """Re-key a replaced replica's register permission: install the
        merged cells under the new owner pid, revoke the old owner's write
        access, and drop its cells (the permission token moves — §6.1's
        RDMA access control, now epoch-aware)."""
        if self.manager is not None and src != self.manager:
            return
        token, old, new, cells = body
        self.revoked.add(old)
        for key, blob in cells:
            _owner, reg, sub = tuple(key)
            v = _unpack(blob)
            if v is None:
                continue
            cell = self.cells.setdefault((new, reg, sub), _Cell())
            cur = _unpack(cell.blob)
            if cur is None or v[0] > cur[0]:
                cell.write(blob, self.sim.now, 0.0)
        for key in [k for k in self.cells if k[0] == old]:
            del self.cells[key]
        self.send(src, "POOL_REKEY_ACK", token)

    def memory_bytes(self) -> int:
        """Occupied disaggregated memory: one RDMA buffer per sub-register.
        WRITEs overwrite it in place (which is why READs can tear) —
        ``_Cell.prev`` is torn-read modeling, not allocated memory."""
        return sum(len(c.blob) for c in self.cells.values())

    def memory_bytes_by_owner(self) -> Dict[str, int]:
        """Occupancy split by writing owner pid — the attribution unit for
        per-application Table 2 accounting on a shared substrate."""
        out: Dict[str, int] = {}
        for (owner, _reg, _sub), c in self.cells.items():
            if c.blob:
                out[owner] = out.get(owner, 0) + len(c.blob)
        return out


class _PoolManager(Node):
    """Lease + reconfiguration orchestrator for one :class:`MemoryPool`.

    Models the paper's assumption that disaggregated memory is provided by
    the infrastructure: the manager is a crash-free control-plane process
    (not on any data path) that grants leases and performs state transfer
    when a member is replaced.
    """

    handling_cost = 0.3

    def __init__(self, sim: Simulator, net: NetworkModel, registry,
                 pid: str, pool: "MemoryPool"):
        super().__init__(sim, net, registry, pid)
        self.pool = pool
        self._last_ack: Dict[str, float] = {}
        self._sync: Dict[int, dict] = {}
        self._tok = 0
        self._leasing = False
        self._lease_timer = None
        self.suspected: List[Tuple[float, str]] = []
        self._suspect_live: set = set()
        self.handle("LEASE_ACK", self._on_lease_ack)
        self.handle("POOL_PULL_ACK", self._on_pull_ack)
        self.handle("POOL_PUSH_ACK", self._on_push_ack)
        self.handle("POOL_REKEY_ACK", self._on_rekey_ack)

    # ------------------------------------------------------------- leases
    def start_leases(self) -> None:
        if self._leasing:
            return
        self._leasing = True
        for m in self.pool.members:
            self._last_ack[m] = self.sim.now
        # First tick immediately, then coalesced on the shared periodic
        # bucket: every pool with the same lease quantum rides ONE heap
        # event per tick instead of one timer chain per pool manager.
        self._tick()
        self._lease_timer = self.sim.periodic(self.pool.lease_us / 2,
                                              self._tick)

    def stop_leases(self) -> None:
        self._leasing = False
        if self._lease_timer is not None:
            self._lease_timer.cancel()
            self._lease_timer = None

    def _tick(self) -> None:
        if self._leasing:
            now = self.sim.now
            for m in list(self.pool.members):
                self.send(m, "LEASE_PING", now)
                expiry = self._last_ack.setdefault(m, now) + self.pool.lease_us
                if now > expiry:
                    self._suspect(m)

    def _on_lease_ack(self, src: str, body: Any) -> None:
        self._last_ack[src] = self.sim.now
        self._suspect_live.discard(src)

    def _suspect(self, pid: str) -> None:
        if pid not in self._suspect_live:     # one suspicion per episode
            self._suspect_live.add(pid)
            self.suspected.append((self.sim.now, pid))
        if self.pool.auto_reconfigure:
            self.pool.reconfigure(pid)

    # ---------------------------------------------------- reconfiguration
    def begin_sync(self, dead: str, fresh: str, survivors: List[str],
                   on_done: Callable[[], None],
                   on_abort: Callable[[], None]) -> None:
        self._tok += 1
        tok = self._tok
        self._sync[tok] = {"kind": "sync", "resps": [], "fresh": fresh,
                           "dead": dead, "pushed": False, "cb": on_done,
                           "need": self.pool.f_m + 1}
        for s in survivors:
            self.send(s, "POOL_PULL", tok)
        # A sync that cannot gather f_m+1 pull acks (fault budget transiently
        # exceeded) must not wedge the pool: abort and let the caller retry.
        def expire() -> None:
            if self._sync.pop(tok, None) is not None:
                on_abort()

        self.timer(self.pool.sync_timeout_us, expire)

    def begin_rekey(self, old: str, new: str,
                    on_done: Callable[[Dict[str, int]], None],
                    on_abort: Optional[Callable[[], None]] = None) -> None:
        """Re-key register permissions ``old`` → ``new`` (replica
        replacement): the *same* pull/merge path as reconfiguration
        gathers the old owner's highest-valid-timestamp cells from f_m+1
        members, then every member installs them under the new owner and
        revokes the old one's write access (POOL_REKEY).  ``on_done``
        receives the per-register max write timestamps so the new owner's
        RegisterClient can adopt them (its next WRITE must supersede the
        inherited blobs).  A round that cannot complete within
        ``sync_timeout_us`` calls ``on_abort`` (the pool's
        :meth:`MemoryPool.rekey_owner` retries — a transiently degraded
        pool must not silently leave the old permission live)."""
        self._tok += 1
        tok = self._tok
        self._sync[tok] = {"kind": "rekey", "resps": [], "old": old,
                           "new": new, "pushed": False, "cb": on_done,
                           "need": self.pool.f_m + 1, "acks": 0,
                           "wts": {}}
        for s in self.pool.members:
            self.send(s, "POOL_PULL", tok)

        def expire() -> None:
            if self._sync.pop(tok, None) is not None and on_abort is not None:
                on_abort()

        self.timer(self.pool.sync_timeout_us, expire)

    def _on_pull_ack(self, src: str, body: Any) -> None:
        tok, cells = body
        st = self._sync.get(tok)
        if st is None or st["pushed"]:
            return
        st["resps"].append(cells)
        if len(st["resps"]) < st["need"]:
            return
        # merge: highest valid timestamp per (owner, reg, sub).  f_m+1
        # responses intersect every completed WRITE's f_m+1 ack quorum, so
        # the merge contains every acknowledged value.
        st["pushed"] = True
        merged: Dict[tuple, Tuple[int, bytes]] = {}
        for cells in st["resps"]:
            for key, blob in cells:
                key = tuple(key)
                v = _unpack(blob)
                if v is None:
                    continue
                if key not in merged or v[0] > merged[key][0]:
                    merged[key] = (v[0], blob)
        if st["kind"] == "rekey":
            old, new = st["old"], st["new"]
            keep = [(k, blob) for k, (_ts, blob) in merged.items()
                    if k[0] == old]
            wts: Dict[str, int] = {}
            for (owner, reg, _sub), (ts, _blob) in merged.items():
                if owner == old and ts > wts.get(reg, 0):
                    wts[reg] = ts
            st["wts"] = wts
            for m in self.pool.members:
                self.send(m, "POOL_REKEY", (tok, old, new, keep))
            return
        self.send(st["fresh"], "POOL_PUSH",
                  (tok, [(k, blob) for k, (_ts, blob) in merged.items()]))

    def _on_push_ack(self, src: str, body: Any) -> None:
        st = self._sync.pop(body, None)
        if st is not None:
            st["cb"]()

    def _on_rekey_ack(self, src: str, body: Any) -> None:
        st = self._sync.get(body)
        if st is None or st.get("kind") != "rekey":
            return
        st["acks"] += 1
        if st["acks"] >= st["need"]:
            del self._sync[body]
            st["cb"](st["wts"])


class MemoryPool:
    """A pool of 2f_m+1 crash-injectable disaggregated-memory nodes with
    lease-based reconfiguration (see module docstring).

    The pool object doubles as the *directory* clients consult for the
    current membership (``members`` / ``epoch``) — the sim-level stand-in
    for the provider's membership service.
    """

    def __init__(self, sim: Simulator, net: NetworkModel, registry,
                 f_m: int = 1, name: str = "pool0",
                 prefix: Optional[str] = None,
                 write_duration_us: float = 0.4,
                 lease_us: float = 200.0,
                 auto_reconfigure: bool = False,
                 sync_timeout_us: float = 2_000.0):
        self.sim = sim
        self.net = net
        self.registry = registry
        self.f_m = f_m
        self.name = name
        self.prefix = prefix if prefix is not None else f"{name}/m"
        self.write_duration_us = write_duration_us
        self.lease_us = lease_us
        self.auto_reconfigure = auto_reconfigure
        self.sync_timeout_us = sync_timeout_us
        self.epoch = 0
        self.nodes: Dict[str, MemoryNode] = {}
        self.members: List[str] = []
        self._next_id = 0
        self._reconfiguring = False
        #: (time, dead_pid, fresh_pid) per completed reconfiguration
        self.reconfigurations: List[Tuple[float, str, str]] = []
        #: (time, dead_pid, fresh_pid) per timed-out, rolled-back sync
        self.aborted_syncs: List[Tuple[float, str, str]] = []
        #: (time, old_owner, new_owner) per completed permission rekey
        self.rekeys: List[Tuple[float, str, str]] = []
        #: (time, old_owner, new_owner) per timed-out (retried) rekey round
        self.aborted_rekeys: List[Tuple[float, str, str]] = []
        self.manager = _PoolManager(sim, net, registry, f"{self.prefix}gr",
                                    self)
        for _ in range(2 * f_m + 1):
            self.members.append(self._spawn(serving=True).pid)
        if auto_reconfigure and lease_us > 0:
            self.manager.start_leases()

    def _spawn(self, serving: bool) -> MemoryNode:
        pid = f"{self.prefix}{self._next_id}"
        self._next_id += 1
        node = MemoryNode(self.sim, self.net, self.registry, pid,
                          write_duration_us=self.write_duration_us,
                          manager=self.manager.pid, serving=serving)
        self.nodes[pid] = node
        return node

    # ------------------------------------------------------ fault surface
    def crash_node(self, pid: str) -> None:
        self.nodes[pid].crash()

    def recover_node(self, pid: str) -> None:
        self.nodes[pid].recover()

    def crashed_members(self) -> List[str]:
        return [m for m in self.members if self.nodes[m].crashed]

    # ---------------------------------------------------- reconfiguration
    def reconfigure(self, dead: Optional[str] = None,
                    cb: Optional[Callable[[], None]] = None) -> bool:
        """Replace ``dead`` (default: first crashed member) with a fresh
        node: pull state from f_m+1 survivors, push the highest-timestamp
        merge to the fresh node, then swap it into the membership.  Returns
        False when there is nothing to do / a swap is already in flight.
        A sync that cannot complete within ``sync_timeout_us`` (e.g. the
        crash budget is transiently exceeded and f_m+1 survivors cannot
        answer) is aborted — the pool stays on the old membership and a
        later ``reconfigure`` (or the next lease tick) retries."""
        if self._reconfiguring:
            return False
        if dead is None:
            crashed = self.crashed_members()
            if not crashed:
                return False
            dead = crashed[0]
        if dead not in self.members:
            return False
        self._reconfiguring = True
        fresh = self._spawn(serving=False)
        survivors = [m for m in self.members if m != dead]

        def done() -> None:
            idx = self.members.index(dead)
            self.members[idx] = fresh.pid
            self.epoch += 1
            self._reconfiguring = False
            self.reconfigurations.append((self.sim.now, dead, fresh.pid))
            if cb is not None:
                cb()

        def abort() -> None:
            # discard the never-served replacement and unwedge the pool
            self.nodes.pop(fresh.pid, None)
            self.sim.processes.pop(fresh.pid, None)
            self._reconfiguring = False
            self.aborted_syncs.append((self.sim.now, dead, fresh.pid))

        self.manager.begin_sync(dead, fresh.pid, survivors, done, abort)
        return True

    def rekey_owner(self, old: str, new: str,
                    cb: Optional[Callable[[Dict[str, int]], None]] = None
                    ) -> None:
        """Move the register permission of owner ``old`` to ``new`` on
        every member (replica replacement).  Reuses the reconfiguration
        pull/merge machinery; records the completed rekey and forwards the
        inherited per-register write timestamps to ``cb``.  A round that
        times out (pull quorum transiently unreachable) is recorded in
        ``aborted_rekeys`` and retried — the revocation must eventually
        land on every serving member, or a Byzantine replaced replica
        could keep writing."""

        def done(wts: Dict[str, int]) -> None:
            self.rekeys.append((self.sim.now, old, new))
            if cb is not None:
                cb(wts)

        def aborted() -> None:
            self.aborted_rekeys.append((self.sim.now, old, new))
            self.manager.timer(self.sync_timeout_us / 2, retry)

        def retry() -> None:
            if not any(o == old and n == new
                       for (_t, o, n) in self.rekeys):
                self.manager.begin_rekey(old, new, done, aborted)

        self.manager.begin_rekey(old, new, done, aborted)

    # --------------------------------------------------------- accounting
    def member_nodes(self) -> List[MemoryNode]:
        return [self.nodes[m] for m in self.members]

    def memory_bytes(self) -> int:
        """Occupancy of the pool's *current* members (Table 2: must stay
        under 1 MiB per pool)."""
        return sum(n.memory_bytes() for n in self.member_nodes())

    def memory_bytes_by_owner(self) -> Dict[str, int]:
        """Occupancy of the current members split by owner pid; the
        substrate rolls this up into per-application accounting."""
        out: Dict[str, int] = {}
        for n in self.member_nodes():
            for owner, nbytes in n.memory_bytes_by_owner().items():
                out[owner] = out.get(owner, 0) + nbytes
        return out


@dataclass
class _StaticPool:
    """Legacy fixed-membership view: a bare pid list wrapped to look like a
    pool (no manager, no reconfiguration)."""
    members: List[str]
    name: str = "static"
    epoch: int = 0


class RegisterClient:
    """Reliable SWMR regular register operations for one node (§6.1).

    ``mem`` may be a bare list of memory-node pids (legacy static
    deployment), one :class:`MemoryPool`, or a list of pools — register
    keys are then sharded ``crc32(owner:reg) % n_pools``, or
    ``crc32(app:owner:reg)`` when the client carries an application
    ``namespace`` (many replicated applications over one substrate; the
    empty namespace is the legacy single-app layout, preserved
    bit-for-bit).  Membership is re-read from the pool directory at every
    operation, so reconfigurations are picked up without any client-side
    protocol change.
    """

    def __init__(self, node: Node, mem, f_m: int, slot_bytes: int = 128,
                 namespace: str = ""):
        self.node = node
        self.namespace = namespace
        self.pools = self._normalize(mem)
        for p in self.pools:
            assert len(p.members) >= 2 * f_m + 1
        self.quorum = f_m + 1
        self.slot_bytes = slot_bytes
        self._wts: Dict[str, int] = {}
        self._last_write: Dict[str, float] = {}
        self._pending: Dict[int, dict] = {}
        self._token = 0
        self.stats = {"read_attempts": 0, "read_retries": 0,
                      "reads_exhausted": 0}
        node.handle("REG_WRITE_ACK", self._on_write_ack)
        node.handle("REG_READ_ACK", self._on_read_ack)

    @staticmethod
    def _normalize(mem) -> List[Any]:
        if isinstance(mem, MemoryPool):
            return [mem]
        mem = list(mem)
        assert mem, "need at least one memory node / pool"
        if isinstance(mem[0], str):
            return [_StaticPool(members=mem)]
        return mem

    # ------------------------------------------------------------ routing
    @property
    def n_shards(self) -> int:
        return len(self.pools)

    def pool_for(self, owner: str, reg: str,
                 namespace: Optional[str] = None):
        """Stable shard routing of register keys across pools.  Namespaced
        clients hash ``app:owner:reg`` so each application's keys spread
        independently; the unnamed app hashes the legacy ``owner:reg``.
        ``namespace`` overrides the client's own namespace — a reader in
        one application following a register written under another's
        namespace (shard split/merge range transfer) must route with the
        *writer's* namespace or it consults the wrong pool."""
        if len(self.pools) == 1:
            return self.pools[0]
        ns = self.namespace if namespace is None else namespace
        key = f"{ns}:{owner}:{reg}" if ns else f"{owner}:{reg}"
        h = zlib.crc32(key.encode())
        return self.pools[h % len(self.pools)]

    @property
    def mem_nodes(self) -> List[str]:
        """Legacy single-pool view of the current membership."""
        return list(self.pools[0].members)

    def adopt_wts(self, wts: Dict[str, int]) -> None:
        """Adopt inherited per-register write timestamps (permission rekey
        during replica replacement): the new owner's next WRITE to an
        inherited register must carry a higher timestamp than any blob the
        pools re-keyed over, or readers would keep preferring the stale
        inherited value."""
        for reg, ts in wts.items():
            if ts > self._wts.get(reg, 0):
                self._wts[reg] = ts

    # ------------------------------------------------------------- WRITE
    def write(self, reg: str, value: bytes, cb: Callable[[], None]) -> None:
        """WRITE my register ``reg`` (owner = this node).  Completes at a
        majority of the owning pool's memory nodes.  Enforces the δ cooldown
        between WRITEs to the same register (§6.1) so readers can always
        find a complete sub-register."""
        now = self.node.sim.now
        delta = self.node.netp.delta_us
        earliest = self._last_write.get(reg, -delta) + delta
        if now < earliest:
            self.node.timer(earliest - now, lambda: self.write(reg, value, cb))
            return
        self._last_write[reg] = now
        if self.node.sim.tracing:
            t0 = now
            inner_cb = cb
            def cb():
                self.node.sim.trace.append(("smwr", t0, self.node.sim.now))
                inner_cb()
        ts = self._wts.get(reg, 0) + 1
        self._wts[reg] = ts
        blob = _pack(ts, value)
        sub = ts % 2  # round-robin double buffering
        self._token += 1
        tok = self._token
        self._pending[tok] = {"kind": "w", "acks": 0, "cb": cb, "done": False}
        body = (self.node.pid, reg, sub, blob, tok)
        size = crypto.wire_size_shallow(body) + 25  # len("REG_WRITE") + 16
        self.node.send_fanout(self.pool_for(self.node.pid, reg).members,
                              "REG_WRITE", body, size=size)

    def _on_write_ack(self, src: str, body: Any) -> None:
        _reg, _sub, tok = body
        st = self._pending.get(tok)
        if st is None or st["kind"] != "w" or st["done"]:
            return
        st["acks"] += 1
        if st["acks"] >= self.quorum:
            st["done"] = True
            del self._pending[tok]
            st["cb"]()

    # -------------------------------------------------------------- READ
    def read(self, owner: str, reg: str,
             cb: Callable[[Optional[Tuple[int, bytes]], bool], None],
             namespace: Optional[str] = None) -> None:
        """READ ``owner``'s register.  cb(value, owner_is_byzantine) where
        value is (ts, bytes) or None (default value ⊥).  ``namespace``
        routes the read under another application's namespace (see
        :meth:`pool_for`)."""
        if self.node.sim.tracing:
            t0 = self.node.sim.now
            inner_cb = cb
            def cb(val, byz):
                self.node.sim.trace.append(("smwr", t0, self.node.sim.now))
                inner_cb(val, byz)
        self._start_read(owner, reg, cb, attempt=1, namespace=namespace)

    def _start_read(self, owner: str, reg: str, cb, attempt: int,
                    namespace: Optional[str] = None) -> None:
        self.stats["read_attempts"] += 1
        self._token += 1
        tok = self._token
        self._pending[tok] = {
            "kind": "r", "resps": [], "cb": cb, "done": False,
            "start": self.node.sim.now, "owner": owner, "reg": reg,
            "attempt": attempt, "ns": namespace,
        }
        body = (owner, reg, tok)
        size = crypto.wire_size_shallow(body) + 24  # len("REG_READ") + 16
        self.node.send_fanout(self.pool_for(owner, reg, namespace).members,
                              "REG_READ", body, size=size)

    def _on_read_ack(self, src: str, body: Any) -> None:
        owner, reg, tok, blobs = body
        st = self._pending.get(tok)
        if st is None or st["kind"] != "r" or st["done"]:
            return
        st["resps"].append(blobs)
        if len(st["resps"]) < self.quorum:
            return
        st["done"] = True
        del self._pending[tok]
        self._conclude_read(st)

    def _conclude_read(self, st: dict) -> None:
        took = self.node.sim.now - st["start"]
        delta = self.node.netp.delta_us
        best: Optional[Tuple[int, bytes]] = None
        byz = False
        resps = st["resps"]
        # one checksum batch for the whole quorum (2 sub-registers × q acks)
        flat = _unpack_batch([b for blobs in resps for b in blobs])
        pos = 0
        for blobs in resps:
            vals = flat[pos:pos + len(blobs)]
            pos += len(blobs)
            ok = [v for v in vals if v is not None]
            if len(ok) == 2 and ok[0][0] == ok[1][0]:
                byz = True  # both sub-registers with the same timestamp
            if (not ok and took < delta
                    and all(len(b) >= BLOB_HEADER for b in blobs)):
                # Both sub-registers carry data yet neither validates within
                # δ — an honest writer can tear at most one sub-register per
                # δ window, so the owner is Byzantine.  (An *empty* second
                # sub-register means a READ overlapping the first-ever
                # WRITE: regularity allows ⊥, no verdict.)
                byz = True
            for v in ok:
                if best is None or v[0] > best[0]:
                    best = v
        if best is None and not byz:
            blank = all(not b for blobs in st["resps"] for b in blobs)
            if took >= delta and not blank:
                # inconclusive slow read — retry, capped end-to-end (§6.1)
                if st["attempt"] < MAX_READ_ATTEMPTS:
                    self.stats["read_retries"] += 1
                    self._start_read(st["owner"], st["reg"], st["cb"],
                                     st["attempt"] + 1,
                                     namespace=st.get("ns"))
                else:
                    self.stats["reads_exhausted"] += 1
                    st["cb"](None, False)
                return
        st["cb"](best, byz)

    # --------------------------------------------------------- accounting
    def disaggregated_bytes_per_register(self) -> int:
        """Table 2 model: 2 sub-registers × (checksum 8 + header 12 + value)."""
        return 2 * (8 + 12 + self.slot_bytes)
