import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run driver (deliverable e).

For every (architecture × input shape × mesh) cell:
  jax.jit(step, in_shardings=…).lower(**input_specs).compile()
must succeed on the 16×16 single-pod mesh AND the 2×16×16 multi-pod mesh.
Records memory_analysis / cost_analysis / collective schedule per cell into
artifacts/dryrun/*.json, with while-trip-count-corrected FLOPs/bytes/
collectives (see repro.launch.costing).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.launch import costing
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import (SHAPES, cell_runnable, input_specs,
                                 opt_spec, params_spec)
from repro.models.common import ModelConfig
from repro.models.scan_utils import cost_mode
from repro.models.transformer import (ShardCtx, apply_layer_decode,
                                      apply_layer_prefill, apply_layer_train)
from repro.optim.adamw import opt_pspecs
from repro.parallel.sharding import (batch_pspecs, cache_pspecs, named,
                                     param_pspecs, shard_ctx_for_mesh)
from repro.runtime.steps import make_prefill, make_serve_step, make_train_step

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")

#: sequence lengths for the sLSTM linear-cost fit (see costing docstring);
#: small because cost-mode unrolls S time steps per layer before the vjp
SLSTM_FIT_S = (32, 64)


def _named(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Standalone group-body compiles (trip-count correction)
# ---------------------------------------------------------------------------
def _group_param_shapes(p_shapes, gi: int):
    stacked = p_shapes["groups"][gi]
    return jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype),
                        stacked)


def _group_param_specs(p_specs, gi: int):
    stacked = p_specs["groups"][gi]
    return jax.tree.map(lambda s: P(*tuple(s)[1:]), stacked,
                        is_leaf=lambda x: isinstance(x, P))


def _body_cost(cfg: ModelConfig, ctx, mesh, kind: str, gi: int,
               p_shapes, p_specs, B: int, S: int, cache_shapes=None,
               cache_specs=None, exact: bool = False) -> costing.Cost:
    """Compile one group body standalone; return its Cost."""
    pattern, reps = cfg.blocks[gi]
    gp_shapes = _group_param_shapes(p_shapes, gi)
    gp_specs = _group_param_specs(p_specs, gi)
    dp = ctx.dp_axes
    import numpy as _np
    dp_size = int(_np.prod([mesh.shape[a] for a in dp]))
    if B % dp_size != 0:
        dp = ()   # tiny batch (long_500k): replicate over the dp axes
    dt = cfg.jdtype()
    x_sds = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
    pos_sds = jax.ShapeDtypeStruct((B, S), jnp.int32)
    x_spec = P(dp, None, None) if dp else P(None, None, None)
    pos_spec = P(dp, None) if dp else P(None, None)

    if kind == "train":
        def fwd(x, positions, gp):
            for spec, p in zip(pattern, gp):
                x = apply_layer_train(cfg, spec, p, x, positions, ctx)
            return x

        # match the main program's remat policy so the bwd recompute FLOPs
        # are reflected in the corrected cost
        from repro.models.transformer import _remat
        fwd_r = _remat(cfg, lambda x, gp, positions: fwd(x, positions, gp))

        def body(x, positions, gp, ct):
            y, vjp = jax.vjp(lambda xx, pp: fwd_r(xx, pp, positions), x, gp)
            dx, dgp = vjp(ct)
            return y, dx, dgp

        args = (x_sds, pos_sds, gp_shapes, x_sds)
        shardings = (NamedSharding(mesh, x_spec), NamedSharding(mesh, pos_spec),
                     _named(mesh, gp_specs), NamedSharding(mesh, x_spec))
        fn = body
    elif kind == "prefill":
        def fn(x, positions, gp):
            outs = []
            for spec, p in zip(pattern, gp):
                x, st = apply_layer_prefill(cfg, spec, p, x, positions, S, ctx)
                outs.append(st)
            return x, tuple(outs)

        args = (x_sds, pos_sds, gp_shapes)
        shardings = (NamedSharding(mesh, x_spec), NamedSharding(mesh, pos_spec),
                     _named(mesh, gp_specs))
    else:  # decode
        def fn(x, gp, gc, position):
            outs = []
            for spec, p, c in zip(pattern, gp, gc):
                x, nc = apply_layer_decode(cfg, spec, p, x, c, position, ctx)
                outs.append(nc)
            return x, tuple(outs)

        gc_shapes = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype),
            cache_shapes[gi])
        gc_specs = jax.tree.map(lambda s: P(*tuple(s)[1:]), cache_specs[gi],
                                is_leaf=lambda x: isinstance(x, P))
        args = (jax.ShapeDtypeStruct((B, 1, cfg.d_model), dt), gp_shapes,
                gc_shapes, jax.ShapeDtypeStruct((), jnp.int32))
        xd_spec = P(dp, None, None) if dp else P(None, None, None)
        shardings = (NamedSharding(mesh, xd_spec),
                     _named(mesh, gp_specs), _named(mesh, gc_specs),
                     NamedSharding(mesh, P()))

    def compile_once() -> costing.Cost:
        lowered = jax.jit(fn, in_shardings=shardings).lower(*args)
        return costing.cost_of_compiled(lowered.compile())

    if exact:
        with cost_mode():
            return compile_once()
    return compile_once()


def _has_slstm(cfg: ModelConfig, gi: int) -> bool:
    return any(s.kind == "slstm" for s in cfg.blocks[gi][0])


def corrected_cost(cfg: ModelConfig, ctx, mesh, kind: str, main_cost,
                   p_shapes, p_specs, B: int, S: int,
                   cache_shapes=None, cache_specs=None) -> costing.Cost:
    total = main_cost
    for gi, (pattern, reps) in enumerate(cfg.blocks):
        scan_cost = _body_cost(cfg, ctx, mesh, kind, gi, p_shapes, p_specs,
                               B, S, cache_shapes, cache_specs, exact=False)
        if kind == "decode":
            exact = scan_cost       # no inner loops in decode bodies
        elif _has_slstm(cfg, gi) and S > SLSTM_FIT_S[1]:
            s1, s2 = SLSTM_FIT_S
            c1 = _body_cost(cfg, ctx, mesh, kind, gi, p_shapes, p_specs,
                            B, s1, exact=True)
            c2 = _body_cost(cfg, ctx, mesh, kind, gi, p_shapes, p_specs,
                            B, s2, exact=True)
            slope = (c2 + c1.scale(-1.0)).scale(1.0 / (s2 - s1))
            exact = c1 + slope.scale(float(S - s1))
        else:
            exact = _body_cost(cfg, ctx, mesh, kind, gi, p_shapes, p_specs,
                               B, S, cache_shapes, cache_specs, exact=True)
        total = total + scan_cost.scale(-1.0) + exact.scale(float(reps))
    return total


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------
def run_cell(arch: str, shape_name: str, multi_pod: bool,
             correct: bool = True, cfg: Optional[ModelConfig] = None,
             save: bool = True) -> Dict[str, Any]:
    shape = SHAPES[shape_name]
    ok, why = cell_runnable(arch, shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    out: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "skipped",
                           "reason": why}
    if not ok:
        return _save(out) if save else out

    cfg = cfg or get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = shard_ctx_for_mesh(mesh)
    p_shapes = params_spec(cfg)
    p_specs = param_pspecs(cfg, p_shapes, mesh)
    t0 = time.time()

    cache_shapes = cache_specs = None
    if shape.kind == "train":
        o_shapes = opt_spec(cfg, p_shapes)
        o_specs = opt_pspecs(p_specs)
        b = input_specs(cfg, shape)
        b_specs = batch_pspecs(cfg, mesh)
        step = make_train_step(cfg, ctx)
        jitted = jax.jit(step,
                         in_shardings=(_named(mesh, p_specs),
                                       _named(mesh, o_specs),
                                       _named(mesh, b_specs)),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(p_shapes, o_shapes, b)
    elif shape.kind == "prefill":
        b = input_specs(cfg, shape)
        step = make_prefill(cfg, ctx, max_seq=shape.seq)
        dp = ctx.dp_axes
        jitted = jax.jit(step, in_shardings=(
            _named(mesh, p_specs), NamedSharding(mesh, P(dp))))
        lowered = jitted.lower(p_shapes, b["inputs"])
    else:  # decode
        specs = input_specs(cfg, shape)
        cache_shapes = specs["caches"]
        cache_specs = cache_pspecs(cfg, cache_shapes, mesh)
        dp = ctx.dp_axes
        import numpy as _np
        dp_size = int(_np.prod([mesh.shape[a] for a in dp]))
        tok_spec = P(dp) if shape.batch % dp_size == 0 else P(None)
        step = make_serve_step(cfg, ctx)
        jitted = jax.jit(step, in_shardings=(
            _named(mesh, p_specs), _named(mesh, cache_specs),
            NamedSharding(mesh, tok_spec), NamedSharding(mesh, P())),
            donate_argnums=(1,))
        lowered = jitted.lower(p_shapes, cache_shapes, specs["tokens"],
                               specs["position"])
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = costing.memory_of_compiled(compiled)
    raw = costing.cost_of_compiled(compiled)
    out.update({
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": mem,
        "raw": {"flops": raw.flops, "bytes": raw.bytes_accessed,
                "collectives": raw.coll},
    })
    print(compiled.memory_analysis())

    if correct:
        t0 = time.time()
        corr = corrected_cost(cfg, ctx, mesh, shape.kind, raw, p_shapes,
                              p_specs, shape.batch, shape.seq,
                              cache_shapes, cache_specs)
        out["corrected"] = {"flops": corr.flops, "bytes": corr.bytes_accessed,
                            "collectives": corr.coll}
        out["correct_s"] = round(time.time() - t0, 1)
    return _save(out) if save else out


def _save(out: Dict[str, Any]) -> Dict[str, Any]:
    os.makedirs(ARTIFACTS, exist_ok=True)
    fname = f"{out['arch']}__{out['shape']}__{out['mesh']}.json"
    with open(os.path.join(ARTIFACTS, fname), "w") as f:
        json.dump(out, f, indent=1)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-correct", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                fname = os.path.join(ARTIFACTS,
                                     f"{arch}__{shape}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(fname):
                    prev = json.load(open(fname))
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[skip] {arch} {shape} {mesh_name} (cached)")
                        continue
                tag = f"{arch} {shape} {mesh_name}"
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    r = run_cell(arch, shape, mp,
                                 correct=not args.no_correct)
                    print(f"[done] {tag}: {r['status']} "
                          f"compile={r.get('compile_s')}s", flush=True)
                    results.append(r)
                except Exception as e:
                    traceback.print_exc()
                    _save({"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": str(e)[:2000]})
                    print(f"[FAIL] {tag}: {e}", flush=True)
    ok = sum(1 for r in results if r["status"] == "ok")
    print(f"\n{ok}/{len(results)} cells ok")


if __name__ == "__main__":
    main()
