"""Serving launcher: a uBFT-replicated token server (deliverable b's
end-to-end driver — the paper's kind is SMR/serving).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \\
      --requests 20 --batch 4

Three replicas hold the same model; client requests are ordered through
uBFT consensus; the client accepts f+1 matching token streams, so a
Byzantine replica cannot forge a generation.  Prints per-request latency:
replication overhead is microseconds on top of model time.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models.common import init_params
from repro.models.transformer import decode_step, prefill
from repro.runtime.server import ReplicatedServer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4, help="client sessions")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    max_seq = args.prompt_len + args.gen * args.requests + 8

    pf = jax.jit(lambda p, i: prefill(cfg, p, i, max_seq=max_seq))
    ds = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))

    def decode_fn(session: str, hist, n: int):
        """Deterministic greedy decode of n tokens after `hist`."""
        toks = jnp.asarray([hist], jnp.int32)
        logits, caches = pf(params, toks)
        out = []
        pos = len(hist)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(n):
            out.append(int(tok[0]))
            logits, caches = ds(params, caches, tok, jnp.int32(pos + i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return out

    server = ReplicatedServer.build(decode_fn)
    clients = [server.cluster.new_client() for _ in range(args.batch)]
    rng = np.random.default_rng(0)
    lats = []
    t0 = time.time()
    for r in range(args.requests):
        cl = clients[r % len(clients)]
        prompt = rng.integers(0, cfg.vocab, size=args.prompt_len).tolist() \
            if r % len(clients) == r // len(clients) == 0 or True else []
        toks, lat = server.generate(cl, f"s{r % len(clients)}",
                                    prompt if r < len(clients) else [],
                                    args.gen)
        lats.append(lat)
        print(f"[req {r}] session=s{r % len(clients)} tokens={toks} "
              f"smr_latency={lat:.1f}us")
    lats = sorted(lats)
    print(f"\n{args.requests} requests, {args.batch} sessions | "
          f"SMR-ordering latency p50={lats[len(lats)//2]:.1f}us "
          f"p90={lats[int(len(lats)*0.9)]:.1f}us | wall={time.time()-t0:.1f}s")
    # all replicas hold identical session state (BFT guarantee)
    snaps = [r.app.snapshot() for r in server.cluster.replicas]
    assert snaps[0] == snaps[1] == snaps[2]
    print("replica state identical across 2f+1 replicas: OK")


if __name__ == "__main__":
    main()
