"""Training launcher: uBFT-coordinated, checkpoint/restart fault tolerance.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \\
      --steps 50 --ckpt-dir /tmp/ckpt [--resume] [--byzantine 2]

Runs 2f+1 replicated trainers on the in-process harness: every step id and
data range is agreed through uBFT consensus, gradients/params are
fingerprint-attested (a Byzantine replica is flagged), and checkpoint cuts
are consensus-ordered before being written.  ``--resume`` restarts from the
latest attested checkpoint — kill the process mid-run and relaunch to see
fault tolerance end-to-end.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_config, get_smoke_config
from repro.data import DataConfig, TokenPipeline
from repro.models.common import init_params, params_count
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.runtime.steps import make_train_step
from repro.runtime.trainer import ReplicatedTrainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--byzantine", type=int, default=None,
                    help="index of a replica to corrupt (demo detection)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                    global_batch=args.batch))
    opt_cfg = AdamWConfig(lr=args.lr)

    start_step = 0
    if args.resume:
        try:
            start_step, params0, opt0 = load_checkpoint(args.ckpt_dir)
            print(f"[resume] from attested checkpoint @ step {start_step}")
        except FileNotFoundError:
            params0 = init_params(cfg, jax.random.PRNGKey(0))
            opt0 = adamw_init(params0, opt_cfg)
    else:
        params0 = init_params(cfg, jax.random.PRNGKey(0))
        opt0 = adamw_init(params0, opt_cfg)

    # three independent training replicas (each its own copy of the state)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg=opt_cfg))
    replicas = [{"params": params0, "opt": opt0} for _ in range(3)]
    losses = []

    def train_one(idx: int, step: int, data_epoch: int):
        b = pipe.global_batch(start_step + step)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        r = replicas[idx]
        r["params"], r["opt"], m = step_fn(r["params"], r["opt"], batch)
        if idx == 0:
            losses.append(float(m["loss"]))
        return int(m["grad_fp"]), int(m["param_fp"]), {"loss": float(m["loss"])}

    rt = ReplicatedTrainer.build(train_one)
    t0 = time.time()
    done = 0
    while done < args.steps:
        n = min(args.ckpt_every, args.steps - done)
        recs = rt.run_steps(n, byzantine_replica=args.byzantine)
        done += n
        step = start_step + done
        fp = save_checkpoint(args.ckpt_dir, step,
                             replicas[0]["params"], replicas[0]["opt"])
        rt.agree_checkpoint(step, fp)
        flagged = recs[-1]["flagged"]
        print(f"[step {step}] loss={losses[-1]:.4f} "
              f"ckpt_fp={fp} flagged={flagged} "
              f"({(time.time() - t0) / done:.2f}s/step)")
    print(f"params={params_count(replicas[0]['params'])} "
          f"final_loss={losses[-1]:.4f} "
          f"coordinator_checkpoints={rt.coordinator_state.checkpoints}")


if __name__ == "__main__":
    main()
