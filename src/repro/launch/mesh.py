"""Production mesh definition.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16×16 = 256 chips (data × model).
Multi-pod: 2×16×16 = 512 chips with a leading "pod" axis — the pod axis is
pure data parallelism whose gradient all-reduce crosses the (slow) inter-pod
links; the dry-run proves it shards.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for CPU smoke tests."""
    return jax.make_mesh((1, 1), ("data", "model"))
