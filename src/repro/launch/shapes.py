"""Assigned input shapes and ShapeDtypeStruct factories (no allocation).

Four shapes per LM architecture (40 cells total):
    train_4k     seq 4096,   global batch 256   → train_step
    prefill_32k  seq 32768,  global batch 32    → prefill
    decode_32k   KV 32768,   global batch 128   → serve_step
    long_500k    KV 524288,  global batch 1     → serve_step (sub-quadratic
                 archs only; pure full-attention archs are skipped per the
                 assignment — see DESIGN.md §6)

``input_specs`` returns weak-type-correct, shardable ShapeDtypeStructs for
every model input; modality frontends ([audio]/[vlm]) get precomputed
frame/patch embeddings instead of token ids.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import LONG_CONTEXT_OK
from repro.models.common import ModelConfig
from repro.models.transformer import init_caches


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def cell_runnable(arch: str, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
        return False, "pure full-attention arch: long_500k skipped (DESIGN §6)"
    return True, ""


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStructs for the step function's *data* arguments."""
    B, S = shape.batch, shape.seq
    if shape.kind == "train":
        if cfg.frontend:
            inputs = sds((B, S, cfg.d_model), cfg.dtype)
        else:
            inputs = sds((B, S), jnp.int32)
        return {"inputs": inputs, "targets": sds((B, S), jnp.int32)}
    if shape.kind == "prefill":
        if cfg.frontend:
            return {"inputs": sds((B, S, cfg.d_model), cfg.dtype)}
        return {"inputs": sds((B, S), jnp.int32)}
    if shape.kind == "decode":
        caches = jax.eval_shape(lambda: init_caches(cfg, B, S))
        return {
            "caches": caches,
            "tokens": sds((B,), jnp.int32),
            "position": sds((), jnp.int32),
        }
    raise ValueError(shape.kind)


def params_spec(cfg: ModelConfig) -> Any:
    """ShapeDtypeStructs for the parameter tree (no allocation)."""
    from repro.models.common import init_params
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def opt_spec(cfg: ModelConfig, params_shapes: Any) -> Any:
    from repro.optim.adamw import AdamWConfig, adamw_init
    return jax.eval_shape(lambda p: adamw_init(p, AdamWConfig()), params_shapes)
