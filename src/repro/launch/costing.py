"""Cost extraction from compiled artifacts, with while-trip-count correction.

``compiled.cost_analysis()`` counts a while-loop body ONCE (verified
empirically).  The step programs contain two nesting levels of statically
known trip counts: layer-group scans (reps) and inner chunk/time scans
(attention q-chunks, mLSTM chunks, sLSTM time steps).  Correction:

    total = main − Σ_g body_scan_g + Σ_g reps_g · body_exact_g

where ``body_scan_g`` is the group body compiled standalone in run mode
(what main counted once) and ``body_exact_g`` is the body compiled in cost
mode (inner loops unrolled → exact).  Groups whose cost is linear in S but
whose unroll would be enormous (sLSTM: S time steps) are compiled at two
reduced sequence lengths and extrapolated linearly (exact for linear costs).

Collective bytes are parsed from the optimized (post-SPMD) HLO text: the
summed operand bytes of all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute instructions, with the same correction.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g. f32[8,128,4096]{2,1,0} or bf16[] — dtype + dims
_SHAPE_RE = re.compile(r"\b(pred|[sub]\d+|bf16|f16|f32|f64)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(1, len(m.group(1).split(",")))
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Collective traffic from post-SPMD HLO (per device).

    For each instruction, the *result* shape is parsed (operands are not
    type-annotated in optimized HLO text) and converted to (a) operand bytes
    per the assignment's definition and (b) ring-model link bytes:
        all-reduce       op=R         link=2·R·(G-1)/G
        all-gather       op=R/G       link=R·(G-1)/G
        reduce-scatter   op=R·G       link=R·(G-1)
        all-to-all       op=R         link=R·(G-1)/G
        collective-permute op=R       link=R
    """
    out: Dict[str, float] = {}
    for k in _COLLECTIVES:
        out[k] = 0.0
        out[k + "_link"] = 0.0
        out[k + "_count"] = 0.0
    op_re = re.compile(r"=\s*(.*?)\s*(all-reduce|all-gather|reduce-scatter|"
                       r"all-to-all|collective-permute)(-start)?\(")
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = op_re.search(stripped)
        if m is None or "-done(" in stripped:
            continue
        kind = m.group(2)
        shapes = _SHAPE_RE.findall(m.group(1))   # result type(s), left of op
        R = float(sum(_shape_bytes(d, s) for d, s in shapes))
        G = _group_size(stripped)
        if kind == "all-reduce":
            op, link = R, 2.0 * R * (G - 1) / max(G, 1)
        elif kind == "all-gather":
            op, link = R / G, R * (G - 1) / max(G, 1)
        elif kind == "reduce-scatter":
            op, link = R * G, R * (G - 1)
        elif kind == "all-to-all":
            op, link = R, R * (G - 1) / max(G, 1)
        else:  # collective-permute
            op, link = R, R
        out[kind] += op
        out[kind + "_link"] += link
        out[kind + "_count"] += 1
    out["total"] = float(sum(out[k] for k in _COLLECTIVES))
    out["total_link"] = float(sum(out[k + "_link"] for k in _COLLECTIVES))
    return out


@dataclass
class Cost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)

    def __add__(self, o: "Cost") -> "Cost":
        keys = set(self.coll) | set(o.coll)
        return Cost(self.flops + o.flops,
                    self.bytes_accessed + o.bytes_accessed,
                    {k: self.coll.get(k, 0.0) + o.coll.get(k, 0.0)
                     for k in keys})

    def scale(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes_accessed * f,
                    {k: v * f for k, v in self.coll.items()})


def cost_of_compiled(compiled) -> Cost:
    ca = compiled.cost_analysis() or {}
    try:
        text = compiled.as_text()
    except Exception:
        text = ""
    return Cost(float(ca.get("flops", 0.0)),
                float(ca.get("bytes accessed", 0.0)),
                collective_bytes(text))


def memory_of_compiled(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        out[k] = float(getattr(ma, k, 0.0))
    out["total_hbm_bytes"] = (out["argument_size_in_bytes"] +
                              out["output_size_in_bytes"] +
                              out["temp_size_in_bytes"] -
                              out.get("alias_size_in_bytes", 0.0))
    return out
