"""Replicated financial order matching (the paper's Liquibook workload):
50/50 BUY/SELL limit orders against a price-time-priority book, replicated
across 3 replicas with ~10 µs of added latency.

    PYTHONPATH=src python examples/matching_engine.py
"""

import os
import struct
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.apps.matching import MatchingEngineApp, order_req
from repro.core.smr import Cluster
from repro.core.substrate import Substrate


def main() -> None:
    substrate = Substrate()
    cluster = Cluster.attach(substrate, MatchingEngineApp, name="book")
    client = cluster.new_client()
    rng = np.random.default_rng(1)
    lats, fills_total = [], 0
    for i in range(200):
        side = "buy" if rng.random() < 0.5 else "sell"
        price = int(100 + rng.integers(-5, 6))
        r, lat = cluster.run_request(client, order_req(side, i, price, 10))
        nfills = struct.unpack_from("<Q", r, 0)[0]
        fills_total += nfills
        lats.append(lat)
    lats.sort()
    print(f"200 orders | fills={fills_total} | "
          f"latency p50={lats[100]:.1f}us p90={lats[180]:.1f}us "
          f"p99={lats[198]:.1f}us")
    books = [(len(r.app.bids), len(r.app.asks), r.app.fills)
             for r in cluster.replicas]
    assert books[0] == books[1] == books[2]
    print(f"book state identical across replicas: bids={books[0][0]} "
          f"asks={books[0][1]} fills={books[0][2]}")


if __name__ == "__main__":
    main()
