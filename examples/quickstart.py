"""Quickstart: Byzantine fault tolerance in microseconds.

Replicates a key-value store across 2f+1 = 3 replicas with uBFT, shows the
~10 µs fast path, then crashes the leader and shows the system recover via
a view change — all on the discrete-event simulator with a calibrated
RDMA-class network model.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.apps.kvstore import KVStoreApp, get_req, set_req
from repro.core.consensus import ConsensusConfig
from repro.core.smr import Cluster
from repro.core.substrate import Substrate


def main() -> None:
    # one disaggregated-memory substrate; the replicated KV store is one
    # application attached to it (more apps could share the same pools)
    substrate = Substrate()
    cluster = Cluster.attach(substrate, KVStoreApp, name="kv",
                             cfg=ConsensusConfig(view_timeout_us=2000.0))
    client = cluster.new_client()

    print("== fast path (no failures) ==")
    for i in range(5):
        r, lat = cluster.run_request(client, set_req(b"key%d" % i, b"v%d" % i))
        print(f"  SET key{i} -> {r.decode()}  ({lat:.1f} us end-to-end)")
    r, lat = cluster.run_request(client, get_req(b"key3"))
    print(f"  GET key3 -> {r.decode()}  ({lat:.1f} us)")

    print("\n== leader crash -> view change -> continue ==")
    cluster.replicas[0].crash()
    r, lat = cluster.run_request(client, set_req(b"after", b"crash"),
                                 timeout=60_000_000)
    views = [rep.view for rep in cluster.replicas[1:]]
    print(f"  SET after -> {r.decode()}  ({lat:.1f} us, views now {views})")
    r, lat = cluster.run_request(client, get_req(b"key3"), timeout=60_000_000)
    print(f"  GET key3 -> {r.decode()}  (state preserved across the change)")

    stores = [rep.app.store for rep in cluster.replicas[1:]]
    assert stores[0] == stores[1]
    print("\nreplica states identical; total simulated time:",
          f"{cluster.sim.now / 1000:.1f} ms")


if __name__ == "__main__":
    main()
