"""Two replicated applications, one disaggregated-memory substrate.

The paper's deployment story (§8): uBFT's TCB is a small amount of
reliable disaggregated memory *shared by many replicated applications*.
Here a replicated KV store and a replicated matching engine attach to the
same substrate — one event loop, one network, one set of memory pools —
and run concurrent workloads (the KV store closed-loop, the matching
engine open-loop Poisson).  Afterwards we print each app's latency and its
own slice of the shared pools (Table 2, split per app).

    PYTHONPATH=src python examples/shared_substrate.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.apps.kvstore import KVStoreApp, set_req
from repro.apps.matching import MatchingEngineApp, order_req
from repro.core.consensus import ConsensusConfig
from repro.scenario import AppSpec, ScenarioSpec, Workload, run_scenario


def main() -> None:
    def kv_payload(i: int) -> bytes:
        return set_req(b"key%d" % (i % 16), b"value%d" % i)

    def order_payload(i: int) -> bytes:
        side = "buy" if i % 2 == 0 else "sell"
        return order_req(side, i, 100 + (i * 7) % 11 - 5, 10)

    slow = ConsensusConfig(t=16, window=16, slow_mode="always",
                           ctb_fast_enabled=False)
    spec = ScenarioSpec(
        n_pools=2,
        apps=[
            AppSpec(name="kv", app=KVStoreApp, cfg=slow,
                    workload=Workload(kind="closed", n_requests=40,
                                      payload_fn=kv_payload)),
            AppSpec(name="book", app=MatchingEngineApp, cfg=slow,
                    workload=Workload(kind="open", rate_rps=8000.0,
                                      duration_us=3000.0,
                                      payload_fn=order_payload, seed=7)),
        ])
    res = run_scenario(spec)

    for name in ("kv", "book"):
        ar = res.apps[name]
        lats = sorted(ar.latencies)
        kib = {p: f"{b / 1024:.1f}KiB" for p, b in ar.memory_by_pool.items()}
        print(f"{name:5}: {ar.completed} requests, "
              f"p50={lats[len(lats) // 2]:.1f}us, per-pool memory {kib}")
    assert not res.budget_overruns
    kv = res.clusters["kv"]
    assert all(r.app.store == kv.replicas[0].app.store for r in kv.replicas)
    print("per-app budgets respected; replica states identical; "
          f"total simulated time {res.substrate.sim.now / 1000:.1f} ms")


if __name__ == "__main__":
    main()
