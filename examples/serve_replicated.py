"""Serve a small model with batched requests behind uBFT (the paper's kind
of application: latency-critical serving made Byzantine-tolerant for ~10 µs
of SMR overhead).

    PYTHONPATH=src python examples/serve_replicated.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve as serve_mod


def main() -> None:
    sys.argv = ["serve", "--arch", "gemma3-1b", "--smoke",
                "--requests", "12", "--batch", "4", "--gen", "6"]
    serve_mod.main()


if __name__ == "__main__":
    main()
