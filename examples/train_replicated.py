"""End-to-end replicated training driver.

Trains a model for a few hundred steps with the uBFT-replicated coordinator:
step ids agreed through consensus, gradient/param fingerprints attested each
step (a corrupted replica is flagged), checkpoints consensus-ordered, and a
mid-run restart from the attested checkpoint.

Defaults are CPU-sized; on real hardware run e.g.:
    python -m repro.launch.train --arch qwen3-8b --steps 300 --batch 32

    PYTHONPATH=src python examples/train_replicated.py [--steps 120]
"""

import os
import shutil
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

from repro.launch import train as train_mod


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--arch", default="gemma3-1b")
    args = ap.parse_args()
    ckpt = "/tmp/repro_example_ckpt"
    shutil.rmtree(ckpt, ignore_errors=True)

    print("== phase 1: train with a Byzantine replica injected ==")
    sys.argv = ["train", "--arch", args.arch, "--smoke",
                "--steps", str(args.steps // 2), "--ckpt-dir", ckpt,
                "--ckpt-every", "20", "--byzantine", "2"]
    train_mod.main()

    print("\n== phase 2: simulate a crash; restart from the attested "
          "checkpoint and keep training ==")
    sys.argv = ["train", "--arch", args.arch, "--smoke",
                "--steps", str(args.steps - args.steps // 2),
                "--ckpt-dir", ckpt, "--ckpt-every", "20", "--resume"]
    train_mod.main()


if __name__ == "__main__":
    main()
