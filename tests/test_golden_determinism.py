"""Golden seeded-determinism tests (ISSUE 3's enforced invariant).

The digests in ``tests/golden/golden_traces.json`` were recorded on the
tree *before* the zero-re-encode wire layer / fast event engine landed.
Re-running each scenario must reproduce them bit-for-bit: every latency
(packed as raw float64), every message count, every byte count.  An engine
change that alters any simulated number fails here — "faster but
identical" is a test, not a hope.

If a change *intentionally* alters simulated results (e.g. a recalibrated
cost model), re-record with::

    PYTHONPATH=src python tests/golden_scenarios.py --record

and say so explicitly in the commit message.
"""

import json
import os

import pytest

from golden_scenarios import GOLDEN_PATH, SCENARIOS


def _golden():
    assert os.path.exists(GOLDEN_PATH), (
        "golden digests missing — record them with "
        "`PYTHONPATH=src python tests/golden_scenarios.py --record`")
    with open(GOLDEN_PATH) as f:
        return json.load(f)


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_golden_digest(name):
    golden = _golden()
    assert name in golden, f"scenario {name} has no recorded golden digest"
    got = SCENARIOS[name]()
    exp = golden[name]
    assert got["digest"] == exp["digest"], (
        f"seeded scenario {name!r} diverged from the pre-refactor golden "
        f"trace:\n  golden: {exp}\n  got:    {got}")


def test_goldens_cover_all_scenarios():
    """Adding a scenario without recording its digest should be loud."""
    golden = _golden()
    assert set(golden) == set(SCENARIOS)


def test_same_seed_same_run_twice():
    """Within one process the same seed reproduces itself exactly (the
    wire cache and jitter blocks carry no cross-run state)."""
    fn = SCENARIOS["throughput_mini"]
    assert fn()["digest"] == fn()["digest"]
