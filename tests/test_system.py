"""End-to-end system behaviour: replicated training with attestation,
Byzantine-replica detection, checkpoint/restart, replicated serving."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import DataConfig, TokenPipeline
from repro.models.common import init_params
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.runtime.steps import make_train_step
from repro.runtime.trainer import CoordinatorApp, ReplicatedTrainer


def _make_training_rig(arch="qwen3-8b", n=3, lr=1e-3):
    cfg = get_smoke_config(arch)
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=24,
                                    global_batch=4, seed=1))
    opt_cfg = AdamWConfig(lr=lr)
    params0 = init_params(cfg, jax.random.PRNGKey(0))
    opt0 = adamw_init(params0, opt_cfg)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg=opt_cfg))
    replicas = [{"params": params0, "opt": opt0} for _ in range(n)]

    def train_one(idx, step, data_epoch):
        b = pipe.global_batch(step)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        r = replicas[idx]
        r["params"], r["opt"], m = step_fn(r["params"], r["opt"], batch)
        return int(m["grad_fp"]), int(m["param_fp"]), {"loss": float(m["loss"])}

    return replicas, train_one


def test_replicated_training_steps_agree():
    replicas, train_one = _make_training_rig()
    rt = ReplicatedTrainer.build(train_one)
    recs = rt.run_steps(4)
    assert [r["step"] for r in recs] == [0, 1, 2, 3]
    for rec in recs:
        fps = set(rec["fps"].values())
        assert len(fps) == 1, "honest replicas must produce identical state"
        assert rec["flagged"] == []


def test_byzantine_training_replica_flagged():
    replicas, train_one = _make_training_rig()
    rt = ReplicatedTrainer.build(train_one)
    recs = rt.run_steps(3, byzantine_replica=1)
    assert "t1" in recs[-1]["flagged"]
    assert "t0" not in recs[-1]["flagged"]


def test_coordinator_survives_leader_crash():
    from repro.core.consensus import ConsensusConfig
    replicas, train_one = _make_training_rig()
    rt = ReplicatedTrainer.build(
        train_one, cfg=ConsensusConfig(view_timeout_us=2000.0))
    rt.run_steps(2)
    rt.cluster.replicas[0].crash()
    recs = rt.run_steps(2)
    assert [r["step"] for r in recs] == [2, 3]


def test_checkpoint_roundtrip_and_corruption_detection(tmp_path):
    from repro.checkpoint import load_checkpoint, save_checkpoint
    cfg = get_smoke_config("gemma3-1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params, AdamWConfig())
    fp = save_checkpoint(str(tmp_path), 7, params, opt)
    step, p2, o2 = load_checkpoint(str(tmp_path), expect_fp=fp)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # corrupt the file on disk — the fingerprint must catch it
    import pickle
    blob_path = tmp_path / "ckpt_7.pkl"
    state = pickle.loads(blob_path.read_bytes())
    leaves, treedef = jax.tree.flatten(state["params"])
    arr = np.array(leaves[0], copy=True)
    arr.flat[0] = arr.flat[0] + 1.0
    leaves[0] = arr
    state["params"] = jax.tree.unflatten(treedef, leaves)
    blob_path.write_bytes(pickle.dumps(state))
    with pytest.raises(ValueError, match="fingerprint"):
        load_checkpoint(str(tmp_path))


def test_data_pipeline_deterministic_and_shardable():
    g = TokenPipeline(DataConfig(vocab=1000, seq_len=16, global_batch=8,
                                 seed=42, n_shards=1))
    s = TokenPipeline(DataConfig(vocab=1000, seq_len=16, global_batch=8,
                                 seed=42, n_shards=4))
    for step in (0, 5, 99):
        gb = g.batch(step, 0)
        sb = s.global_batch(step)
        assert gb["inputs"].shape == sb["inputs"].shape
        # replay determinism
        again = s.global_batch(step)
        np.testing.assert_array_equal(sb["inputs"], again["inputs"])


def test_gradient_compression_preserves_training():
    cfg = get_smoke_config("qwen3-8b")
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=24,
                                    global_batch=4, seed=2))
    losses = {}
    for compress in (None, "int8"):
        oc = AdamWConfig(lr=3e-3, compress=compress)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params, oc)
        step = jax.jit(make_train_step(cfg, opt_cfg=oc))
        for i in range(10):
            b = pipe.global_batch(i)
            params, opt, m = step(params, opt,
                                  {k: jnp.asarray(v) for k, v in b.items()})
        losses[compress] = float(m["loss"])
    # int8 all-reduce compression costs < 5% loss difference here
    assert abs(losses["int8"] - losses[None]) < 0.05 * abs(losses[None])


def test_replicated_server_identical_generations():
    from repro.runtime.server import ReplicatedServer
    cfg = get_smoke_config("gemma3-1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    from repro.models.transformer import decode_step, prefill
    pf = jax.jit(lambda p, i: prefill(cfg, p, i, max_seq=64))
    ds = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))

    def decode_fn(session, hist, n):
        toks = jnp.asarray([hist], jnp.int32)
        logits, caches = pf(params, toks)
        out = []
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(n):
            out.append(int(tok[0]))
            logits, caches = ds(params, caches, tok, jnp.int32(len(hist) + i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return out

    server = ReplicatedServer.build(decode_fn)
    client = server.cluster.new_client()
    toks, lat = server.generate(client, "s0", [1, 2, 3, 4], 4)
    assert len(toks) == 4
    snaps = [r.app.snapshot() for r in server.cluster.replicas]
    assert snaps[0] == snaps[1] == snaps[2]
    toks2, _ = server.generate(client, "s0", [], 2)
    assert len(toks2) == 2


def test_replicated_server_batched_multi_request_submission():
    """generate_many submits concurrent generation requests; the batched
    consensus hot path coalesces them into slots, every replica decodes the
    same totally-ordered sequence, and each request gets its own reply."""
    from repro.core.consensus import ConsensusConfig
    from repro.runtime.server import ReplicatedServer

    def decode_fn(session, hist, n):
        # deterministic toy decoder: next token = len(hist) + i
        return [len(hist) + i for i in range(n)]

    cfg = ConsensusConfig(max_request_bytes=4096, max_batch=8,
                          pipeline_depth=4, batch_timeout_us=20.0)
    server = ReplicatedServer.build(decode_fn, cfg=cfg)
    client = server.cluster.new_client()
    reqs = [(f"s{i % 4}", [i], 2) for i in range(12)]
    outs = server.generate_many(client, reqs)
    assert len(outs) == 12
    assert all(len(toks) == 2 for toks, _lat in outs)
    # all replicas hold identical session state (agreement over batches)
    snaps = [r.app.snapshot() for r in server.cluster.replicas]
    assert snaps[0] == snaps[1] == snaps[2]
    # the load actually exercised batching: fewer slots than requests
    decided = server.cluster.replicas[0].decided
    assert sum(len(b) for b in decided.values()) == 12
    assert len(decided) < 12


def test_coordinator_app_is_deterministic_state_machine():
    import json
    a, b = CoordinatorApp(), CoordinatorApp()
    reqs = [json.dumps({"op": "step"}).encode(),
            json.dumps({"op": "attest", "step": 0, "who": "t0",
                        "grad_fp": 1, "param_fp": 2}).encode(),
            json.dumps({"op": "attest", "step": 0, "who": "t1",
                        "grad_fp": 1, "param_fp": 2}).encode(),
            json.dumps({"op": "checkpoint", "step": 0,
                        "param_fp": 2}).encode()]
    for r in reqs:
        assert a.apply(r) == b.apply(r)
    assert a.snapshot() == b.snapshot()
