import os
import sys

import pytest

# Tests and benches see ONE device; only the dry-run forces 512 (and sets its
# own XLA_FLAGS before any jax import — see repro/launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture
def pooled_cluster():
    """Factory for a kvstore uBFT cluster over sharded memory pools —
    the shared rig for the fault-schedule matrix.  Built through the
    Substrate/attach API (a private substrate, one unnamed app — the pids
    match the legacy ``build_cluster`` layout: r0.., m0.., p1m0..)."""
    from repro.apps.kvstore import KVStoreApp
    from repro.core.consensus import ConsensusConfig
    from repro.core.smr import Cluster
    from repro.core.substrate import Substrate

    def make(n_pools=2, f=None, f_m=None, seed=0, cfg=None, **kw):
        if cfg is not None:
            # mirror build_cluster: never silently clobber a caller cfg
            if f is not None and f != cfg.f:
                raise ValueError(f"conflicting f={f} vs cfg.f={cfg.f}")
            if f_m is not None and f_m != cfg.f_m:
                raise ValueError(f"conflicting f_m={f_m} vs "
                                 f"cfg.f_m={cfg.f_m}")
        else:
            cfg = ConsensusConfig(f=1 if f is None else f,
                                  f_m=1 if f_m is None else f_m)
        substrate = Substrate(f_m=cfg.f_m, n_pools=n_pools, seed=seed, **kw)
        return Cluster.attach(substrate, KVStoreApp, name="", cfg=cfg)

    return make


@pytest.fixture
def shared_substrate():
    """Factory for a multi-application deployment: one substrate, N named
    kvstore apps attached to the same pools (the cross-app isolation rig)."""
    from repro.apps.kvstore import KVStoreApp
    from repro.core.smr import Cluster
    from repro.core.substrate import Substrate

    def make(app_names, n_pools=2, f_m=1, seed=0, cfg_fn=None,
             app_factory=KVStoreApp, **kw):
        substrate = Substrate(f_m=f_m, n_pools=n_pools, seed=seed, **kw)
        clusters = {
            name: Cluster.attach(substrate, app_factory, name=name,
                                 cfg=cfg_fn() if cfg_fn else None)
            for name in app_names
        }
        return substrate, clusters

    return make


@pytest.fixture
def fault_injector():
    """Factory wiring a FaultInjector (with pool resolution) to a cluster."""
    from repro.sim.faults import FaultInjector

    def make(cluster, schedule=None):
        return FaultInjector.for_cluster(cluster, schedule)

    return make
