import os
import sys

import pytest

# Tests and benches see ONE device; only the dry-run forces 512 (and sets its
# own XLA_FLAGS before any jax import — see repro/launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture
def pooled_cluster():
    """Factory for a kvstore uBFT cluster over sharded memory pools —
    the shared rig for the fault-schedule matrix."""
    from repro.apps.kvstore import KVStoreApp
    from repro.core.smr import build_cluster

    def make(n_pools=2, f=1, f_m=1, seed=0, cfg=None, **kw):
        return build_cluster(KVStoreApp, f=f, f_m=f_m, cfg=cfg, seed=seed,
                             n_pools=n_pools, **kw)

    return make


@pytest.fixture
def fault_injector():
    """Factory wiring a FaultInjector (with pool resolution) to a cluster."""
    from repro.sim.faults import FaultInjector

    def make(cluster, schedule=None):
        return FaultInjector.for_cluster(cluster, schedule)

    return make
