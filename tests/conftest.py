import os
import sys

# Tests and benches see ONE device; only the dry-run forces 512 (and sets its
# own XLA_FLAGS before any jax import — see repro/launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
