"""Substrate / Cluster.attach / ScenarioSpec — the multi-application API.

Covers the three new layers (ISSUE 4):

* ``Substrate`` — shared simulator/network/registry/pools, per-app
  accounting and per-app budget faults;
* ``Cluster.attach`` — N independent 2f+1 clusters co-running on one
  event loop over the same pools, with app-namespaced pids and
  ``crc32(app:owner:reg)`` register sharding;
* ``ScenarioSpec``/``run_scenario`` — declarative topology + workloads
  (closed and open loop) + faults;
* the ``build_cluster`` shim — legacy layout preserved, f/f_m conflicts
  raise instead of silently clobbering the caller's config.
"""

import pytest

from repro.apps.flip import FlipApp
from repro.apps.kvstore import KVStoreApp, set_req
from repro.core.consensus import ConsensusConfig
from repro.core.registers import RegisterClient
from repro.core.smr import Cluster, build_cluster
from repro.core.substrate import Substrate
from repro.scenario import (AppSpec, ScenarioSpec, Workload, open_loop,
                            run_scenario)


def _slow_cfg(**kw):
    base = dict(t=16, window=16, slow_mode="always", ctb_fast_enabled=False,
                view_timeout_us=20_000.0)
    base.update(kw)
    return ConsensusConfig(**base)


# --------------------------------------------------------------------------
# Substrate + attach
# --------------------------------------------------------------------------
def test_two_apps_share_one_substrate_and_both_make_progress():
    substrate = Substrate(n_pools=2)
    a = Cluster.attach(substrate, KVStoreApp, name="A", cfg=_slow_cfg())
    b = Cluster.attach(substrate, KVStoreApp, name="B", cfg=_slow_cfg())

    assert a.replica_pids == ["A/r0", "A/r1", "A/r2"]
    assert b.replica_pids == ["B/r0", "B/r1", "B/r2"]
    assert a.pools is b.pools  # genuinely the same TCB

    ca, cb = a.new_client(), b.new_client()
    assert ca.pid == "A/c0" and cb.pid == "B/c0"
    ra, _ = a.run_request(ca, set_req(b"x", b"from-A"))
    rb, _ = b.run_request(cb, set_req(b"x", b"from-B"))
    assert ra == b"OK" and rb == b"OK"
    # same key, different apps: no cross-talk through the shared memory
    for rep in a.replicas:
        assert rep.app.store[b"x"] == b"from-A"
    for rep in b.replicas:
        assert rep.app.store[b"x"] == b"from-B"


def test_duplicate_app_name_rejected():
    substrate = Substrate()
    Cluster.attach(substrate, FlipApp, name="A")
    with pytest.raises(ValueError, match="already attached"):
        Cluster.attach(substrate, FlipApp, name="A")


def test_namespaced_register_sharding_differs_between_apps():
    """crc32(app:owner:reg) — the same (owner, reg) pair must not be
    pinned to the same shard for every app (and "" preserves the legacy
    crc32(owner:reg) routing)."""
    substrate = Substrate(n_pools=4)
    legacy = Cluster.attach(substrate, FlipApp, name="")
    rc = legacy.replicas[0].regs
    assert rc.namespace == ""
    import zlib
    for owner, reg in [("r0", "r0/3"), ("r1", "r1/7")]:
        expect = substrate.pools[zlib.crc32(f"{owner}:{reg}".encode()) % 4]
        assert rc.pool_for(owner, reg) is expect

    # different namespaces spread the same key differently somewhere
    node = legacy.replicas[0]
    shards = {
        ns: [RegisterClient(node, substrate.pools, 1, namespace=ns
                            ).pool_for("r0", f"r0/{k}").name
             for k in range(16)]
        for ns in ("A", "B")
    }
    assert shards["A"] != shards["B"]


def test_per_app_memory_accounting_sums_to_pool_totals():
    substrate = Substrate(n_pools=2)
    a = Cluster.attach(substrate, KVStoreApp, name="A", cfg=_slow_cfg())
    b = Cluster.attach(substrate, KVStoreApp, name="B", cfg=_slow_cfg())
    for cluster in (a, b):
        cl = cluster.new_client()
        for i in range(4):
            cluster.run_request(cl, set_req(b"k%d" % i, b"v"))
    usage = substrate.memory_by_app()
    assert usage["A"] and usage["B"]
    for pool in substrate.pools:
        total = pool.memory_bytes()
        attributed = sum(by_pool.get(pool.name, 0)
                         for by_pool in usage.values())
        assert attributed == total, pool.name
    # the per-cluster view agrees with the substrate rollup
    assert a.memory_by_pool() == usage["A"]


def test_budget_overrun_is_a_per_app_fault_not_a_global_assert():
    substrate = Substrate(n_pools=1)
    a = Cluster.attach(substrate, KVStoreApp, name="A", cfg=_slow_cfg(),
                       budget=1024)  # absurdly small: guaranteed overrun
    b = Cluster.attach(substrate, KVStoreApp, name="B", cfg=_slow_cfg())
    for cluster in (a, b):
        cl = cluster.new_client()
        for i in range(3):
            cluster.run_request(cl, set_req(b"k%d" % i, b"v" * 32))
    overruns = substrate.audit_budgets()
    assert overruns and all(app == "A" for (_t, app, _p, _b, _bud)
                            in overruns)
    assert substrate.budget_faults == overruns
    # B is unaffected: no fault recorded against it, and it keeps running
    r, _ = b.run_request(b.clients[0], set_req(b"after", b"audit"))
    assert r == b"OK"


# --------------------------------------------------------------------------
# build_cluster shim
# --------------------------------------------------------------------------
def test_shim_preserves_legacy_layout():
    c = build_cluster(FlipApp, n_pools=2)
    assert c.replica_pids == ["r0", "r1", "r2"]
    assert [p.name for p in c.pools] == ["pool0", "pool1"]
    assert c.pools[0].members == ["m0", "m1", "m2"]
    assert c.pools[1].members == ["p1m0", "p1m1", "p1m2"]
    assert c.new_client().pid == "c0"
    assert c.substrate is not None and "" in c.substrate.apps


def test_shim_raises_on_conflicting_fault_budgets():
    cfg = ConsensusConfig(f=1, f_m=1)
    with pytest.raises(ValueError, match="conflicting fault budgets"):
        build_cluster(FlipApp, f=2, cfg=cfg)
    with pytest.raises(ValueError, match="conflicting fault budgets"):
        build_cluster(FlipApp, f_m=2, cfg=cfg)
    # agreement (or omission) is fine, and cfg is never mutated
    c = build_cluster(FlipApp, f=1, cfg=cfg)
    assert cfg.f == 1 and c.replicas[0].f == 1
    c = build_cluster(FlipApp, cfg=ConsensusConfig(f=2))
    assert len(c.replicas) == 5  # f comes from cfg alone


# --------------------------------------------------------------------------
# ScenarioSpec / workloads
# --------------------------------------------------------------------------
def test_run_scenario_two_apps_closed_plus_open():
    acked = {}

    def kv_payload(i):
        k, v = b"k%d" % (i % 4), b"v%d" % i
        acked[k] = v
        return set_req(k, v)

    spec = ScenarioSpec(
        n_pools=2,
        apps=[
            AppSpec(name="A", app=KVStoreApp, cfg=_slow_cfg(),
                    workload=Workload(kind="closed", n_requests=8,
                                      payload_fn=kv_payload)),
            AppSpec(name="B", app=FlipApp, cfg=_slow_cfg(),
                    workload=Workload(kind="open", rate_rps=10_000.0,
                                      duration_us=1500.0,
                                      payload=b"y" * 8, seed=3)),
        ])
    res = run_scenario(spec)
    assert res.apps["A"].completed == 8
    assert res.apps["B"].completed == res.apps["B"].issued > 0
    assert not res.budget_overruns
    a = res.clusters["A"]
    for rep in a.replicas:
        for k, v in acked.items():
            assert rep.app.store.get(k) == v
    # flips really executed on B's replicas, not A's
    assert all(r.app.count > 0 for r in res.clusters["B"].replicas)


def test_open_loop_arrivals_are_seeded_and_deterministic():
    def arrivals(seed):
        c = build_cluster(FlipApp, seed=0)
        lats = open_loop(c, lambda i: b"z" * 16, rate_rps=50_000.0,
                         duration_us=800.0, seed=seed)
        return len(lats), tuple(lats)

    n1, l1 = arrivals(seed=5)
    n2, l2 = arrivals(seed=5)
    n3, l3 = arrivals(seed=6)
    assert n1 > 0 and (n1, l1) == (n2, l2)
    assert (n3, l3) != (n1, l1)


def test_workload_validation():
    with pytest.raises(ValueError):
        Workload(kind="open")                # no rate/duration
    with pytest.raises(ValueError):
        Workload(kind="closed")              # no count/duration
    with pytest.raises(ValueError):
        Workload(kind="closed", n_requests=10, duration_us=100.0)  # both
    with pytest.raises(ValueError):
        Workload(kind="sawtooth", n_requests=1)


def test_attach_rejects_f_m_disagreeing_with_substrate():
    """An app's cfg.f_m must equal the substrate's — a smaller value would
    run register quorums that need not intersect on the shared pools."""
    substrate = Substrate(f_m=2)
    with pytest.raises(ValueError, match="f_m"):
        Cluster.attach(substrate, FlipApp, name="A",
                       cfg=ConsensusConfig(f_m=1))
    # omitting cfg inherits the substrate's budget
    c = Cluster.attach(substrate, FlipApp, name="B")
    assert c.replicas[0].regs.quorum == 3


# --------------------------------------------------------------------------
# Pool placement policies (ISSUE 5)
# --------------------------------------------------------------------------
def test_pool_placement_pins_apps_to_disjoint_subsets():
    """attach(..., pools=subset) pins an app's register sharding to a pool
    subset on top of the namespaced crc32 sharding: each app's cells land
    only in its pinned pools, so a noisy neighbour cannot even share a
    pool when the operator says so."""
    substrate = Substrate(n_pools=4)
    a = Cluster.attach(substrate, KVStoreApp, name="A", cfg=_slow_cfg(),
                       pools=[0, 1])
    b = Cluster.attach(substrate, KVStoreApp, name="B", cfg=_slow_cfg(),
                       pools=["pool2", "pool3"])
    assert [p.name for p in a.pools] == ["pool0", "pool1"]
    assert [p.name for p in b.pools] == ["pool2", "pool3"]
    for cluster in (a, b):
        cl = cluster.new_client()
        for i in range(6):
            r, _ = cluster.run_request(cl, set_req(b"k%d" % i, b"v"))
            assert r == b"OK"
    usage = substrate.memory_by_app()
    assert set(usage["A"]) <= {"pool0", "pool1"} and usage["A"]
    assert set(usage["B"]) <= {"pool2", "pool3"} and usage["B"]
    # the un-pinned default still spreads over every pool (same object)
    c = Cluster.attach(substrate, KVStoreApp, name="C", cfg=_slow_cfg())
    assert c.pools is substrate.pools


def test_pool_placement_validation():
    substrate = Substrate(n_pools=2)
    with pytest.raises(ValueError, match="resolve pool"):
        Cluster.attach(substrate, FlipApp, name="A", pools=["nope"])
    with pytest.raises(ValueError, match="at least one"):
        Cluster.attach(substrate, FlipApp, name="B", pools=[])
    with pytest.raises(ValueError, match="resolve pool"):
        Cluster.attach(substrate, FlipApp, name="B2", pools=[5])
    with pytest.raises(ValueError, match="resolve pool"):
        Cluster.attach(substrate, FlipApp, name="B3", pools=[-1])
    with pytest.raises(ValueError, match="twice"):
        Cluster.attach(substrate, FlipApp, name="B4", pools=[0, "pool0"])
    other = Substrate(n_pools=1)
    with pytest.raises(ValueError, match="not on this substrate"):
        Cluster.attach(substrate, FlipApp, name="C",
                       pools=[other.pools[0]])
