"""Straggler mitigation: the protocol's own fast→slow fallback.

A fail-slow replica (the paper's §1 taxonomy) breaks fast-path unanimity;
uBFT must keep deciding through the slow path without a view change, and
recover fast-path latency when the straggler heals.
"""

from repro.apps.kvstore import KVStoreApp, set_req
from repro.core.consensus import ConsensusConfig
from repro.core.smr import build_cluster


def test_slow_follower_degrades_gracefully():
    cfg = ConsensusConfig(view_timeout_us=50_000.0, slow_after_us=300.0)
    c = build_cluster(KVStoreApp, cfg=cfg)
    cl = c.new_client()
    r, fast_lat = c.run_request(cl, set_req(b"a", b"0"))
    assert fast_lat < 15

    # make r2 fail-slow: +5 ms on every link to/from it (asynchrony window)
    c.sim.gst = c.sim.now + 100_000.0
    for other in ("r0", "r1", "c0"):
        c.net.delay_link("r2", other, 5000.0)
        c.net.delay_link(other, "r2", 5000.0)

    lats = []
    for i in range(5):
        r, lat = c.run_request(cl, set_req(b"k%d" % i, b"v"),
                               timeout=60_000_000)
        assert r == b"OK"
        lats.append(lat)
    # decided via the slow path (no unanimity), far below the view timeout
    assert all(200.0 < l < 50_000.0 for l in lats), lats
    assert c.replicas[0].view == 0, "no view change needed for a straggler"

    # straggler heals at GST → fast path resumes
    c.sim.run(until=c.sim.gst + 1000.0)
    c.net.heal()
    lats2 = [c.run_request(cl, set_req(b"h%d" % i, b"v"),
                           timeout=60_000_000)[1] for i in range(10)]
    assert min(lats2) < 15.0, lats2


def test_all_correct_after_straggler_epoch():
    cfg = ConsensusConfig(view_timeout_us=50_000.0, slow_after_us=300.0)
    c = build_cluster(KVStoreApp, cfg=cfg)
    cl = c.new_client()
    c.sim.gst = 20_000.0
    c.net.delay_link("r1", "r0", 2000.0)
    c.net.delay_link("r0", "r1", 2000.0)
    for i in range(8):
        r, _ = c.run_request(cl, set_req(b"x%d" % i, b"%d" % i),
                             timeout=60_000_000)
        assert r == b"OK"
    c.net.heal()
    c.sim.run(until=c.sim.now + 200_000)
    stores = [rep.app.store for rep in c.replicas]
    assert stores[0] == stores[1] == stores[2]
