"""Self-healing membership (ISSUE 8): suspicion-driven replacement,
precomputed reconfiguration plans, rolling full-group rotation.

Covers the tentpole end to end:

* :class:`~repro.core.health.PlanTable` — one precomputed plan per
  possible target, staleness detection, chained rotation plans;
* autonomous detect → replace → recover for a crashed replica and for a
  gray-degraded (``slow_replica``) one, with the detection/recovery
  timeline recorded on the monitor;
* hysteresis and gating: a healthy group never replaces anyone, a stale
  plan never executes, ``replace_replica`` rejects bad requests with
  clear reasons (and raises with ``strict=True``);
* the rolling 2f+1 rotation: every seat replaced through consecutive
  epoch bumps, strictly one replacement in flight, the group serving
  requests afterwards;
* the telemetry surface: ``Cluster.stats()`` exposes per-replica health
  counters, per-pool rekey/abort counts and the suspicion state.
"""

import pytest

from repro.apps.kvstore import KVStoreApp, set_req
from repro.core.consensus import ConsensusConfig
from repro.core.health import (HealthConfig, ReconfigPlan, as_health_config)
from repro.core.smr import Cluster, ReplacementError
from repro.core.substrate import Substrate
from repro.sim.faults import FaultInjector, FaultSchedule


def _registers_cfg(**kw):
    base = dict(t=16, window=16, slow_mode="always", ctb_fast_enabled=False,
                view_timeout_us=20_000.0)
    base.update(kw)
    return ConsensusConfig(**base)


def _cluster(seed=0, n_pools=2, cfg=None, heal=None):
    substrate = Substrate(n_pools=n_pools, seed=seed)
    c = Cluster.attach(substrate, KVStoreApp, name="",
                       cfg=cfg or _registers_cfg())
    mon = c.enable_self_healing(heal) if heal is not None else None
    return c, mon


def _run_kv(cluster, client, lo, hi, acked, timeout=600_000_000):
    for i in range(lo, hi):
        k, v = b"k%d" % (i % 4), b"v%d" % i
        r, _ = cluster.run_request(client, set_req(k, v), timeout=timeout)
        assert r == b"OK"
        acked[k] = v


def _assert_converged(cluster, acked):
    cluster.sim.run(until=cluster.sim.now + 100_000)
    live = [r for r in cluster.replicas if not r.crashed and not r.joining]
    for rep in live:
        for k, v in acked.items():
            assert rep.app.store.get(k) == v, (rep.pid, k, v)
    for a, b in zip(live, live[1:]):
        assert a.app.store == b.app.store


# --------------------------------------------------------------------------
# HealthConfig normalization
# --------------------------------------------------------------------------
def test_as_health_config_accepts_true_dict_and_instance():
    assert as_health_config(True) == HealthConfig()
    assert as_health_config(None) == HealthConfig()
    assert as_health_config({"hb_us": 250.0}).hb_us == 250.0
    hc = HealthConfig(budget=9)
    assert as_health_config(hc) is hc
    with pytest.raises(TypeError):
        as_health_config(42)


# --------------------------------------------------------------------------
# PlanTable
# --------------------------------------------------------------------------
def test_plan_table_one_plan_per_member():
    c, mon = _cluster(seed=1, heal=True)
    plans = mon.plans.plans
    assert set(plans) == {"r0", "r1", "r2"}
    for old, plan in plans.items():
        assert plan.epoch == 1
        assert plan.old_pid == old
        assert plan.new_pid == "r3"          # deterministic joiner pid
        assert plan.members == ("r0", "r1", "r2")
        assert plan.xfer_sources == tuple(
            m for m in ("r0", "r1", "r2") if m != old)
        assert plan.rekey_order == tuple(p.name for p in c.pools)
        assert plan.neighborhood[0] == 1      # f
        assert mon.plans.current(plan)


def test_plan_goes_stale_after_epoch_switch():
    c, mon = _cluster(seed=2, heal=True)
    stale = mon.plans.plan_for("r1")
    c.replicas[2].crash()
    assert c.replace_replica("r2") is not None
    c.sim.run(until=c.sim.now + 50_000)
    assert c.current_epoch() == 1
    assert not mon.plans.current(stale)
    # executing the stale plan is refused with a clear reason
    assert c.replace_replica("r1", plan=stale) is None
    assert "stale plan" in c.rejected_replacements[-1][2]
    # refreshed table targets the new membership and the next joiner pid
    mon.plans.refresh()
    fresh = mon.plans.plan_for("r1")
    assert fresh.epoch == 2 and fresh.new_pid == "r4"
    assert fresh.members == ("r0", "r1", "r3")


def test_rotation_chain_is_consecutive_and_membership_chained():
    c, mon = _cluster(seed=3, heal=True)
    chain = mon.plans.rotation()
    assert [p.epoch for p in chain] == [1, 2, 3]
    # leader-last: the seated leader (r0, view 0) is rotated in the final
    # step so only one view change is paid across the whole rotation
    assert c.current_leader() == "r0"
    assert [p.old_pid for p in chain] == ["r1", "r2", "r0"]
    assert [p.new_pid for p in chain] == ["r3", "r4", "r5"]
    # each plan's expected membership is the previous plan's outcome
    assert chain[0].members == ("r0", "r1", "r2")
    assert chain[1].members == ("r0", "r3", "r2")
    assert chain[2].members == ("r0", "r3", "r4")


# --------------------------------------------------------------------------
# replace_replica guards
# --------------------------------------------------------------------------
def test_replace_guards_reject_with_reasons():
    c, _ = _cluster(seed=4)
    assert c.replace_replica("nope") is None
    assert "unknown pid" in c.rejected_replacements[-1][2]
    with pytest.raises(ReplacementError):
        c.replace_replica("nope", strict=True)

    c.replicas[2].crash()
    joiner = c.replace_replica("r2")
    assert joiner is not None
    # target mid-replacement / second replacement in flight
    assert c.replace_replica(joiner.pid) is None
    assert "joiner" in c.rejected_replacements[-1][2]
    assert c.replace_replica("r1") is None
    assert "in flight" in c.rejected_replacements[-1][2]
    c.sim.run(until=c.sim.now + 50_000)
    # already retired by the committed switch
    assert c.replace_replica("r2") is None
    assert "already retired" in c.rejected_replacements[-1][2]
    # every rejection carries (time, pid, reason)
    assert all(len(rec) == 3 for rec in c.rejected_replacements)


def test_replace_guard_rejects_conflicting_new_pid():
    c, mon = _cluster(seed=5, heal=True)
    plan = mon.plans.plan_for("r2")
    c.replicas[2].crash()
    assert c.replace_replica("r2", new_pid="weird", plan=plan) is None
    assert "conflicts" in c.rejected_replacements[-1][2]
    # the plan itself still executes afterwards (the guard had no effect)
    assert c.replace_replica("r2", plan=plan) is not None


# --------------------------------------------------------------------------
# Autonomous detection and replacement
# --------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [5, 11])
def test_crash_is_detected_and_replaced_autonomously(seed):
    c, mon = _cluster(seed=seed, heal=True)
    cl = c.new_client()
    acked = {}
    _run_kv(c, cl, 0, 6, acked)
    t_crash = c.sim.now
    c.replicas[2].crash()
    c.sim.run(until=c.sim.now + 60_000)
    assert len(mon.replacements) == 1
    rec = mon.replacements[0]
    assert rec["target"] == "r2" and rec["new"] == "r3"
    assert t_crash <= rec["t_detect"] <= rec["t_fire"]
    assert rec["t_active"] is not None and rec["t_active"] >= rec["t_fire"]
    # detection + recovery well inside the fault-schedule noise floor
    assert rec["t_active"] - t_crash < 30_000.0
    assert c.current_epoch() == 1
    assert "r2" not in c.current_members()
    _run_kv(c, cl, 6, 12, acked)
    _assert_converged(c, acked)


@pytest.mark.parametrize("seed", [11, 29])
def test_gray_degraded_replica_is_detected_and_replaced(seed):
    """A slow_replica (alive, but delaying and dropping every send) is
    caught by heartbeat *age* even though its heartbeats keep arriving."""
    c, mon = _cluster(seed=seed, heal=True)
    sched = FaultSchedule().add(
        2_000.0, "slow_replica",
        ("r1", {"delay_us": 1500.0, "drop": 0.5, "seed": 3}))
    FaultInjector.for_cluster(c, sched)
    cl = c.new_client()
    acked = {}
    _run_kv(c, cl, 0, 20, acked)
    c.sim.run(until=c.sim.now + 80_000)
    assert mon.replacements, "gray failure went undetected"
    assert all(rec["target"] == "r1" for rec in mon.replacements)
    assert "r1" not in c.current_members()
    c.net.clear_degrade("r1")     # the sick NIC is out of the group now
    _run_kv(c, cl, 20, 26, acked)
    _assert_converged(c, acked)


def test_healthy_group_never_replaces_anyone():
    c, mon = _cluster(seed=6, heal=True)
    cl = c.new_client()
    acked = {}
    _run_kv(c, cl, 0, 12, acked)
    c.sim.run(until=c.sim.now + 100_000)
    assert mon.replacements == []
    assert mon.accusations == {} or all(
        not acc for acc in mon.accusations.values())
    assert c.current_epoch() == 0
    _assert_converged(c, acked)


def test_suspicion_retracts_when_peer_recovers():
    """A transiently degraded peer is accused, then retracted once its
    heartbeats flow again — hysteresis, not eviction (the accusation
    quorum holds < hold_us or the budget gates fire)."""
    cfg = HealthConfig(hold_us=30_000.0)   # hold long enough to recover
    c, mon = _cluster(seed=7, heal=cfg)
    c.net.degrade_src("r2", delay_us=2_500.0, drop=0.0, seed=1)
    c.sim.run(until=c.sim.now + 8_000)
    accused = {a for (_t, a, tgt, _s, kind) in mon.suspicion_log
               if kind == "accuse" and tgt == "r2"}
    assert accused, "degradation never raised suspicion"
    c.net.clear_degrade("r2")
    c.sim.run(until=c.sim.now + 60_000)
    retracted = {a for (_t, a, tgt, _s, kind) in mon.suspicion_log
                 if kind == "retract" and tgt == "r2"}
    assert accused <= retracted
    assert mon.replacements == []
    assert c.current_epoch() == 0


def test_seat_backoff_and_budget_gate_repeat_fires():
    """After one automatic replacement the same seat backs off
    exponentially and the global cooldown defers immediate refires."""
    cfg = HealthConfig(cooldown_us=30_000.0, backoff_base_us=50_000.0)
    c, mon = _cluster(seed=8, heal=cfg)
    c.replicas[2].crash()
    c.sim.run(until=c.sim.now + 30_000)
    assert len(mon.replacements) == 1
    assert mon._seat_backoff[2][0] == 1
    # the replacement seat (slot 2) now needs backoff_base_us to elapse;
    # crash the joiner immediately and watch the gates defer
    c.replicas[2].crash()
    c.sim.run(until=c.sim.now + 20_000)
    reasons = {r for (_t, _tgt, r) in mon.deferred}
    assert any("cooldown" in r or "backoff" in r for r in reasons), reasons


# --------------------------------------------------------------------------
# Rolling full-group rotation
# --------------------------------------------------------------------------
@pytest.mark.slow
def test_rolling_rotation_replaces_every_seat():
    c, mon = _cluster(seed=21, heal=True)
    cl = c.new_client()
    acked = {}
    _run_kv(c, cl, 0, 8, acked)
    done = []
    mon.rotate(lambda: done.append(c.sim.now))
    with pytest.raises(RuntimeError):
        mon.rotate()                      # one rotation at a time
    c.sim.run(until=c.sim.now + 300_000)
    assert done and not mon.rotating
    assert [e["epoch"] for e in mon.rotation_log] == [1, 2, 3]
    assert all(e["t_done"] is not None for e in mon.rotation_log)
    # strictly sequential: step k+1 fires only after step k completed
    for a, b in zip(mon.rotation_log, mon.rotation_log[1:]):
        assert b["t_fire"] >= a["t_done"]
    assert c.current_epoch() == 3
    assert sorted(c.current_members()) == ["r3", "r4", "r5"]
    # the rotated group still serves requests, history preserved
    _run_kv(c, cl, 8, 16, acked)
    _assert_converged(c, acked)


@pytest.mark.slow
def test_rotation_under_load_stays_safe():
    c, mon = _cluster(seed=13, heal=True)
    cl = c.new_client()
    acked = {}
    _run_kv(c, cl, 0, 6, acked)
    done = []
    mon.rotate(lambda: done.append(c.sim.now))
    # keep writing while all three seats rotate underneath the client
    _run_kv(c, cl, 6, 40, acked)
    c.sim.run(until=c.sim.now + 300_000)
    assert done and c.current_epoch() == 3
    _assert_converged(c, acked)


# --------------------------------------------------------------------------
# Telemetry surface
# --------------------------------------------------------------------------
def test_stats_surface_counters_and_suspicions():
    c, mon = _cluster(seed=9, heal=True)
    c.replicas[2].crash()
    c.sim.run(until=c.sim.now + 40_000)
    st = c.stats()
    assert st["epoch"] == 1
    assert st["members"] == list(c.current_members())
    assert st["auto_replacements"] and \
        st["auto_replacements"][0]["target"] == "r2"
    assert not st["replacement_in_flight"]
    for name, pool in st["pools"].items():
        assert set(pool) == {"rekeys", "aborted_rekeys", "aborted_syncs",
                             "reconfigurations"}
        assert pool["rekeys"] == 1        # exactly the r2 -> r3 rekey
    for pid, h in st["health"].items():
        assert {"starvations", "view_changes", "seated_past"} <= set(h)
    # live agents also expose their miss/suspect state
    live_pid = c.replicas[0].pid
    assert "hb_misses" in st["health"][live_pid]
    assert "suspects" in st["health"][live_pid]
    assert isinstance(st["suspicions"], dict)
    assert st["rejected_replacements"] == []


def test_stats_without_health_layer_has_no_suspicions_key():
    c, _ = _cluster(seed=10)
    st = c.stats()
    assert "suspicions" not in st and "auto_replacements" not in st
    assert st["epoch"] == 0
    # consensus health counters exist even with the layer off (they are
    # plain local counters, zero wire traffic)
    assert all(h["starvations"] >= 0 for h in st["health"].values())


# --------------------------------------------------------------------------
# Scenario / service wiring
# --------------------------------------------------------------------------
def test_scenario_appspec_self_heal_wires_monitor():
    from repro.scenario import AppSpec, ScenarioSpec, Workload, run_scenario

    spec = ScenarioSpec(
        n_pools=2, seed=15, drain_us=20_000.0,
        apps=[AppSpec(name="", app=KVStoreApp, cfg=_registers_cfg(),
                      self_heal={"hb_us": 300.0},
                      workload=Workload(kind="closed", n_requests=6,
                                        payload_fn=lambda i: set_req(
                                            b"a%d" % (i % 2), b"b%d" % i),
                                        seed=3))])
    res = run_scenario(spec)
    mon = res.clusters[""].health_monitor
    assert mon is not None and mon.cfg.hb_us == 300.0
    assert mon.replacements == []      # healthy run


def test_sharded_service_self_heal_covers_every_shard():
    from repro.service import ShardedService

    substrate = Substrate(n_pools=2, seed=16)
    svc = ShardedService.attach(substrate, 2, name="kv",
                                cfg=_registers_cfg(), self_heal=True)
    for shard in svc.shards:
        assert shard.health_monitor is not None
    assert svc._self_heal is True      # split-born shards inherit it
