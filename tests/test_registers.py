"""Reliable SWMR regular registers (§6.1): regularity, torn writes,
Byzantine-writer detection, crash tolerance of memory nodes, retry caps,
memory pools (lease-based reconfiguration + sharding)."""

import pytest

from repro.core import crypto
from repro.core.node import Node
from repro.core.registers import (MAX_READ_ATTEMPTS, MemoryNode, MemoryPool,
                                  RegisterClient, _Cell, _pack, _unpack)
from repro.sim.events import Simulator
from repro.sim.net import NetParams, NetworkModel


class Host(Node):
    pass


def make_rig(n_mem=3, f_m=1, seed=0, params=None):
    sim = Simulator(seed=seed)
    net = NetworkModel(sim, params)
    reg = crypto.KeyRegistry()
    mems = [MemoryNode(sim, net, reg, f"m{i}") for i in range(n_mem)]
    writer = Host(sim, net, reg, "w0")
    reader = Host(sim, net, reg, "q0")
    wc = RegisterClient(writer, [m.pid for m in mems], f_m)
    rc = RegisterClient(reader, [m.pid for m in mems], f_m)
    return sim, mems, writer, reader, wc, rc


def make_pool_rig(n_pools=1, f_m=1, seed=0, **pool_kw):
    sim = Simulator(seed=seed)
    net = NetworkModel(sim)
    reg = crypto.KeyRegistry()
    pools = [MemoryPool(sim, net, reg, f_m=f_m, name=f"pool{i}",
                        prefix=f"p{i}m", **pool_kw) for i in range(n_pools)]
    writer = Host(sim, net, reg, "w0")
    reader = Host(sim, net, reg, "q0")
    wc = RegisterClient(writer, pools if n_pools > 1 else pools[0], f_m)
    rc = RegisterClient(reader, pools if n_pools > 1 else pools[0], f_m)
    return sim, pools, writer, reader, wc, rc


def test_write_then_read():
    sim, mems, w, r, wc, rc = make_rig()
    done = {}
    wc.write("reg0", b"hello-register", lambda: done.setdefault("w", sim.now))
    assert sim.run_until(lambda: "w" in done)
    rc.read("w0", "reg0", lambda v, byz: done.setdefault("r", (v, byz)))
    assert sim.run_until(lambda: "r" in done)
    val, byz = done["r"]
    assert not byz
    assert val is not None and val[1] == b"hello-register"


def test_read_empty_register_returns_bottom():
    sim, mems, w, r, wc, rc = make_rig()
    out = {}
    rc.read("w0", "nothing", lambda v, byz: out.setdefault("r", (v, byz)))
    assert sim.run_until(lambda: "r" in out)
    assert out["r"][0] is None


def test_sequential_writes_monotonic_timestamps():
    sim, mems, w, r, wc, rc = make_rig()
    state = {"n": 0, "done": 0}

    def write_next():
        state["done"] = state["n"]
        i = state["n"]
        if i >= 5:
            return
        state["n"] += 1
        wc.write("reg", f"v{i}".encode(), write_next)

    write_next()
    assert sim.run_until(lambda: state["done"] >= 5)
    out = {}
    rc.read("w0", "reg", lambda v, byz: out.setdefault("r", (v, byz)))
    assert sim.run_until(lambda: "r" in out)
    val, byz = out["r"]
    assert not byz and val[1] == b"v4" and val[0] == 5


def test_survives_memory_node_crash():
    sim, mems, w, r, wc, rc = make_rig()
    mems[0].crash()   # f_m = 1 crash is tolerated
    done = {}
    wc.write("reg", b"crash-tolerant", lambda: done.setdefault("w", 1))
    assert sim.run_until(lambda: "w" in done)
    rc.read("w0", "reg", lambda v, byz: done.setdefault("r", (v, byz)))
    assert sim.run_until(lambda: "r" in done)
    assert done["r"][0][1] == b"crash-tolerant"


def test_torn_write_detected_by_checksum():
    """A READ overlapping a WRITE sees spliced 8-byte-granularity data; the
    checksum must reject it (and the reader falls back to the other
    sub-register / older value)."""
    sim, mems, w, r, wc, rc = make_rig()
    done = {}
    wc.write("reg", b"A" * 64, lambda: done.setdefault("w1", 1))
    assert sim.run_until(lambda: "w1" in done)
    # second write lands after the delta cooldown; read overlaps it
    wc.write("reg", b"B" * 64, lambda: done.setdefault("w2", 1))
    results = []
    # issue reads around the write window
    for delay in (9.0, 10.0, 10.2, 10.4, 11.0, 14.0):
        sim.after(delay, lambda: rc.read("w0", "reg",
                                         lambda v, b: results.append((v, b))))
    assert sim.run_until(lambda: len(results) >= 6, timeout=100000)
    for val, byz in results:
        assert not byz
        assert val is not None
        assert val[1] in (b"A" * 64, b"B" * 64)   # regularity: old or new


def test_blob_pack_unpack_roundtrip():
    blob = _pack(7, b"payload")
    assert _unpack(blob) == (7, b"payload")
    # corruption is detected (flip bits in a payload byte)
    corrupted = blob[:21] + bytes([blob[21] ^ 0xFF]) + blob[22:]
    assert _unpack(corrupted) is None


def test_byzantine_same_timestamp_both_subregisters():
    """A writer that puts the same timestamp in both sub-registers is
    exposed as Byzantine (§6.1)."""
    sim, mems, w, r, wc, rc = make_rig()
    blob = _pack(3, b"evil")
    for m in mems:
        m.cells.clear()
    # forge: owner writes same ts to both sub-registers directly
    for m in mems:
        for sub in (0, 1):
            c = _Cell()
            c.write(blob, now=0.0, dur=0.0)
            m.cells[("w0", "reg", sub)] = c
    out = {}
    rc.read("w0", "reg", lambda v, byz: out.setdefault("r", (v, byz)))
    assert sim.run_until(lambda: "r" in out)
    assert out["r"][1] is True   # Byzantine detected


def test_first_write_overlap_is_bottom_not_byzantine():
    """A READ overlapping the very first WRITE sees one torn sub-register
    next to an *empty* one — regularity allows returning ⊥, but the honest
    writer must NOT be flagged Byzantine (regression: the old all-invalid
    check treated any data-bearing response as a verdict)."""
    sim, mems, w, r, wc, rc = make_rig()
    garbage = b"\xff" * 40            # torn first write: fails the checksum
    for m in mems:
        c = _Cell()
        c.write(garbage, now=0.0, dur=0.0)
        m.cells[("w0", "reg", 1)] = c  # sub 1 = first write (ts 1); sub 0 empty
    out = {}
    rc.read("w0", "reg", lambda v, byz: out.setdefault("r", (v, byz)))
    assert sim.run_until(lambda: "r" in out)
    val, byz = out["r"]
    assert val is None and byz is False


def test_inconclusive_slow_read_gives_up_after_cap():
    """Satellite: the inconclusive-slow-read retry must be capped
    end-to-end.  A permanently-torn register (garbage in both sub-registers
    on every node, δ smaller than the read round-trip so every attempt is
    'slow') yields ⊥ after exactly MAX_READ_ATTEMPTS attempts instead of
    retrying forever."""
    sim, mems, w, r, wc, rc = make_rig(params=NetParams(delta_us=0.1))
    garbage = b"\xee" * 40
    for m in mems:
        for sub in (0, 1):
            c = _Cell()
            c.write(garbage, now=0.0, dur=0.0)
            m.cells[("w0", "reg", sub)] = c
    out = {}
    rc.read("w0", "reg", lambda v, byz: out.setdefault("r", (v, byz)))
    assert sim.run_until(lambda: "r" in out, timeout=1_000_000)
    assert out["r"] == (None, False)
    assert rc.stats["read_attempts"] == MAX_READ_ATTEMPTS
    assert rc.stats["read_retries"] == MAX_READ_ATTEMPTS - 1
    assert rc.stats["reads_exhausted"] == 1


# ---------------------------------------------------------------- pools
def test_pool_reconfiguration_rereplicates_state():
    """Crash a member, reconfigure: the replacement must hold the
    highest-timestamp data *before* serving — proven by crashing a second
    (old) member afterwards and still reading the latest value."""
    sim, pools, w, r, wc, rc = make_pool_rig()
    pool = pools[0]
    done = {}
    wc.write("reg", b"precious", lambda: done.setdefault("w", 1))
    assert sim.run_until(lambda: "w" in done)
    old = list(pool.members)
    pool.crash_node(old[0])
    assert pool.crashed_members() == [old[0]]
    assert pool.reconfigure(cb=lambda: done.setdefault("rc", sim.now))
    assert sim.run_until(lambda: "rc" in done)
    assert pool.epoch == 1
    fresh = pool.reconfigurations[0][2]
    assert fresh in pool.members and old[0] not in pool.members
    assert pool.nodes[fresh].serving
    # second crash: quorum now *requires* the replacement's copy
    pool.crash_node(old[1])
    rc.read("w0", "reg", lambda v, byz: done.setdefault("r", (v, byz)))
    assert sim.run_until(lambda: "r" in done)
    val, byz = done["r"]
    assert not byz and val is not None and val[1] == b"precious"


def test_pool_reconfigure_noop_without_crash():
    sim, pools, w, r, wc, rc = make_pool_rig()
    assert pools[0].reconfigure() is False
    assert pools[0].epoch == 0


def test_replacement_node_serves_no_reads_before_sync():
    sim, pools, w, r, wc, rc = make_pool_rig()
    pool = pools[0]
    node = pool._spawn(serving=False)
    out = {}
    r.handle("REG_READ_ACK", lambda src, body: out.setdefault("ack", body))
    r.send(node.pid, "REG_READ", ("w0", "reg", 1))
    sim.run(until=sim.now + 100)
    assert "ack" not in out   # dropped until POOL_PUSH flips `serving`


def test_lease_expiry_auto_reconfigures():
    """Lease-based detection: with auto_reconfigure on, a crashed member is
    suspected after its lease expires and replaced without manual action."""
    sim, pools, w, r, wc, rc = make_pool_rig(auto_reconfigure=True,
                                             lease_us=100.0)
    pool = pools[0]
    dead = pool.members[1]
    pool.crash_node(dead)
    assert sim.run_until(lambda: pool.epoch >= 1, timeout=5_000)
    assert dead not in pool.members
    assert any(s[1] == dead for s in pool.manager.suspected)
    done = {}
    wc.write("reg", b"after-lease", lambda: done.setdefault("w", 1))
    assert sim.run_until(lambda: "w" in done)
    rc.read("w0", "reg", lambda v, byz: done.setdefault("r", (v, byz)))
    assert sim.run_until(lambda: "r" in done)
    assert done["r"][0][1] == b"after-lease"


def test_sharding_spreads_registers_across_pools():
    """Keys hash across pools; both pools see traffic and reads route back
    to the right shard."""
    sim, pools, w, r, wc, rc = make_pool_rig(n_pools=2)
    assert rc.n_shards == 2
    done = {"w": 0}
    regs = [f"reg{i}" for i in range(8)]
    for name in regs:
        wc.write(name, name.encode(),
                 lambda: done.__setitem__("w", done["w"] + 1))
    assert sim.run_until(lambda: done["w"] >= len(regs), timeout=1_000_000)
    assert all(p.memory_bytes() > 0 for p in pools)
    shards = {wc.pool_for("w0", name).name for name in regs}
    assert shards == {"pool0", "pool1"}
    out = {}
    for name in regs:
        rc.read("w0", name, lambda v, byz, name=name: out.setdefault(name, v))
    assert sim.run_until(lambda: len(out) == len(regs), timeout=1_000_000)
    for name in regs:
        assert out[name] is not None and out[name][1] == name.encode()


def test_pool_memory_accounting_counts_current_members():
    sim, pools, w, r, wc, rc = make_pool_rig()
    pool = pools[0]
    done = {}
    wc.write("reg", b"x" * 64, lambda: done.setdefault("w", 1))
    assert sim.run_until(lambda: "w" in done)
    assert pool.memory_bytes() == sum(n.memory_bytes()
                                      for n in pool.member_nodes())
    assert pool.memory_bytes() < 2**20


# --------------------------------------------------------------------------
# Byzantine memory-side adversary + permission rekeying (ISSUE 5)
# --------------------------------------------------------------------------
def test_stale_serve_cannot_break_regularity_within_budget():
    """≤ f_m stale-serving nodes (old-but-well-formed blobs: valid
    checksum, stale timestamp): once a completed write has propagated to
    the other live members, READs still return the latest acknowledged
    value — they complete at f_m+1 responses and take the highest valid
    timestamp, and some fresh responder outbids the stale one.  This
    sharpens the crash-only TCB boundary of §3: *serving stale* is already
    Byzantine behaviour, yet timestamp-quorum reads absorb it up to the
    same f_m budget in the steady state.  (The adversarial propagation
    race — the stale server as the only write-acker inside a read quorum
    of lagging members — is the precise edge of that boundary and is NOT
    claimed here; see ROADMAP.)"""
    sim, pools, w, r, wc, rc = make_pool_rig()
    pool = pools[0]
    done = {}
    wc.write("reg", b"v1", lambda: done.setdefault("w1", 1))
    assert sim.run_until(lambda: "w1" in done)
    # one member (= f_m) freezes what it serves at v1
    stale_node = pool.member_nodes()[0]
    stale_node.set_stale_serve(True)
    wc.write("reg", b"v2-fresh", lambda: done.setdefault("w2", 1))
    assert sim.run_until(lambda: "w2" in done)
    for i in range(4):  # several reads: every quorum draw must be fresh
        rc.read("w0", "reg",
                lambda v, byz, i=i: done.setdefault(f"r{i}", (v, byz)))
        assert sim.run_until(lambda: f"r{i}" in done)
        val, byz = done[f"r{i}"]
        assert not byz
        assert val is not None and val[1] == b"v2-fresh", (i, val)
    # the stale node is genuinely serving old data (the adversary engaged)
    assert stale_node.stale_serve
    assert _unpack(stale_node._stale_cells.get(("w0", "reg", 1), b""))[1] \
        == b"v1"


def test_stale_serve_toggles_off():
    sim, pools, w, r, wc, rc = make_pool_rig()
    node = pools[0].member_nodes()[1]
    node.set_stale_serve(True)
    assert node.stale_serve and node._stale_cells == {}  # nothing written
    node.set_stale_serve(False)
    assert not node.stale_serve


def test_rekey_owner_moves_permission_and_state():
    """rekey_owner: the old owner's cells move to the new pid (highest
    valid timestamp, via the pull/merge path), the old pid's write access
    is revoked, and the inherited write timestamps are reported so the new
    owner's next WRITE supersedes the inherited blobs."""
    sim, pools, w, r, wc, rc = make_pool_rig()
    pool = pools[0]
    done = {}
    for i in range(3):  # ts 1..3
        wc.write("reg", b"gen%d" % i, lambda i=i: done.setdefault(i, 1))
        assert sim.run_until(lambda: i in done)
    new_owner = Host(sim, net := pool.net, pool.registry, "w9")
    nc = RegisterClient(new_owner, pool, 1)
    pool.rekey_owner("w0", "w9", cb=lambda wts: (
        nc.adopt_wts(wts), done.setdefault("rekey", dict(wts))))
    assert sim.run_until(lambda: "rekey" in done)
    assert pool.rekeys and pool.rekeys[0][1:] == ("w0", "w9")
    assert done["rekey"] == {"reg": 3}
    assert nc._wts["reg"] == 3
    # old owner can no longer write anywhere
    out = {}
    wc.write("reg", b"zombie", lambda: out.setdefault("w", 1))
    assert not sim.run_until(lambda: "w" in out, timeout=5_000)
    # readers of the NEW owner see the inherited value...
    rc.read("w9", "reg", lambda v, byz: out.setdefault("r1", (v, byz)))
    assert sim.run_until(lambda: "r1" in out)
    val, byz = out["r1"]
    assert not byz and val is not None and val[1] == b"gen2"
    # ...and the new owner's next WRITE outbids it (adopted timestamps)
    nc.write("reg", b"fresh-owner", lambda: out.setdefault("w9", 1))
    assert sim.run_until(lambda: "w9" in out)
    rc.read("w9", "reg", lambda v, byz: out.setdefault("r2", (v, byz)))
    assert sim.run_until(lambda: "r2" in out)
    val2, byz2 = out["r2"]
    assert not byz2 and val2 is not None
    assert val2[1] == b"fresh-owner" and val2[0] == 4


def test_rekey_timeout_is_recorded_and_retried():
    """A rekey whose pull quorum is transiently unreachable must not
    silently drop the revocation: the round lands in aborted_rekeys and
    is retried until it completes."""
    sim, pools, w, r, wc, rc = make_pool_rig(sync_timeout_us=500.0)
    pool = pools[0]
    done = {}
    wc.write("reg", b"data", lambda: done.setdefault("w", 1))
    assert sim.run_until(lambda: "w" in done)
    # kill the pull quorum (f_m+1 = 2 of 3 members down)
    down = pool.members[:2]
    for pid in down:
        pool.crash_node(pid)
    pool.rekey_owner("w0", "w9", cb=lambda wts: done.setdefault("rk", wts))
    sim.run(until=sim.now + 2_000.0)
    assert pool.aborted_rekeys and not pool.rekeys  # timed out, recorded
    # quorum comes back: the retry loop completes the revocation
    for pid in down:
        pool.recover_node(pid)
    assert sim.run_until(lambda: "rk" in done, timeout=60_000.0)
    assert pool.rekeys and pool.rekeys[0][1:] == ("w0", "w9")
    assert done["rk"] == {"reg": 1}
    for n in pool.member_nodes():
        if not n.crashed:
            assert "w0" in n.revoked
