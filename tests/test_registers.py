"""Reliable SWMR regular registers (§6.1): regularity, torn writes,
Byzantine-writer detection, crash tolerance of memory nodes."""

import pytest

from repro.core import crypto
from repro.core.node import Node
from repro.core.registers import MemoryNode, RegisterClient, _pack, _unpack
from repro.sim.events import Simulator
from repro.sim.net import NetworkModel


class Host(Node):
    pass


def make_rig(n_mem=3, f_m=1, seed=0):
    sim = Simulator(seed=seed)
    net = NetworkModel(sim)
    reg = crypto.KeyRegistry()
    mems = [MemoryNode(sim, net, reg, f"m{i}") for i in range(n_mem)]
    writer = Host(sim, net, reg, "w0")
    reader = Host(sim, net, reg, "q0")
    wc = RegisterClient(writer, [m.pid for m in mems], f_m)
    rc = RegisterClient(reader, [m.pid for m in mems], f_m)
    return sim, mems, writer, reader, wc, rc


def test_write_then_read():
    sim, mems, w, r, wc, rc = make_rig()
    done = {}
    wc.write("reg0", b"hello-register", lambda: done.setdefault("w", sim.now))
    assert sim.run_until(lambda: "w" in done)
    rc.read("w0", "reg0", lambda v, byz: done.setdefault("r", (v, byz)))
    assert sim.run_until(lambda: "r" in done)
    val, byz = done["r"]
    assert not byz
    assert val is not None and val[1] == b"hello-register"


def test_read_empty_register_returns_bottom():
    sim, mems, w, r, wc, rc = make_rig()
    out = {}
    rc.read("w0", "nothing", lambda v, byz: out.setdefault("r", (v, byz)))
    assert sim.run_until(lambda: "r" in out)
    assert out["r"][0] is None


def test_sequential_writes_monotonic_timestamps():
    sim, mems, w, r, wc, rc = make_rig()
    state = {"n": 0, "done": 0}

    def write_next():
        state["done"] = state["n"]
        i = state["n"]
        if i >= 5:
            return
        state["n"] += 1
        wc.write("reg", f"v{i}".encode(), write_next)

    write_next()
    assert sim.run_until(lambda: state["done"] >= 5)
    out = {}
    rc.read("w0", "reg", lambda v, byz: out.setdefault("r", (v, byz)))
    assert sim.run_until(lambda: "r" in out)
    val, byz = out["r"]
    assert not byz and val[1] == b"v4" and val[0] == 5


def test_survives_memory_node_crash():
    sim, mems, w, r, wc, rc = make_rig()
    mems[0].crash()   # f_m = 1 crash is tolerated
    done = {}
    wc.write("reg", b"crash-tolerant", lambda: done.setdefault("w", 1))
    assert sim.run_until(lambda: "w" in done)
    rc.read("w0", "reg", lambda v, byz: done.setdefault("r", (v, byz)))
    assert sim.run_until(lambda: "r" in done)
    assert done["r"][0][1] == b"crash-tolerant"


def test_torn_write_detected_by_checksum():
    """A READ overlapping a WRITE sees spliced 8-byte-granularity data; the
    checksum must reject it (and the reader falls back to the other
    sub-register / older value)."""
    sim, mems, w, r, wc, rc = make_rig()
    done = {}
    wc.write("reg", b"A" * 64, lambda: done.setdefault("w1", 1))
    assert sim.run_until(lambda: "w1" in done)
    # second write lands after the delta cooldown; read overlaps it
    wc.write("reg", b"B" * 64, lambda: done.setdefault("w2", 1))
    results = []
    # issue reads around the write window
    for delay in (9.0, 10.0, 10.2, 10.4, 11.0, 14.0):
        sim.after(delay, lambda: rc.read("w0", "reg",
                                         lambda v, b: results.append((v, b))))
    assert sim.run_until(lambda: len(results) >= 6, timeout=100000)
    for val, byz in results:
        assert not byz
        assert val is not None
        assert val[1] in (b"A" * 64, b"B" * 64)   # regularity: old or new


def test_blob_pack_unpack_roundtrip():
    blob = _pack(7, b"payload")
    assert _unpack(blob) == (7, b"payload")
    # corruption is detected (flip bits in a payload byte)
    corrupted = blob[:21] + bytes([blob[21] ^ 0xFF]) + blob[22:]
    assert _unpack(corrupted) is None


def test_byzantine_same_timestamp_both_subregisters():
    """A writer that puts the same timestamp in both sub-registers is
    exposed as Byzantine (§6.1)."""
    sim, mems, w, r, wc, rc = make_rig()
    blob = _pack(3, b"evil")
    for m in mems:
        m.cells.clear()
    # forge: owner writes same ts to both sub-registers directly
    for m in mems:
        from repro.core.registers import _Cell
        for sub in (0, 1):
            c = _Cell()
            c.write(blob, now=0.0, dur=0.0)
            m.cells[("w0", "reg", sub)] = c
    out = {}
    rc.read("w0", "reg", lambda v, byz: out.setdefault("r", (v, byz)))
    assert sim.run_until(lambda: "r" in out)
    assert out["r"][1] is True   # Byzantine detected
