"""The wire-cache invariant (DESIGN_PERF.md): every cached form must agree
with a fresh computation, for every protocol message shape — plus the
immutability discipline the cache relies on and the engine helpers the
refactor introduced (periodic timers, event accounting, jitter stream)."""

import struct

import pytest

from repro.core import crypto
from repro.sim.events import Simulator
from repro.sim.net import NetParams, NetworkModel


# --------------------------------------------------------------------------
# protocol message shapes (requests, batches, checkpoints, summaries, certs)
# --------------------------------------------------------------------------
_RID = ("c0", 7)
_REQ = (_RID, "c0", b"x" * 32)
_BATCH = (_REQ, (("c1", 0), "c1", b"y" * 8), (("c2", 3), "c2", b""))
_FP = bytes(range(32))
PROTOCOL_SHAPES = [
    _REQ,                                            # request triple
    _BATCH,                                          # batched PREPARE payload
    ("PREPARE", 0, 3, _BATCH),                       # CTBcast message
    ("COMMIT", (0, 3, _FP, _BATCH, (("r0", b"s" * 64),))),
    ("cp", 256, 256, _FP),                           # checkpoint payload
    ("CPCERT", 256, 256, _FP, (("r0", b"s" * 64), ("r1", b"t" * 64))),
    ("sum", "r0", 63, ((62, _FP), (63, _FP))),       # summary digest body
    ("ctb/r0/LK/", 5, 0, ("PREPARE", 0, 5, _BATCH)),  # TB wire body
    ("certify", 0, 3, _FP),                          # signature payload
    (0, b"s" * 64, _FP),                             # register blob tuple
    None, True, False, 0, -1, 2**40, 1.5, "", "pid", b"", b"\x00" * 129,
    (), ((),), ("nested", ("deep", ("deeper", b"x"))),
]


@pytest.mark.parametrize("obj", PROTOCOL_SHAPES,
                         ids=[f"shape{i}" for i in range(len(PROTOCOL_SHAPES))])
def test_cached_forms_agree_with_fresh(obj):
    assert crypto.encode_cached(obj) == crypto.encode(obj)
    assert crypto.encode_shallow(obj) == crypto.encode(obj)
    assert crypto.fingerprint_cached(obj) == crypto.fingerprint(crypto.encode(obj))
    assert crypto.wire_size_cached(obj) == crypto.wire_size(obj)
    assert crypto.wire_size_shallow(obj) == crypto.wire_size(obj)
    # second pass: the memoized entry must return the same answers
    assert crypto.encode_cached(obj) == crypto.encode(obj)
    assert crypto.wire_size_cached(obj) == crypto.wire_size(obj)


def test_property_cached_equals_fresh_random_shapes():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    scalars = st.one_of(
        st.none(), st.booleans(),
        st.integers(min_value=-2**62, max_value=2**62),
        st.floats(allow_nan=False, allow_infinity=False, width=64),
        st.binary(max_size=64), st.text(max_size=16))
    payloads = st.recursive(
        scalars, lambda inner: st.tuples(inner, inner, inner) |
        st.lists(inner, max_size=4).map(tuple), max_leaves=24)

    @settings(max_examples=200, deadline=None)
    @given(payloads)
    def check(obj):
        assert crypto.encode_cached(obj) == crypto.encode(obj)
        assert crypto.encode_shallow(obj) == crypto.encode(obj)
        assert crypto.wire_size_cached(obj) == crypto.wire_size(obj)
        assert crypto.wire_size_shallow(obj) == crypto.wire_size(obj)
        assert (crypto.fingerprint_cached(obj) ==
                crypto.fingerprint(crypto.encode(obj)))

    check()


def test_cache_generation_churn_stays_correct():
    """Push far past the generation limit; late and early entries must
    still answer correctly (strong refs pin ids; evicted entries simply
    recompute)."""
    early = ("early", b"payload", 1)
    early_enc = crypto.encode_cached(early)
    objs = [("churn", i, b"x" * (i % 7)) for i in range(3000)]
    for o in objs:
        assert crypto.encode_cached(o) == crypto.encode(o)
    assert crypto.encode_cached(early) == early_enc == crypto.encode(early)


def test_receiver_reuses_senders_encoding():
    """Identity caching is what lets a receiver skip re-encoding: the same
    object yields the very same bytes object back (no recompute)."""
    payload = ("PREPARE", 0, 1, _BATCH)
    first = crypto.encode_cached(payload)
    assert crypto.encode_cached(payload) is first
    assert crypto.fingerprint_cached(payload) is crypto.fingerprint_cached(payload)


def test_immutability_discipline_mutable_containers_not_cached():
    """Lists/dicts may be mutated between calls — the cache must never
    memoize them (only tuples/bytes, which Python cannot mutate)."""
    lst = [1, 2, 3]
    before = crypto.encode_cached(lst)
    lst.append(4)
    after = crypto.encode_cached(lst)
    assert before != after == crypto.encode(lst)
    d = {"a": 1}
    b1 = crypto.encode_cached(d)
    d["b"] = 2
    assert crypto.encode_cached(d) == crypto.encode(d) != b1


def test_immutability_discipline_nested_mutables_not_frozen():
    """A tuple with a dict/list anywhere beneath it (a COMMIT wraps its
    cert dict exactly like this) must re-encode so child mutation stays
    visible — the memo only freezes deeply immutable trees."""
    cert = {"view": 0, "slot": 3, "sigs": (("r0", b"s" * 64),)}
    m = ("COMMIT", cert)
    e1 = crypto.encode_cached(m)
    f1 = crypto.fingerprint_cached(m)
    s1 = crypto.wire_size_cached(m)
    assert e1 == crypto.encode(m)
    cert["slot"] = 4
    assert crypto.encode_cached(m) == crypto.encode(m) != e1
    assert (crypto.fingerprint_cached(m) ==
            crypto.fingerprint(crypto.encode(m)) != f1)
    assert crypto.wire_size_cached(m) == crypto.wire_size(m)
    nested = ("wrap", ("deeper", [1, 2]))
    b = crypto.encode_cached(nested)
    nested[1][1].append(3)
    assert crypto.encode_cached(nested) == crypto.encode(nested) != b
    assert s1 == crypto.wire_size(("COMMIT", {"view": 0, "slot": 3,
                                              "sigs": (("r0", b"s" * 64),)}))


def test_wire_cache_clear():
    crypto.encode_cached(("fill", 1, b"x"))
    assert crypto.wire_cache_len() > 0
    crypto.clear_wire_cache()
    assert crypto.wire_cache_len() == 0
    # still functional after a clear
    obj = ("post-clear", b"y")
    assert crypto.encode_cached(obj) == crypto.encode(obj)


# --------------------------------------------------------------------------
# checksum satellite: single pass, no reversed copy, still 8 bytes
# --------------------------------------------------------------------------
def test_checksum_is_8_bytes_and_deterministic():
    for data in [b"", b"a", b"hello world", bytes(range(256)) * 5]:
        c = crypto.checksum(data)
        assert 0 <= c < 2**64
        assert c == crypto.checksum(data)
        assert len(crypto.checksum_bytes(data)) == 8


def test_checksum_two_words_decorrelated():
    """The low word must not simply mirror the high word, and
    prefix-sharing buffers must not collide (the reason for two words)."""
    a = crypto.checksum(b"abcdef")
    b = crypto.checksum(b"abcdeg")
    assert a != b
    assert (a >> 32) != (a & 0xFFFFFFFF)
    # a torn blob (bit flip) is rejected
    blob = crypto.checksum_bytes(b"payload") + b"payload"
    torn = blob[:10] + bytes([blob[10] ^ 0xFF]) + blob[11:]
    assert crypto.checksum_bytes(torn[8:]) != torn[:8]


# --------------------------------------------------------------------------
# engine helpers: periodic coalescing, event accounting, jitter stream
# --------------------------------------------------------------------------
def test_periodic_coalesces_and_preserves_order():
    sim = Simulator(seed=0)
    fired = []
    sim.periodic(10.0, lambda: fired.append(("a", sim.now)))
    sim.periodic(10.0, lambda: fired.append(("b", sim.now)))
    sim.run(until=35.0)
    assert fired == [("a", 10.0), ("b", 10.0), ("a", 20.0), ("b", 20.0),
                     ("a", 30.0), ("b", 30.0)]
    # both subscribers share one heap event per tick: 3 ticks = 3 events
    assert sim.events_processed == 3


def test_periodic_cancel():
    sim = Simulator(seed=0)
    fired = []
    ha = sim.periodic(10.0, lambda: fired.append("a"))
    sim.periodic(10.0, lambda: fired.append("b"))
    sim.run(until=15.0)
    ha.cancel()
    sim.run(until=45.0)
    assert fired == ["a", "b", "b", "b", "b"]


def test_periodic_distinct_phases_do_not_merge():
    sim = Simulator(seed=0)
    fired = []
    sim.periodic(10.0, lambda: fired.append(("a", sim.now)))
    sim.after(5.0, lambda: sim.periodic(10.0,
                                        lambda: fired.append(("b", sim.now))))
    sim.run(until=26.0)
    assert fired == [("a", 10.0), ("b", 15.0), ("a", 20.0), ("b", 25.0)]


def test_events_processed_counter():
    sim = Simulator(seed=0)
    for i in range(5):
        sim.after(float(i), lambda: None)
    sim.run()
    assert sim.events_processed == 5


def test_jitter_block_matches_scalar_draws():
    """Vectorized refills must consume the seeded stream exactly like
    scalar draws (the bit-identical-results invariant)."""
    import numpy as np
    sim = Simulator(seed=123)
    net = NetworkModel(sim, NetParams())
    got = [net.jitter() for _ in range(5000)]
    rng = np.random.default_rng(123)
    want = [float(rng.lognormal(0.0, net.p.jitter_sigma)) for _ in range(5000)]
    assert got == want


def test_jitter_sigma_change_resets_block():
    sim = Simulator(seed=1)
    net = NetworkModel(sim, NetParams())
    net.jitter()
    net.p.jitter_sigma = 0.5
    v = net.jitter()  # must be drawn with the new sigma, not the stale block
    assert net._jitter_sigma == 0.5
    assert v > 0


def test_wire_sizes_priced_from_cache_match_message_sizes():
    """End-to-end: bytes_sent accounting must be unchanged by caching —
    send the same logical message twice (fresh object vs shared object)
    and observe identical pricing."""
    from repro.core.node import Node

    class Probe(Node):
        def on_message(self, src, msg):
            pass

    sim = Simulator(seed=0)
    net = NetworkModel(sim, NetParams(jitter_sigma=0.0))
    reg = crypto.KeyRegistry()
    a = Probe(sim, net, reg, "a")
    Probe(sim, net, reg, "b")
    body = ("PREPARE", 0, 1, _BATCH)
    a.send("b", "X", body)
    first = net.bytes_sent
    a.send("b", "X", ("PREPARE", 0, 1,
                      (_REQ, (("c1", 0), "c1", b"y" * 8), (("c2", 3), "c2", b""))))
    assert net.bytes_sent == 2 * first
