"""Membership epochs: live replica replacement (ISSUE 5).

Covers the tentpole end to end:

* :class:`~repro.core.membership.MembershipEpoch` — slot-preserving
  replacement, leader mapping, quorum arithmetic;
* ``Cluster.replace_replica`` — non-voting install, state transfer via the
  pools (``xfer/<epoch>`` registers + permission rekey), epoch bump agreed
  through a consensus MEMBERSHIP slot, f+1 EPOCH activation;
* stale-epoch rejection — SEAL_VIEW/NEW_VIEW from the wrong epoch are
  dropped, retired replicas' streams are dead;
* non-voting joiners cannot affect quorums before the epoch commits —
  neither by casting votes nor by having votes counted;
* the acceptance scenario: crash + replace under load with a Byzantine
  (equivocating) leader in the same window, safety + liveness + < 1 MiB
  per pool throughout the transfer.
"""

import pytest

from repro.apps.kvstore import KVStoreApp, set_req
from repro.core import crypto
from repro.core.consensus import ConsensusConfig
from repro.core.membership import MembershipEpoch
from repro.core.registers import POOL_MEMORY_BUDGET
from repro.core.smr import Cluster
from repro.core.substrate import Substrate
from repro.sim.faults import FaultInjector, FaultSchedule


def _registers_cfg(**kw):
    base = dict(t=16, window=16, slow_mode="always", ctb_fast_enabled=False,
                view_timeout_us=20_000.0)
    base.update(kw)
    return ConsensusConfig(**base)


def _cluster(seed=0, n_pools=2, cfg=None):
    substrate = Substrate(n_pools=n_pools, seed=seed)
    return Cluster.attach(substrate, KVStoreApp, name="",
                          cfg=cfg or _registers_cfg())


def _run_kv(cluster, client, lo, hi, acked, timeout=600_000_000):
    for i in range(lo, hi):
        k, v = b"k%d" % (i % 4), b"v%d" % i
        r, _ = cluster.run_request(client, set_req(k, v), timeout=timeout)
        assert r == b"OK"
        acked[k] = v


def _assert_converged(cluster, acked):
    cluster.sim.run(until=cluster.sim.now + 100_000)
    live = [r for r in cluster.replicas if not r.crashed]
    for rep in live:
        for k, v in acked.items():
            assert rep.app.store.get(k) == v, (rep.pid, k, v)
    for a, b in zip(live, live[1:]):
        assert a.app.store == b.app.store


# --------------------------------------------------------------------------
# MembershipEpoch
# --------------------------------------------------------------------------
def test_membership_epoch_replace_preserves_slot():
    m0 = MembershipEpoch(0, ("r0", "r1", "r2"))
    assert (m0.n, m0.f, m0.quorum) == (3, 1, 2)
    assert m0.leader(4) == "r1"
    m1 = m0.replace("r1", "r3")
    assert m1.epoch == 1
    assert m1.replicas == ("r0", "r3", "r2")   # slot preserved
    assert m1.leader(4) == "r3"                # only the replaced slot moves
    assert "r1" not in m1 and "r3" in m1
    with pytest.raises(ValueError):
        m1.replace("r1", "r4")                 # r1 is no longer a member
    with pytest.raises(ValueError):
        m1.replace("r0", "r2")                 # r2 already a member
    with pytest.raises(ValueError):
        MembershipEpoch(0, ("r0", "r0", "r1"))


# --------------------------------------------------------------------------
# Replacement end to end
# --------------------------------------------------------------------------
def test_replace_crashed_replica_epoch_commits_and_joiner_converges():
    c = _cluster(seed=5)
    cl = c.new_client()
    acked = {}
    _run_kv(c, cl, 0, 6, acked)
    c.replicas[2].crash()
    joiner = c.replace_replica("r2")
    assert joiner is not None and joiner.pid == "r3" and joiner.joining
    c.sim.run(until=c.sim.now + 50_000)
    # the epoch bump was agreed and applied everywhere, joiner included
    for rep in c.replicas:
        assert rep.membership.epoch == 1
        assert tuple(rep.replicas) == ("r0", "r1", "r3")
        assert not rep.joining
    # register permissions were re-keyed on every pool
    for p in c.pools:
        assert p.rekeys and p.rekeys[0][1:] == ("r2", "r3")
        for n in p.member_nodes():
            assert "r2" in n.revoked
            # no occupied cell remains under the revoked owner (reads may
            # have re-created empty placeholder cells — zero occupancy)
            assert not any(k[0] == "r2" and cell.blob
                           for k, cell in n.cells.items())
    _run_kv(c, cl, 6, 12, acked)
    _assert_converged(c, acked)
    # the joiner executed the full history (state transfer + catch-up)
    assert joiner.app.store == c.replicas[0].app.store
    assert c.replacements and c.replacements[0][1:] == ("r2", "r3")


def test_replace_replica_fault_event_drives_replacement():
    c = _cluster(seed=9)
    sched = (FaultSchedule()
             .add(500.0, "crash", "r1")
             .add(1200.0, "replace_replica", "r1"))
    inj = FaultInjector.for_cluster(c, sched)
    cl = c.new_client()
    acked = {}
    _run_kv(c, cl, 0, 10, acked)
    c.sim.run(until=c.sim.now + 60_000)
    assert [a for (_t, a, _tgt) in inj.log] == ["crash", "replace_replica"]
    for rep in c.replicas:
        if not rep.crashed:
            assert rep.membership.epoch == 1
    _assert_converged(c, acked)


def test_second_replacement_rejected_while_one_in_flight():
    c = _cluster(seed=3)
    c.replicas[2].crash()
    assert c.replace_replica("r2") is not None
    # in flight: survivors hold a pending bump → a second one is refused
    assert c.replace_replica("r1") is None
    c.sim.run(until=c.sim.now + 50_000)
    # after the commit, a further replacement is possible again
    c.replicas[1].crash()
    j2 = c.replace_replica("r1")
    assert j2 is not None and j2.pid == "r4"
    c.sim.run(until=c.sim.now + 50_000)
    assert all(r.membership.epoch == 2 for r in c.replicas if not r.crashed)


# --------------------------------------------------------------------------
# Stale-epoch rejection
# --------------------------------------------------------------------------
def test_stale_epoch_seal_view_and_new_view_are_dropped():
    c = _cluster(seed=1)
    rep = c.replicas[0]
    peer = c.replicas[1].pid
    # bump the local epoch as an agreed switch would
    rep.pending_membership[1] = ("r2", "rX")
    rep._ensure_participant("rX")
    rep._apply_membership(1, "r2", "rX", slot=-1)
    assert rep.membership.epoch == 1
    st = rep.state[peer]
    before = (st.view, st.seal_view)
    # epoch-0 (stale) SEAL_VIEW: rejected like a stale view
    rep._on_seal_view(peer, ("SEAL_VIEW", 3))
    assert (st.view, st.seal_view) == before
    # wrong-epoch NEW_VIEW: rejected as well
    rep._on_new_view(peer, ("NEW_VIEW", {}, 7))
    assert st.new_view is None
    # current-epoch SEAL_VIEW is processed
    rep._on_seal_view(peer, ("SEAL_VIEW", 3, 1))
    assert st.view == 3


def test_retired_replica_stream_is_dead():
    c = _cluster(seed=2)
    rep = c.replicas[0]
    rep.pending_membership[1] = ("r2", "rX")
    rep._ensure_participant("rX")
    rep._apply_membership(1, "r2", "rX", slot=-1)
    assert "r2" in rep.retired
    fifo_before = rep.state["r2"].fifo_next
    rep._ctb_deliver("r2", fifo_before, ("SEAL_VIEW", 1, 1))
    assert rep.state["r2"].fifo_next == fifo_before  # nothing interpreted
    # votes signed by the retired pid no longer count anywhere
    rep._on_will_certify("r2", "cons/WILL_CERTIFY", 0, (0, 0))
    assert not rep.will_certify.get((0, 0))


# --------------------------------------------------------------------------
# Non-voting joiner
# --------------------------------------------------------------------------
def test_joiner_votes_do_not_count_and_joiner_does_not_vote():
    c = _cluster(seed=4)
    rep = c.replicas[0]
    # votes from a pid outside the current epoch are never counted
    rep._on_will_certify("r9", "cons/WILL_CERTIFY", 0, (0, 0))
    rep._on_will_commit("r9", "cons/WILL_COMMIT", 0, (0, 0))
    assert not rep.will_certify.get((0, 0))
    assert not rep.will_commit.get((0, 0))
    # echoes from non-members do not count toward the echo quorum
    rep._note_echo(("rid", 0), "r9")
    assert ("rid", 0) not in rep.echoes

    # a joiner itself never promises / certifies / seals
    c.replicas[2].crash()
    joiner = c.replace_replica("r2")
    msgs_before = c.net.msgs_sent
    joiner._endorse(0, 0)
    joiner._do_certify(0, 0)
    joiner.change_view()
    assert joiner.my_will_certifies == set()
    assert joiner.my_certified == set()
    assert not joiner.changing_view and joiner.view == 0
    assert c.net.msgs_sent == msgs_before  # cast no vote on the wire


def test_joiner_cannot_complete_quorums_before_epoch_commit():
    """A quorum of f+1 over {survivor, joiner} must NOT form: the joiner's
    share is refused, so only current-epoch members can decide."""
    c = _cluster(seed=6)
    rep = c.replicas[0]
    c.replicas[2].crash()
    joiner = c.replace_replica("r2")
    # before the epoch commit the joiner is not in anyone's member set
    assert joiner.pid not in rep._member_set
    rep._on_certify_summary(joiner.pid, (7, b"x", b"sig"))
    assert joiner.pid not in rep.summary_sigs.get(7, {})
    rep._on_crtfy_vc(joiner.pid, (1, "r0", b"d", b"s"))
    assert (1, "r0") not in rep.vc_shares


# --------------------------------------------------------------------------
# Acceptance: replacement under load with a Byzantine leader in the window
# --------------------------------------------------------------------------
def _equivocate_leader(leader, f1, f2):
    """The leader equivocates below CTBcast: conflicting PREPAREs for one
    slot to different followers (the existing Byzantine-leader rig),
    stitched into its live stream position so it happens mid-run."""
    v, s, k = leader.view, leader.next_slot, leader.my_ctb.next_k
    reqA = (("evil", s), "", b"")
    reqB = (("evil", s), "", b"\x01")
    mA = ("PREPARE", v, s, reqA)
    mB = ("PREPARE", v, s, reqB)
    stream = leader.my_ctb._s_lock
    leader.tb.broadcast(stream, k, mA, [leader.pid, f1])
    leader.tb.broadcast(stream, k, mB, [f2])
    # keep the Byzantine stream position consistent for later broadcasts
    leader.my_ctb.buf[k] = mA
    leader.my_ctb.next_k = max(leader.my_ctb.next_k, k + 1)
    leader.ctb_k = max(leader.ctb_k, k + 1)
    leader.next_slot = s + 1
    leader.my_ctb.escalate(k)   # push one variant through the slow path


@pytest.mark.slow
def test_replacement_under_load_with_byzantine_leader():
    c = _cluster(seed=7)
    sim = c.sim
    cl = c.new_client()
    acked = {}
    _run_kv(c, cl, 0, 4, acked)

    peak = {"bytes": 0}
    handle = sim.periodic(50.0, lambda: peak.__setitem__(
        "bytes", max(peak["bytes"],
                     max(p.memory_bytes() for p in c.pools))))

    sim.at(sim.now + 300.0, lambda: c.replicas[2].crash())
    sim.at(sim.now + 600.0,
           lambda: _equivocate_leader(c.replicas[0], "r1", "r2"))
    sim.at(sim.now + 900.0, lambda: c.replace_replica("r2"))

    _run_kv(c, cl, 4, 16, acked)
    sim.run(until=sim.now + 120_000)
    handle.cancel()

    live = [r for r in c.replicas if not r.crashed]
    assert len(live) == 3                       # joiner replaced the crash
    assert all(r.membership.epoch == 1 for r in live)
    # safety + liveness: every acked write on every current-epoch replica
    _assert_converged(c, acked)
    # the equivocated slot never decided two ways across live replicas
    evil = {}
    for r in live:
        for s, batch in r.decided.items():
            if any(isinstance(x[0], tuple) and x[0][:1] == ("evil",)
                   for x in batch):
                evil.setdefault(s, set()).add(crypto.encode(batch))
    assert all(len(variants) == 1 for variants in evil.values())
    # Table 2: < 1 MiB per pool *throughout* the transfer
    assert peak["bytes"] < POOL_MEMORY_BUDGET
