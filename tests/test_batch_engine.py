"""Batched event core + vectorized crypto: batch ≡ scalar, bit-for-bit.

Every batch API added for the engine-floor work must be *observationally
identical* to the scalar loop it replaced — same bytes, same verdicts,
same ``(time, seq)`` execution order.  These tests pin that contract:

* ``fingerprint_batch`` / ``checksum_batch`` / ``sign_batch`` /
  ``verify_batch`` ≡ their scalar forms across shapes, lengths, empty
  and singleton batches, on every backend;
* the lane-wise numpy SHA-256 matches hashlib across message-schedule
  block boundaries (the padding edge cases live at 55/56/63/64/119/120);
* the Pallas attestation kernel matches the numpy Weyl reference;
* ``registers._unpack_batch`` ≡ ``_unpack`` including corrupt blobs;
* ``Simulator.push_run`` / ``NetworkModel.send_fanout`` preserve the
  exact event order and jitter stream of n individual sends;
* ``Cluster.stats()["engine"]`` proves the batched paths run hot.

Hypothesis deepens the sweep when installed; the explicit cases below
cover the boundaries regardless.
"""

import struct

import numpy as np
import pytest

from repro.core import crypto
from repro.core.registers import _pack, _unpack, _unpack_batch
from repro.sim.events import Process, Simulator
from repro.sim.net import NetParams, NetworkModel

# -- edge-length corpus: SHA-256 pads to 64 B blocks with 9 B overhead, so
# the interesting lengths straddle 55/56 (1 vs 2 blocks) and 119/120.
EDGE_LENGTHS = [0, 1, 3, 31, 32, 54, 55, 56, 57, 63, 64, 65,
                118, 119, 120, 121, 127, 128, 129, 200, 1000]


def _corpus():
    rng = np.random.default_rng(42)
    return [bytes(rng.integers(0, 256, size=n, dtype=np.uint8).tobytes())
            for n in EDGE_LENGTHS]


# ---------------------------------------------------------------- digests
@pytest.mark.parametrize("backend", ["hashlib", "numpy", None])
def test_fingerprint_batch_equals_scalar(backend):
    datas = _corpus()
    want = [crypto.fingerprint(d) for d in datas]
    assert crypto.fingerprint_batch(datas, backend=backend) == want


@pytest.mark.parametrize("backend", ["hashlib", "numpy"])
def test_fingerprint_batch_empty_and_singleton(backend):
    assert crypto.fingerprint_batch([], backend=backend) == []
    one = [b"solo"]
    assert crypto.fingerprint_batch(one, backend=backend) == \
        [crypto.fingerprint(b"solo")]


def test_numpy_sha256_across_block_counts():
    # mixed batch: 1-block, 2-block, 3-block and 17-block lanes together —
    # the short-lane freeze (np.where) must not corrupt longer lanes
    datas = [b"a" * 10, b"b" * 100, b"c" * 170, b"d" * 1050]
    import hashlib
    assert crypto._sha256_batch_np(datas) == \
        [hashlib.sha256(d).digest() for d in datas]


def test_fingerprint_batch_cached_writes_back_and_hits():
    objs = [("win", i, b"x" * i) for i in range(6)]
    fresh = crypto.fingerprint_batch_cached(objs)
    assert fresh == [crypto.fingerprint_cached(o) for o in objs]
    before = crypto.digest_stats()["batch_fingerprint_hits"]
    again = crypto.fingerprint_batch_cached(objs)
    assert again == fresh
    assert crypto.digest_stats()["batch_fingerprint_hits"] >= before + len(objs)


def test_checksum_batch_equals_scalar():
    datas = _corpus()
    assert crypto.checksum_batch(datas) == [crypto.checksum(d) for d in datas]
    assert crypto.checksum_bytes_batch(datas) == \
        [crypto.checksum_bytes(d) for d in datas]
    assert crypto.checksum_batch([]) == []
    assert crypto.checksum_bytes_batch([]) == []


def test_wire_size_and_encode_batch_equal_scalar():
    objs = [(), (1,), ("REQ", b"x" * 9, 3.5), ((1, 2), (b"n", -7)), b"raw"]
    assert crypto.wire_size_batch(objs) == \
        [crypto.wire_size_cached(o) for o in objs]
    assert crypto.encode_batch_cached(objs) == \
        [crypto.encode_cached(o) for o in objs]


# ------------------------------------------------------------------- MACs
def test_sign_and_verify_batch_equal_scalar():
    reg = crypto.KeyRegistry()
    s1 = reg.keygen("p1")
    s2 = reg.keygen("p2")
    payloads = [("certify", v, v * 7, b"fp" * 8) for v in range(5)]
    sigs = s1.sign_batch(payloads)
    assert sigs == [s1.sign(p) for p in payloads]

    items = [("p1", p, sig) for p, sig in zip(payloads, sigs)]
    # forgery: p2's MAC over the same payload must not verify as p1's
    items.append(("p1", payloads[0], s2.sign(payloads[0])))
    # tamper: valid MAC, different payload
    items.append(("p1", ("certify", 99, 0, b"zz"), sigs[0]))
    got = reg.verify_batch(items)
    assert got == [reg.verify(pid, p, sig) for pid, p, sig in items]
    assert got == [True] * 5 + [False, False]
    assert reg.verify_batch([]) == []


# ------------------------------------------------- attestation (Pallas)
def test_attest_batch_numpy_reference():
    arrays = [np.arange(n, dtype=np.uint32) for n in (0, 1, 7, 4096, 5000)]
    got = crypto.attest_batch(arrays, backend="numpy")
    for a, g in zip(arrays, got):
        # independent scalar reference of the Weyl mix
        acc = 0
        for w in a.tolist():
            acc = (acc + (((w * crypto.MIX32) & 0xFFFFFFFF) ^ (w >> 16))) \
                & 0xFFFFFFFF
        assert g == acc


@pytest.mark.slow
def test_attest_batch_pallas_parity():
    pytest.importorskip("jax")
    rng = np.random.default_rng(7)
    arrays = [rng.integers(0, 2**32, size=n, dtype=np.uint32)
              for n in (1, 5, 4096, 4097, 10_000)] + \
        [np.zeros(0, dtype=np.uint32)]
    assert crypto.attest_batch(arrays, backend="pallas") == \
        crypto.attest_batch(arrays, backend="numpy")


# --------------------------------------------------- register validation
def test_unpack_batch_equals_scalar():
    good = [_pack(ts, bytes([ts]) * ts) for ts in range(5)]
    corrupt = good[2][:8] + b"\x00" + good[2][9:]       # checksum mismatch
    short = good[1][:10]                                 # below BLOB_HEADER
    truncated = good[3][:-1]                             # ln > len(value)
    blobs = good + [corrupt, short, truncated, None, b""]
    assert _unpack_batch(blobs) == [_unpack(b) for b in blobs]
    assert _unpack_batch([]) == []


# ------------------------------------------------------- event-core order
def test_push_run_preserves_time_seq_order():
    sim = Simulator(seed=0)
    order = []
    sim.at(1.0, lambda: order.append("before"))
    # a same-timestamp scalar event pushed BEFORE the run must sort first,
    # one pushed AFTER must sort after the whole run
    sim.at(2.0, lambda: order.append("a"))
    sim.push_run(2.0, [lambda: order.append("r1"),
                       lambda: order.append("r2"),
                       lambda: order.append("r3")])
    sim.at(2.0, lambda: order.append("z"))
    sim.run()
    assert order == ["before", "a", "r1", "r2", "r3", "z"]
    # each run member counts as one event, like n individual pushes
    assert sim.events_processed == 6


def test_push_run_respects_until_and_pred():
    sim = Simulator(seed=0)
    order = []
    sim.push_run(5.0, [lambda i=i: order.append(i) for i in range(3)])
    sim.run(until=4.0)
    assert order == [] and sim.now == 4.0
    hit = sim.run_until(lambda: len(order) >= 3, timeout=100.0)
    assert hit and order == [0, 1, 2]


class _Sink(Process):
    def __init__(self, sim, pid, log):
        super().__init__(sim, pid)
        self.log = log

    def on_message(self, src, msg):
        self.log.append((self.pid, src, msg, self.sim.now))


def _fanout_rig(sigma):
    sim = Simulator(seed=123)
    net = NetworkModel(sim, NetParams(jitter_sigma=sigma))
    log = []
    for i in range(4):
        _Sink(sim, f"p{i}", log)
    return sim, net, log


@pytest.mark.parametrize("sigma", [0.0, 0.08])
def test_send_fanout_bit_identical_to_scalar_sends(sigma):
    dsts = ["p0", "p1", "p2", "p3"]
    runs = []
    for use_fanout in (False, True):
        sim, net, log = _fanout_rig(sigma)
        sim.processes["p2"].crash()       # crashed dst: jitter still drawn
        if use_fanout:
            net.send_fanout("p0", dsts, ("M", 1), 64)
        else:
            for d in dsts:
                net.send("p0", d, ("M", 1), 64)
        net.send("p0", "p1", ("TAIL", 2), 32)  # stream must stay aligned
        sim.run()
        runs.append((log, net.msgs_sent, net.bytes_sent, sim.events_processed))
    assert runs[0] == runs[1]


def test_send_fanout_coalesces_at_zero_jitter():
    sim, net, log = _fanout_rig(0.0)
    net.send_fanout("p0", ["p1", "p2", "p3"], "hi", 10)
    assert net.coalesced_runs == 1 and net.fanout_msgs == 3
    sim.run()
    assert [e[0] for e in log] == ["p1", "p2", "p3"]
    assert len({e[3] for e in log}) == 1      # one shared arrival timestamp


def test_send_fanout_falls_back_on_link_state():
    sim, net, log = _fanout_rig(0.0)
    net.partition("p0", "p1", forced=True)
    net.send_fanout("p0", ["p1", "p2"], "hi", 10)
    assert net.fanout_msgs == 0               # scalar fallback path
    sim.run()
    assert [e[0] for e in log] == ["p2"]


# ------------------------------------------------------ end-to-end proof
def test_cluster_stats_expose_hot_batch_counters():
    from repro.apps.flip import FlipApp
    from repro.core.consensus import ConsensusConfig
    from repro.core.smr import build_cluster
    crypto.reset_digest_stats()
    c = build_cluster(FlipApp, cfg=ConsensusConfig(max_batch=4,
                                                   pipeline_depth=2))
    cl = c.new_client()
    done = {"n": 0}

    # enough slots to cross a certify-summary window (t/2 slots) — that is
    # where the batched fingerprint path runs
    target = c.replicas[0].cfg.t // 2 + 8

    def cb(_res, _lat):
        done["n"] += 1
        if done["n"] < target:
            cl.request(b"x" * 16, cb)

    cl.request(b"x" * 16, cb)
    assert c.sim.run_until(lambda: done["n"] >= target, timeout=1_000_000.0)
    eng = c.stats()["engine"]
    assert eng["net"]["fanout_msgs"] > 0
    assert eng["net"]["msgs_sent"] >= eng["net"]["fanout_msgs"]
    assert eng["digests"]["batch_fingerprint_items"] > 0
    assert eng["digests"]["wire_cache_hits"] > 0
    assert eng["events_processed"] == c.sim.events_processed > 0


# ------------------------------------------------- hypothesis deep sweep
def test_property_batch_digests_match_scalar():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.binary(max_size=300), max_size=12),
           st.sampled_from(["hashlib", "numpy"]))
    def check(datas, backend):
        assert crypto.fingerprint_batch(datas, backend=backend) == \
            [crypto.fingerprint(d) for d in datas]
        assert crypto.checksum_batch(datas) == \
            [crypto.checksum(d) for d in datas]

    check()


def test_property_verify_batch_matches_scalar():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    reg = crypto.KeyRegistry()
    signer = reg.keygen("q")

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.tuples(st.binary(max_size=40), st.booleans()),
                    max_size=8))
    def check(specs):
        items = []
        for payload, valid in specs:
            sig = signer.sign(payload) if valid else b"\x00" * 16
            items.append(("q", payload, sig))
        assert reg.verify_batch(items) == \
            [reg.verify(pid, p, s) for pid, p, s in items]

    check()
