"""Distribution correctness: the sharded train step on a small mesh produces
the same numbers as the unsharded one (run in a subprocess so the test
session keeps its single-device view)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, sys.argv[1])
    import jax, jax.numpy as jnp, numpy as np, json
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.models.common import init_params
    from repro.models.transformer import lm_loss
    from repro.parallel.sharding import (batch_pspecs, param_pspecs,
                                         shard_ctx_for_mesh)

    out = {}
    for arch in ("qwen3-8b", "qwen3-moe-235b-a22b", "recurrentgemma-2b"):
        cfg = get_smoke_config(arch)
        if cfg.frontend:
            continue
        params = init_params(cfg, jax.random.PRNGKey(0))
        B, S = 4, 16
        inputs = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
        targets = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)

        loss_ref = float(jax.jit(lambda p: lm_loss(cfg, p, inputs, targets))(params))

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ctx = shard_ctx_for_mesh(mesh)
        pspecs = param_pspecs(cfg, params, mesh)
        named = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                             is_leaf=lambda x: isinstance(x, P))
        params_sh = jax.tree.map(jax.device_put, params, named)
        loss_sh = float(jax.jit(
            lambda p, i, t: lm_loss(cfg, p, i, t, ctx),
            in_shardings=(named,
                          NamedSharding(mesh, P(("data",))),
                          NamedSharding(mesh, P(("data",)))),
        )(params_sh, inputs, targets))
        out[arch] = (loss_ref, loss_sh)
    print("RESULT:" + json.dumps(out))
""")


@pytest.mark.slow
def test_sharded_loss_matches_unsharded(tmp_path):
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = tmp_path / "sharded_check.py"
    script.write_text(SCRIPT)
    proc = subprocess.run(
        [sys.executable, str(script), src],
        capture_output=True, text=True, timeout=1200,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout
    out = json.loads(line[0][len("RESULT:"):])
    assert out, "no archs checked"
    for arch, (ref, sh) in out.items():
        assert abs(ref - sh) < 0.05 + 0.02 * abs(ref), (
            f"{arch}: sharded loss {sh} != unsharded {ref}")
