"""Tail Broadcast: FIFO delivery, tail eviction, retransmission under loss."""

import pytest

from repro.core import crypto
from repro.core.node import Node
from repro.core.tbcast import TBcastService
from repro.sim.events import Simulator
from repro.sim.net import NetworkModel


class TBNode(Node):
    def __init__(self, sim, net, reg, pid, t=8):
        super().__init__(sim, net, reg, pid)
        self.tb = TBcastService(self, t=t)
        self.got = []
        self.tb.register("s/", lambda o, st, k, m: self.got.append((o, k, m)))


def rig(n=3, t=8, seed=0):
    sim = Simulator(seed=seed)
    net = NetworkModel(sim)
    reg = crypto.KeyRegistry()
    nodes = [TBNode(sim, net, reg, f"n{i}", t=t) for i in range(n)]
    return sim, net, nodes


def test_fifo_order():
    sim, net, nodes = rig()
    group = [n.pid for n in nodes]
    for k in range(20):
        nodes[0].tb.broadcast("s/x", k, f"m{k}".encode(), group)
    sim.run(until=50000)
    for n in nodes:
        ks = [k for (_o, k, _m) in n.got]
        assert ks == sorted(ks), "FIFO violated"
        assert ks[-1] == 19


def test_delivery_under_message_loss():
    sim, net, nodes = rig()
    group = [n.pid for n in nodes]
    net.partition("n0", "n1")       # drop everything n0->n1 until GST
    sim.gst = 500.0
    for k in range(5):
        nodes[0].tb.broadcast("s/x", k, f"m{k}".encode(), group)
    sim.run(until=100000)
    ks1 = [k for (_o, k, _m) in nodes[1].got]
    assert ks1 == [0, 1, 2, 3, 4], f"retransmission failed: {ks1}"


def test_tail_eviction_skips_old_messages():
    """With a backlog > 2t while partitioned, old messages are overwritten;
    the receiver skips ahead and still delivers the tail FIFO."""
    t = 4
    sim, net, nodes = rig(t=t)
    group = [n.pid for n in nodes]
    net.partition("n0", "n1")
    sim.gst = 2000.0
    for k in range(20):                 # 20 > 2t = 8: old ones evicted
        nodes[0].tb.broadcast("s/x", k, f"m{k}".encode(), group)
    sim.run(until=200000)
    ks1 = [k for (_o, k, _m) in nodes[1].got]
    assert ks1 == sorted(ks1)
    assert set(range(12, 20)).issubset(set(ks1)), f"tail not delivered: {ks1}"
    assert 0 not in ks1                 # head was evicted, not retransmitted


def test_sender_window_bounded():
    t = 4
    sim, net, nodes = rig(t=t)
    group = [n.pid for n in nodes]
    net.partition("n0", "n1")
    net.partition("n0", "n2")
    sim.gst = 1e9   # never heals
    for k in range(100):
        nodes[0].tb.broadcast("s/x", k, b"x" * 64, group)
    sim.run(until=5000)
    for st in nodes[0].tb._send.values():
        assert len(st.window) <= 2 * t


def test_memory_accounting_scales_with_t():
    sim, net, nodes = rig(t=8)
    group = [n.pid for n in nodes]
    nodes[0].tb.broadcast("s/x", 0, b"m", group)
    m8 = nodes[0].tb.memory_bytes()
    sim2, net2, nodes2 = rig(t=16)
    nodes2[0].tb.broadcast("s/x", 0, b"m", [n.pid for n in nodes2])
    m16 = nodes2[0].tb.memory_bytes()
    assert m16 == 2 * m8
