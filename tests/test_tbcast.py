"""Tail Broadcast: FIFO delivery, tail eviction, retransmission under loss."""

import pytest

from repro.core import crypto
from repro.core.node import Node
from repro.core.tbcast import TBcastService
from repro.sim.events import Simulator
from repro.sim.net import NetworkModel


class TBNode(Node):
    def __init__(self, sim, net, reg, pid, t=8):
        super().__init__(sim, net, reg, pid)
        self.tb = TBcastService(self, t=t)
        self.got = []
        self.tb.register("s/", lambda o, st, k, m: self.got.append((o, k, m)))


def rig(n=3, t=8, seed=0):
    sim = Simulator(seed=seed)
    net = NetworkModel(sim)
    reg = crypto.KeyRegistry()
    nodes = [TBNode(sim, net, reg, f"n{i}", t=t) for i in range(n)]
    return sim, net, nodes


def test_fifo_order():
    sim, net, nodes = rig()
    group = [n.pid for n in nodes]
    for k in range(20):
        nodes[0].tb.broadcast("s/x", k, f"m{k}".encode(), group)
    sim.run(until=50000)
    for n in nodes:
        ks = [k for (_o, k, _m) in n.got]
        assert ks == sorted(ks), "FIFO violated"
        assert ks[-1] == 19


def test_delivery_under_message_loss():
    sim, net, nodes = rig()
    group = [n.pid for n in nodes]
    net.partition("n0", "n1")       # drop everything n0->n1 until GST
    sim.gst = 500.0
    for k in range(5):
        nodes[0].tb.broadcast("s/x", k, f"m{k}".encode(), group)
    sim.run(until=100000)
    ks1 = [k for (_o, k, _m) in nodes[1].got]
    assert ks1 == [0, 1, 2, 3, 4], f"retransmission failed: {ks1}"


def test_tail_eviction_skips_old_messages():
    """With a backlog > 2t while partitioned, old messages are overwritten;
    the receiver skips ahead and still delivers the tail FIFO."""
    t = 4
    sim, net, nodes = rig(t=t)
    group = [n.pid for n in nodes]
    net.partition("n0", "n1")
    sim.gst = 2000.0
    for k in range(20):                 # 20 > 2t = 8: old ones evicted
        nodes[0].tb.broadcast("s/x", k, f"m{k}".encode(), group)
    sim.run(until=200000)
    ks1 = [k for (_o, k, _m) in nodes[1].got]
    assert ks1 == sorted(ks1)
    assert set(range(12, 20)).issubset(set(ks1)), f"tail not delivered: {ks1}"
    assert 0 not in ks1                 # head was evicted, not retransmitted


def test_sender_window_bounded():
    t = 4
    sim, net, nodes = rig(t=t)
    group = [n.pid for n in nodes]
    net.partition("n0", "n1")
    net.partition("n0", "n2")
    sim.gst = 1e9   # never heals
    for k in range(100):
        nodes[0].tb.broadcast("s/x", k, b"x" * 64, group)
    sim.run(until=5000)
    for st in nodes[0].tb._send.values():
        assert len(st.window) <= 2 * t


def _count_tb(net, sim, counts):
    """Wrap net.send to record (time, src, dst) of every TB frame."""
    orig = net.send

    def counting_send(src, dst, msg, size):
        if msg[0] == "TB":
            counts.append((sim.now, src, dst))
        return orig(src, dst, msg, size)

    net.send = counting_send


def test_receiver_crash_recover_reacks_and_quiesces():
    """A receiver that crashes with an ack pending must ack again after
    recovery: the stranded ack_pending flag used to make every live sender
    retransmit its window to the returned replica forever."""
    sim, net, nodes = rig(n=2)
    counts = []
    _count_tb(net, sim, counts)
    group = [n.pid for n in nodes]
    for k in range(5):
        nodes[0].tb.broadcast("s/x", k, f"m{k}".encode(), group)
    assert sim.run_until(lambda: len(nodes[1].got) >= 5, timeout=5000)
    # crash inside the ack window: the coarse ack timer is still pending
    assert any(rs.ack_pending for rs in nodes[1].tb._recv.values())
    nodes[1].crash()
    sim.run(until=6000)     # sender retransmits into the void meanwhile
    assert any(t > 3000 for (t, s, d) in counts if s == "n0" and d == "n1"), \
        "test premise broken: no retransmission while receiver was down"
    nodes[1].recover()
    sim.run(until=8000)
    st = nodes[0].tb._send[("s/x", "n1")]
    assert not any(k > st.acked for k in st.window), \
        "sender window never acked after receiver recovery"
    late = [t for (t, s, d) in counts if s == "n0" and d == "n1" and t > 8000]
    sim.run(until=20000)
    late = [t for (t, s, d) in counts if s == "n0" and d == "n1" and t > 8000]
    assert late == [], f"retransmission did not quiesce: {late[:5]}"


def test_sender_crash_recover_rearms_rto():
    """A sender that crashes while its RTO is pending must re-arm it on
    recovery: its unacked window entries were only ever retransmitted again
    if a fresh broadcast happened to land on the same stream."""
    sim, net, nodes = rig(n=2)
    nodes[1].crash()        # receiver down: no acks, RTO keeps the window
    for k in range(3):
        nodes[0].tb.broadcast("s/x", k, f"m{k}".encode(),
                              [n.pid for n in nodes])
    st = nodes[0].tb._send[("s/x", "n1")]
    assert st.rto_pending
    nodes[0].crash()        # the pending RTO fire lands inside the crash
    sim.run(until=1000)
    assert not st.rto_pending, "flag reset must survive the crash window"
    nodes[1].recover()
    sim.run(until=2000)
    nodes[0].recover()      # recover hook re-arms the RTO for the window
    sim.run(until=30000)
    ks = sorted(k for (_o, k, _m) in nodes[1].got)
    assert ks == [0, 1, 2], f"stranded sender never retransmitted: {ks}"


def test_rto_backoff_decays_and_resets_on_ack():
    """Retransmission to an unresponsive peer decays exponentially
    (bounded), and any ack progress snaps the interval back to rto_us."""
    sim, net, nodes = rig(n=2)
    counts = []
    _count_tb(net, sim, counts)
    nodes[1].crash()
    nodes[0].tb.broadcast("s/x", 0, b"m0", [n.pid for n in nodes])
    sim.run(until=40000)
    rto = nodes[0].tb.rto_us
    cap = rto * (1 << nodes[0].tb.rto_backoff_max)
    early = [t for (t, s, d) in counts if d == "n1" and t <= 1000]
    late = [t for (t, s, d) in counts if d == "n1" and 20000 < t <= 40000]
    assert len(early) >= 4, f"early retransmission too sparse: {early}"
    assert len(late) <= 20000 / cap + 2, \
        f"late retransmission did not decay: {len(late)} sends in 20ms"
    st = nodes[0].tb._send[("s/x", "n1")]
    assert st.backoff == nodes[0].tb.rto_backoff_max
    nodes[1].recover()
    assert sim.run_until(lambda: len(nodes[1].got) == 1, timeout=300000)
    sim.run(until=sim.now + 200)    # let the coarse ack land
    assert st.backoff == 0, "ack progress must reset the backoff"
    # a fresh broadcast after the reset retransmits at full cadence again
    nodes[1].crash()
    t0 = sim.now
    nodes[0].tb.broadcast("s/x", 1, b"m1", [n.pid for n in nodes])
    sim.run(until=t0 + 1000)
    fresh = [t for (t, s, d) in counts if d == "n1" and t > t0]
    assert len(fresh) >= 4, f"backoff reset ineffective: {fresh}"


def test_memory_accounting_scales_with_t():
    sim, net, nodes = rig(t=8)
    group = [n.pid for n in nodes]
    nodes[0].tb.broadcast("s/x", 0, b"m", group)
    m8 = nodes[0].tb.memory_bytes()
    sim2, net2, nodes2 = rig(t=16)
    nodes2[0].tb.broadcast("s/x", 0, b"m", [n.pid for n in nodes2])
    m16 = nodes2[0].tb.memory_bytes()
    assert m16 == 2 * m8
