"""Per-architecture smoke tests (deliverable f): every assigned arch at a
reduced same-family config runs one train step and one prefill+decode step
on CPU, asserting output shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, list_archs
from repro.models.common import init_params, params_count
from repro.models.transformer import decode_step, lm_loss, prefill
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.runtime.steps import make_train_step

pytestmark = pytest.mark.slow

ARCHS = list_archs()
B, S = 2, 24


def _inputs(cfg, key):
    if cfg.frontend:
        return jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    return jax.random.randint(key, (B, S), 0, cfg.vocab)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_is_exact_assignment(arch):
    cfg = get_config(arch)
    cfg.validate()
    spec = {
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == spec


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt = adamw_init(params, AdamWConfig())
    step = jax.jit(make_train_step(cfg))
    batch = {"inputs": _inputs(cfg, key),
             "targets": jax.random.randint(jax.random.PRNGKey(1), (B, S),
                                           0, cfg.vocab)}
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    if cfg.attest:
        assert int(metrics["grad_fp"]) != 0
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_then_decode(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    inputs = _inputs(cfg, key)
    logits, caches = jax.jit(
        lambda p, i: prefill(cfg, p, i, max_seq=S + 8))(params, inputs)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    dstep = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
    for i in range(3):
        logits, caches = dstep(params, caches, tok, jnp.int32(S + i))
        assert logits.shape == (B, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


def test_train_loss_decreases_small_model():
    """A few steps of real training on the structured pipeline reduce loss."""
    from repro.data import DataConfig, TokenPipeline
    cfg = get_smoke_config("qwen3-8b")
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=32,
                                    global_batch=8, seed=3))
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params, AdamWConfig(lr=3e-3))
    step = jax.jit(make_train_step(cfg, opt_cfg=AdamWConfig(lr=3e-3)))
    losses = []
    for i in range(30):
        b = pipe.global_batch(i)
        params, opt, m = step(params, opt, {k: jnp.asarray(v)
                                            for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[:3] + losses[-3:]
