"""Seeded golden-trace scenarios for the determinism invariant.

The wire-cache / event-engine work (ISSUE 3) promises *faster but
bit-identical*: a seeded run must produce exactly the same simulated
results (latencies, message counts, byte counts) before and after any
engine refactor.  This module defines the scenarios and their digests;
``tests/golden/golden_traces.json`` holds digests recorded on the
pre-refactor tree.  ``test_golden_determinism.py`` re-runs every scenario
and asserts digest equality, making the invariant enforced rather than
hoped for.

Re-record (only when a change *intentionally* alters simulated results,
e.g. a new cost model — say so in the commit message):

    PYTHONPATH=src python tests/golden_scenarios.py --record
"""

from __future__ import annotations

import hashlib
import json
import os
import struct
from typing import Dict, List

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "golden_traces.json")


def _digest(floats: List[float], ints: List[int]) -> str:
    """Bit-exact digest: doubles packed verbatim, then counters."""
    h = hashlib.sha256()
    for x in floats:
        h.update(struct.pack("<d", x))
    for x in ints:
        h.update(struct.pack("<q", x))
    return h.hexdigest()


def _pcts(lats: List[float]):
    if not lats:
        return 0.0, 0.0
    s = sorted(lats)
    return s[len(s) // 2], s[min(len(s) - 1, int(len(s) * 0.99))]


def _closed_loop(sim, clients, payload: bytes, until_us: float) -> List[float]:
    lats: List[float] = []

    def refire(cl):
        def cb(_res, lat):
            lats.append(lat)
            cl.request(payload, cb)
        return cb

    for cl in clients:
        cl.request(payload, refire(cl))
    sim.run(until=until_us)
    return lats


def scenario_throughput_mini() -> dict:
    """Batched+pipelined fast path under closed-loop load (jitter stream,
    wire sizing, CTBcast fast path)."""
    from repro.apps.flip import FlipApp
    from repro.core.consensus import ConsensusConfig
    from repro.core.smr import build_cluster

    cfg = ConsensusConfig(max_batch=8, pipeline_depth=4)
    cluster = build_cluster(FlipApp, cfg=cfg, seed=1234)
    clients = [cluster.new_client() for _ in range(8)]
    lats = _closed_loop(cluster.sim, clients, b"x" * 32, 4000.0)
    p50, p99 = _pcts(lats)
    return {
        "digest": _digest(lats, [cluster.net.msgs_sent,
                                 cluster.net.bytes_sent]),
        "n": len(lats), "p50_us": p50, "p99_us": p99,
        "msgs_sent": cluster.net.msgs_sent,
        "bytes_sent": cluster.net.bytes_sent,
    }


def scenario_slow_path() -> dict:
    """Signature slow path over disaggregated registers (async crypto,
    register WRITE/READ, checksum packing)."""
    from repro.apps.flip import FlipApp
    from repro.core.consensus import ConsensusConfig
    from repro.core.smr import build_cluster

    cfg = ConsensusConfig(slow_mode="always")
    cluster = build_cluster(FlipApp, cfg=cfg, seed=7)
    client = cluster.new_client()
    lats = []
    for i in range(10):
        _res, lat = cluster.run_request(client, bytes([i]) * (8 + i))
        lats.append(lat)
    return {
        "digest": _digest(lats, [cluster.net.msgs_sent,
                                 cluster.net.bytes_sent]),
        "n": len(lats),
        "msgs_sent": cluster.net.msgs_sent,
        "bytes_sent": cluster.net.bytes_sent,
    }


def scenario_mu_baseline() -> dict:
    """Mu baseline: its leader draws jitter from the *same* seeded stream
    as the network model — guards the shared-draw-order invariant."""
    from repro.apps.flip import FlipApp
    from repro.baselines.mu import build_mu

    sim, client = build_mu(FlipApp, seed=42)
    lats = _closed_loop(sim, [client], b"y" * 64, 3000.0)
    return {"digest": _digest(lats, []), "n": len(lats)}


def scenario_faults_reconfig() -> dict:
    """Lease-driven pool machinery + a seeded fault schedule (periodic
    timer coalescing must not move lease/suspicion timing)."""
    from repro.apps.kvstore import KVStoreApp, set_req
    from repro.core.consensus import ConsensusConfig
    from repro.core.smr import build_cluster
    from repro.sim.faults import FaultInjector, FaultSchedule

    cfg = ConsensusConfig(slow_mode="always")
    c = build_cluster(KVStoreApp, cfg=cfg, seed=3, n_pools=2,
                      auto_reconfigure=True, lease_us=200.0)
    sched = FaultSchedule.seeded(3, horizon_us=3000.0, memory=["m0"],
                                 pools=c.pools, n_memory_crashes=1,
                                 reconfigure=True)
    FaultInjector.for_cluster(c, sched)
    client = c.new_client()
    lats = []
    for i in range(8):
        _res, lat = c.run_request(client, set_req(b"k%d" % i, b"v" * 16),
                                  timeout=5_000_000.0)
        lats.append(lat)
    c.sim.run(until=c.sim.now + 2000.0)
    recfg = [(t, d, f) for (t, d, f) in c.pools[0].reconfigurations]
    return {
        "digest": _digest(lats + [t for (t, _d, _f) in recfg],
                          [c.net.msgs_sent, c.net.bytes_sent, len(recfg)]),
        "n": len(lats),
        "reconfigurations": len(recfg),
        "msgs_sent": c.net.msgs_sent,
        "bytes_sent": c.net.bytes_sent,
    }


def scenario_shared_substrate() -> dict:
    """Two replicated applications on ONE substrate (shared pools), a
    mid-run pool reconfiguration underneath both, and one open-loop
    workload — gates the multi-app attach path, the app-namespaced
    register sharding, the seeded Poisson arrival process, and the per-app
    Table 2 accounting with one digest."""
    from repro.apps.kvstore import KVStoreApp, set_req
    from repro.core.consensus import ConsensusConfig
    from repro.scenario import AppSpec, ScenarioSpec, Workload, run_scenario
    from repro.sim.faults import FaultSchedule

    def cfg():
        return ConsensusConfig(t=16, window=16, slow_mode="always",
                               ctb_fast_enabled=False,
                               view_timeout_us=20_000.0)

    sched = (FaultSchedule()
             .add(700.0, "crash", "m1")
             .add(1500.0, "reconfigure", ("pool0", "m1")))
    spec = ScenarioSpec(
        n_pools=2, seed=11, faults=sched, drain_us=2000.0,
        apps=[
            AppSpec(name="A", app=KVStoreApp, cfg=cfg(),
                    workload=Workload(kind="closed", n_requests=10,
                                      payload_fn=lambda i: set_req(
                                          b"a%d" % (i % 4), b"v%d" % i),
                                      timeout_us=5_000_000.0)),
            AppSpec(name="B", app=KVStoreApp, cfg=cfg(),
                    workload=Workload(kind="open", rate_rps=6000.0,
                                      duration_us=2500.0,
                                      payload_fn=lambda i: set_req(
                                          b"b%d" % (i % 4), b"w%d" % i),
                                      seed=21, timeout_us=5_000_000.0)),
        ])
    res = run_scenario(spec)
    pool0 = res.substrate.pools[0]
    recfg_times = [t for (t, _d, _f) in pool0.reconfigurations]
    mem = [nbytes for name in ("A", "B")
           for _pool, nbytes in sorted(res.apps[name].memory_by_pool.items())]
    lats = res.apps["A"].latencies + res.apps["B"].latencies
    return {
        "digest": _digest(lats + recfg_times,
                          [res.msgs_sent, res.bytes_sent,
                           res.apps["B"].issued, len(recfg_times)] + mem),
        "n_a": len(res.apps["A"].latencies),
        "n_b": len(res.apps["B"].latencies),
        "reconfigurations": len(recfg_times),
        "msgs_sent": res.msgs_sent,
        "bytes_sent": res.bytes_sent,
        "mem_per_app_pool": mem,
    }


def scenario_replica_replacement() -> dict:
    """Membership epochs end to end: an open-loop app on the registers
    slow path, one replica crashed mid-run and *replaced* (non-voting
    install, xfer via the pools, permission rekey, agreed epoch bump),
    with the Byzantine leader equivocating one slot in the same window —
    gates the whole ISSUE 5 machinery with one digest."""
    from repro.apps.kvstore import KVStoreApp, set_req
    from repro.core.consensus import ConsensusConfig
    from repro.scenario import AppSpec, ScenarioSpec, Workload, run_scenario
    from repro.sim.faults import FaultSchedule

    def cfg():
        return ConsensusConfig(t=16, window=16, slow_mode="always",
                               ctb_fast_enabled=False,
                               view_timeout_us=20_000.0)

    def equivocate(cluster):
        """The leader sends conflicting PREPAREs for one slot to different
        followers below CTBcast (and pushes one variant through the slow
        path), stitched into its live stream position."""
        leader = cluster.replicas[0]
        v, s, k = leader.view, leader.next_slot, leader.my_ctb.next_k
        m_a = ("PREPARE", v, s, (("evil", s), "", b""))
        m_b = ("PREPARE", v, s, (("evil", s), "", b"\x01"))
        stream = leader.my_ctb._s_lock
        leader.tb.broadcast(stream, k, m_a, [leader.pid, "r1"])
        leader.tb.broadcast(stream, k, m_b, ["r2"])
        leader.my_ctb.buf[k] = m_a
        leader.my_ctb.next_k = max(leader.my_ctb.next_k, k + 1)
        leader.ctb_k = max(leader.ctb_k, k + 1)
        leader.next_slot = s + 1
        leader.my_ctb.escalate(k)

    def faults(substrate):
        sim = substrate.sim
        cluster = substrate.clusters[""]
        sim.at(600.0, lambda: equivocate(cluster))
        sim.at(1800.0, lambda: cluster.replace_replica("r2"))
        return FaultSchedule().add(900.0, "crash", "r2")

    spec = ScenarioSpec(
        n_pools=2, seed=17, faults=faults, drain_us=60_000.0,
        apps=[AppSpec(name="", app=KVStoreApp, cfg=cfg(),
                      workload=Workload(kind="open", rate_rps=5000.0,
                                        duration_us=3000.0,
                                        payload_fn=lambda i: set_req(
                                            b"g%d" % (i % 4), b"w%d" % i),
                                        seed=23,
                                        timeout_us=120_000_000.0))])
    res = run_scenario(spec)
    cluster = res.clusters[""]
    live = [r for r in cluster.replicas if not r.crashed]
    assert all(r.membership.epoch == 1 and not r.joining for r in live)
    switch_times = sorted(t for r in live for (t, _e, _o, _n)
                          in r.epoch_switches)
    rekeys = sum(len(p.rekeys) for p in res.substrate.pools)
    lats = res.apps[""].latencies
    return {
        "digest": _digest(lats + switch_times,
                          [res.msgs_sent, res.bytes_sent,
                           res.apps[""].issued, rekeys,
                           max(r.membership.epoch for r in live)]),
        "n": len(lats),
        "issued": res.apps[""].issued,
        "epoch_switches": len(switch_times),
        "rekeys": rekeys,
        "msgs_sent": res.msgs_sent,
        "bytes_sent": res.bytes_sent,
    }


def scenario_sharded_service() -> dict:
    """Sharded service plane (ISSUE 6): two uBFT groups behind the
    ShardRouter on one substrate, a Zipf-keyed workload mixing single-key
    SETs with cross-shard 2PC MSETs, and a participant replica crashed and
    recovered mid-run — gates the routing, the PREPARE/DECIDE/FINISH slot
    protocol, the presumed-abort recovery timers, and the keyed-workload
    sampler with one digest."""
    import zlib

    from repro.core.consensus import ConsensusConfig
    from repro.scenario import (ScenarioSpec, ServiceSpec, Workload,
                                run_scenario)
    from repro.sim.faults import FaultSchedule

    cfg = ConsensusConfig(t=16, window=16, slow_mode="always",
                          ctb_fast_enabled=False, view_timeout_us=20_000.0)

    def op(i, key):
        if i % 3 == 2:
            return ("mset", [(key, b"m%d" % i), (key + b"~", b"m%d" % i)])
        return ("set", key, b"v%d" % i)

    sched = (FaultSchedule()
             .add(800.0, "crash", "kv/s1/r1")
             .add(8_000.0, "recover", "kv/s1/r1"))
    spec = ScenarioSpec(
        apps=[], n_pools=2, seed=31, faults=sched, drain_us=50_000.0,
        services=[ServiceSpec(
            name="kv", n_shards=2, cfg=cfg, tx_timeout_us=40_000.0,
            workload=Workload(kind="closed", n_requests=21, n_clients=2,
                              keyspace=24, zipf_theta=0.9, key_seed=37,
                              payload_fn=op, timeout_us=120_000_000.0))])
    res = run_scenario(spec)
    svc = res.substrate.services["kv"]
    # per-shard committed-state fingerprint: the 2PC outcomes are part of
    # the digest, not just the traffic shape
    stores = [zlib.crc32(b"|".join(k + b"=" + v for k, v in
                                   sorted(s.replicas[0].app.store.items())))
              for s in svc.shards]
    lats = res.apps["kv"].latencies
    return {
        "digest": _digest(lats, [res.msgs_sent, res.bytes_sent,
                                 res.apps["kv"].issued] + stores),
        "n": len(lats),
        "store_crc": stores,
        "msgs_sent": res.msgs_sent,
        "bytes_sent": res.bytes_sent,
    }


def scenario_token_server() -> dict:
    """Replicated inference plane (ISSUE 9): a token server whose
    session/KV metadata rides consensus slots, roofline decode cost
    through the deferred execution engine, SLO-sized admission shedding
    a flash crowd with agreed BUSY replies — and one replica crashed
    mid-crowd (its in-flight decode timer swallowed) and recovered.
    Gates the workload generators, the trace replay, the cost model, the
    admission protocol, and the engine's crash/recover path with one
    digest."""
    import zlib

    import numpy as np

    from repro.core.consensus import AdmissionConfig, ConsensusConfig
    from repro.core.substrate import Substrate
    from repro.serve import InferencePlane, ServingCostModel, SLOSpec
    from repro.workloads import flash_crowd_times, llm_session_trace

    cm = ServingCostModel.from_counts("toy-1b", n_params=1.0e9,
                                      kv_bytes_per_token=26_624, batch=32)
    # the progress timer must ride out the decode backlog: with roofline
    # costs, execution (not agreement) is the bottleneck, and the
    # pipeline cap throttles decisions behind it — a 20 ms timer would
    # read a healthy-but-busy engine as a stalled leader and churn views
    cfg = ConsensusConfig(t=16, window=32, slow_mode="always",
                          ctb_fast_enabled=False,
                          view_timeout_us=200_000.0,
                          max_batch=4, pipeline_depth=4,
                          max_request_bytes=4096)
    plane = InferencePlane.build(
        cm, SLOSpec(deadline_us=3_000.0),
        admission=AdmissionConfig(queue_high=4, queue_accept=2),
        cfg=cfg, substrate=Substrate(n_pools=2, seed=29), name="tok")
    arrivals = flash_crowd_times(np.random.default_rng(13), base_rps=400.0,
                                 peak_rps=3_000.0, t_start_us=8_000.0,
                                 ramp_us=3_000.0, hold_us=6_000.0,
                                 decay_us=3_000.0, duration_us=30_000.0)
    trace = llm_session_trace(13, 30_000.0, session_times=arrivals,
                              mean_turns=2.0, think_us=1_500.0,
                              first_prompt_tokens=8, next_prompt_tokens=4,
                              decode_tokens=4)
    cluster = plane.cluster
    victim = cluster.replicas[2]
    # the outage stays within what the CTBcast 2t-message tails can
    # replay on recovery — a mid-window straggler in epoch 0 cannot be
    # repaired by state transfer (STATE_RESP only fp-verifies at the
    # exact checkpoint boundary), so it must catch up from the wire
    cluster.sim.at(9_000.0, victim.crash)
    cluster.sim.at(12_500.0, victim.recover)
    plane.run_trace(trace, drain_us=10_000_000.0)
    cluster.sim.run(until=cluster.sim.now + 100_000.0)   # victim catch-up
    snaps = {r.app.snapshot() for r in cluster.replicas}
    assert len(snaps) == 1, "replicas (incl. the recovered one) diverged"
    crc = zlib.crc32(repr(sorted(snaps)[0]).encode())
    rep = plane.slo_report()
    busy = {s["busy_replies"] for s in rep["admission"].values()}
    assert len(busy) == 1, "BUSY replies not agreed across replicas"
    lats = [lat for _t, lat, _ok in plane.outcomes]
    return {
        "digest": _digest(lats, [cluster.net.msgs_sent,
                                 cluster.net.bytes_sent, rep["served"],
                                 rep["shed"], busy.pop(), crc]),
        "n": len(trace),
        "served": rep["served"],
        "shed": rep["shed"],
        "session_crc": crc,
        "msgs_sent": cluster.net.msgs_sent,
        "bytes_sent": cluster.net.bytes_sent,
    }


SCENARIOS = {
    "throughput_mini": scenario_throughput_mini,
    "slow_path": scenario_slow_path,
    "mu_baseline": scenario_mu_baseline,
    "faults_reconfig": scenario_faults_reconfig,
    "shared_substrate": scenario_shared_substrate,
    "replica_replacement": scenario_replica_replacement,
    "sharded_service": scenario_sharded_service,
    "token_server": scenario_token_server,
}


def run_all() -> Dict[str, dict]:
    return {name: fn() for name, fn in SCENARIOS.items()}


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--record", action="store_true",
                    help="overwrite the committed golden digests")
    args = ap.parse_args()
    results = run_all()
    for name, res in results.items():
        print(f"{name}: {json.dumps(res, sort_keys=True)}")
    if args.record:
        os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
        with open(GOLDEN_PATH, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"recorded -> {GOLDEN_PATH}")


if __name__ == "__main__":
    main()
