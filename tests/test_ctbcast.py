"""Consistent Tail Broadcast (Algorithm 1): the four properties, fast and
slow paths, and equivocation attacks by a Byzantine broadcaster."""

import pytest

from repro.baselines.sgx_counter import build_ctbcast
from repro.core import crypto


def run_broadcasts(sim, nodes, deliveries, ks, payload=b"m", timeout=1e6):
    bc = nodes[0]
    for k in ks:
        bc.ctb.broadcast(k, payload + str(k).encode())
    sim.run(until=sim.now + timeout)
    return deliveries


def test_fast_path_delivers_to_all():
    sim, nodes, deliv = build_ctbcast(fast=True)
    nodes[0].ctb.broadcast(0, b"hello")
    assert sim.run_until(lambda: len(deliv.get(0, {})) == 3, timeout=100000)


def test_slow_path_delivers_to_all():
    sim, nodes, deliv = build_ctbcast(fast=False)
    nodes[0].ctb.broadcast(0, b"hello")
    assert sim.run_until(lambda: len(deliv.get(0, {})) == 3, timeout=1000000)


def test_no_duplication():
    sim, nodes, deliv = build_ctbcast(fast=True)
    counts = {}
    orig_cb = nodes[1].ctb.deliver_cb

    def counting(k, m):
        counts[k] = counts.get(k, 0) + 1
        orig_cb(k, m)

    nodes[1].ctb.deliver_cb = counting
    for k in range(10):
        nodes[0].ctb.broadcast(k, f"m{k}".encode())
    sim.run(until=sim.now + 200000)
    assert all(v == 1 for v in counts.values())


def test_tail_validity_recent_messages_delivered():
    """All of the last t messages of a correct broadcaster are delivered."""
    t = 16
    sim, nodes, deliv = build_ctbcast(t=t, fast=True)
    for k in range(40):
        nodes[0].ctb.broadcast(k, f"m{k}".encode())
    sim.run(until=sim.now + 500000)
    for k in range(40 - t, 40):
        assert len(deliv.get(k, {})) == 3, f"tail message {k} not delivered"


def test_agreement_under_equivocation_fast():
    """Byzantine broadcaster LOCKs different messages to different receivers:
    no two correct receivers may deliver different messages for the same k."""
    sim, nodes, deliv = build_ctbcast(fast=True)
    byz = nodes[0]
    delivered_values = {}
    for q in nodes:
        orig = q.ctb.deliver_cb

        def rec(k, m, pid=q.pid, orig=orig):
            delivered_values.setdefault(k, {})[pid] = m
            orig(k, m)

        q.ctb.deliver_cb = rec
    # equivocate at the TBcast level: send different LOCK payloads per peer
    stream = byz.ctb._s_lock
    byz.tb.broadcast(stream, 0, b"to-q1", ["p1"])
    byz.tb.broadcast(stream, 0, b"to-q2", ["p2"])
    byz.tb.broadcast(stream, 0, b"to-self", ["p0"])
    sim.run(until=sim.now + 300000)
    vals = {crypto.encode(m) for pid_m in delivered_values.values()
            for m in pid_m.values()}
    # agreement: at most one distinct value delivered for k=0
    assert len(vals) <= 1


def test_agreement_under_equivocation_slow():
    """Byzantine broadcaster sends different SIGNED messages to different
    receivers: registers force agreement (or abort)."""
    sim, nodes, deliv = build_ctbcast(fast=False)
    byz = nodes[0]
    fp1 = crypto.fingerprint(crypto.encode(b"vA"))
    fp2 = crypto.fingerprint(crypto.encode(b"vB"))
    sig1 = byz.signer.sign(("ctb", "p0", 0, fp1))
    sig2 = byz.signer.sign(("ctb", "p0", 0, fp2))
    byz.tb.broadcast(byz.ctb._s_signed, 0, (b"vA", sig1), ["p1"])
    byz.tb.broadcast(byz.ctb._s_signed, 0, (b"vB", sig2), ["p2"])
    values = {}
    for q in nodes[1:]:
        orig = q.ctb.deliver_cb

        def rec(k, m, pid=q.pid, orig=orig):
            values.setdefault(k, {})[pid] = m

        q.ctb.deliver_cb = rec
    sim.run(until=sim.now + 500000)
    got = values.get(0, {})
    assert len({crypto.encode(m) for m in got.values()}) <= 1


def test_integrity_unsigned_injection_rejected():
    """A forged SIGNED message (bad signature) is never delivered."""
    sim, nodes, deliv = build_ctbcast(fast=False)
    attacker = nodes[1]   # p1 pretends to relay p0's broadcast
    fake_sig = attacker.signer.sign(("ctb", "p0", 0,
                                     crypto.fingerprint(crypto.encode(b"x"))))
    attacker.tb.broadcast(nodes[0].ctb._s_signed, 0, (b"x", fake_sig),
                          ["p1", "p2"])
    sim.run(until=sim.now + 200000)
    assert len(deliv.get(0, {})) == 0


def test_stall_accounted_exactly_once_per_episode():
    """total_stall_us accumulates exactly once per stall episode — a second
    summary certification with no intervening stall must not re-account."""
    t = 8
    sim, nodes, deliv = build_ctbcast(t=t, fast=True)
    bc = nodes[0]
    bc.ctb.on_summary_needed = lambda seg: None   # suppress certification
    for k in range(2 * t):
        bc.ctb.broadcast(k, b"x")
    sim.run(until=sim.now + 1000.0)
    assert bc.ctb.stall_count == 1
    assert bc.ctb.stalled_since is not None
    t0 = bc.ctb.stalled_since
    sim.run(until=sim.now + 500.0)
    bc.ctb.summary_certified(5)   # unblocks the whole queue
    expected = sim.now - t0
    assert bc.ctb.total_stall_us == pytest.approx(expected)
    assert bc.ctb.stalled_since is None
    bc.ctb.summary_certified(6)   # no new stall → no new accounting
    assert bc.ctb.total_stall_us == pytest.approx(expected)
    assert not bc.ctb.blocked_queue


def test_summary_blocking_bounds_outstanding():
    """The broadcaster stalls rather than outrun its summaries (double
    buffering, footnote 3)."""
    t = 8
    sim, nodes, deliv = build_ctbcast(t=t, fast=True)
    bc = nodes[0]
    # suppress summary certification to force a stall
    bc.ctb.on_summary_needed = lambda seg: None
    for k in range(t * 3):
        bc.ctb.broadcast(k, b"x")
    sim.run(until=sim.now + 100000)
    assert bc.ctb.stall_count >= 1
    assert bc.ctb.blocked_queue   # still blocked — never outran summaries
    max_bcast = max(bc.ctb.buf)
    assert max_bcast < 2 * t      # at most two segments in flight
