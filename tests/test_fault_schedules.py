"""End-to-end agreement under combined, seeded fault schedules.

The matrix crosses {f_m memory-node crashes, mid-broadcast pool
reconfiguration, replica crash + memory crash, partition + heal} with
deterministic seeds, and asserts after every run that

* kvstore client histories stay consistent: every acknowledged write is
  present on every live replica with its last-acknowledged value and the
  live replicas' stores are identical (no lost / reordered acked writes);
* CTBcast delivery completed (the workload runs with ``slow_mode="always"``
  and the fast path disabled, so every slot crosses the disaggregated
  memory that the faults are hitting);
* ``memory_bytes()`` stays bounded: < 1 MiB per pool (Table 2) and the
  replica-local total stays finite.
"""

import pytest

from repro.apps.kvstore import KVStoreApp, set_req
from repro.core import crypto
from repro.core.consensus import ConsensusConfig
from repro.core.registers import POOL_MEMORY_BUDGET as POOL_BUDGET
from repro.core.smr import build_cluster
from repro.sim.faults import FaultEvent, FaultInjector, FaultSchedule


def _registers_cfg(**kw):
    """Every consensus slot crosses disaggregated memory."""
    base = dict(t=16, window=16, slow_mode="always", ctb_fast_enabled=False,
                view_timeout_us=20_000.0)
    base.update(kw)
    return ConsensusConfig(**base)


def _run_workload(cluster, n_reqs=16, timeout=600_000_000):
    client = cluster.new_client()
    acked = {}
    for i in range(n_reqs):
        k, v = b"k%d" % (i % 5), b"v%d" % i
        r, _ = cluster.run_request(client, set_req(k, v), timeout=timeout)
        assert r == b"OK"
        acked[k] = v
    return acked


def _assert_safe(cluster, acked):
    cluster.sim.run(until=cluster.sim.now + 100_000)
    alive = [r for r in cluster.replicas if not r.crashed]
    assert len(alive) >= 2
    for rep in alive:
        for k, v in acked.items():
            assert rep.app.store.get(k) == v, (rep.pid, k, v)
    for a, b in zip(alive, alive[1:]):
        assert a.app.store == b.app.store
    for p in cluster.pools:
        assert p.memory_bytes() < POOL_BUDGET, p.name
    assert alive[0].memory_bytes()["total"] < 64 * 2**20


@pytest.mark.parametrize("seed", [0, 7])
def test_fm_memory_crashes_per_pool(pooled_cluster, fault_injector, seed):
    """f_m crashes in *each* pool mid-workload: quorums survive, histories
    stay consistent."""
    c = pooled_cluster(n_pools=2, seed=seed, cfg=_registers_cfg())
    sched = FaultSchedule.seeded(
        seed, horizon_us=3000.0, memory=["m0", "p1m2"], pools=c.pools,
        n_memory_crashes=2, recover=True)
    assert sum(e.action == "crash" for e in sched) == 2
    inj = fault_injector(c, sched)
    acked = _run_workload(c, n_reqs=16)
    _assert_safe(c, acked)
    assert len(inj.log) == len(sched)


@pytest.mark.parametrize("seed", [0, 3])
def test_mid_broadcast_pool_reconfiguration(pooled_cluster, fault_injector,
                                            seed):
    """Acceptance: up to f_m memory-node crashes plus one mid-broadcast
    pool reconfiguration — CTBcast delivery (slow path over registers)
    completes with agreement/validity intact and < 1 MiB per pool."""
    c = pooled_cluster(n_pools=2, seed=seed, cfg=_registers_cfg())
    sched = FaultSchedule.seeded(
        seed, horizon_us=3000.0, memory=["m0"], pools=c.pools,
        n_memory_crashes=1, reconfigure=True)
    fault_injector(c, sched)
    acked = _run_workload(c, n_reqs=16)
    _assert_safe(c, acked)
    assert len(c.pools[0].reconfigurations) == 1
    dead, fresh = c.pools[0].reconfigurations[0][1:]
    assert dead == "m0" and fresh in c.pools[0].members
    # every replica delivered every decided slot identically
    decided = [dict(r.decided) for r in c.replicas if not r.crashed]
    common = set(decided[0])
    for d in decided[1:]:
        common &= set(d)
    assert common, "no slots decided"


def test_replica_crash_plus_memory_crash(pooled_cluster, fault_injector):
    """Double fault: a follower replica and a memory node at once."""
    c = pooled_cluster(n_pools=2, seed=11, cfg=_registers_cfg())
    sched = (FaultSchedule()
             .add(800.0, "crash", "r2")
             .add(900.0, "crash", "m1")
             .add(2500.0, "reconfigure", ("pool0", "m1")))
    fault_injector(c, sched)
    acked = _run_workload(c, n_reqs=14, timeout=600_000_000)
    _assert_safe(c, acked)
    assert c.pools[0].reconfigurations


def test_partition_and_heal(pooled_cluster, fault_injector):
    """A forced replica-link partition heals; no acked write is lost."""
    c = pooled_cluster(n_pools=2, seed=5,
                       cfg=_registers_cfg(view_timeout_us=50_000.0))
    sched = (FaultSchedule()
             .add(500.0, "partition", ("r1", "r2"))
             .add(2500.0, "heal", ("r1", "r2")))
    fault_injector(c, sched)
    acked = _run_workload(c, n_reqs=12)
    _assert_safe(c, acked)
    assert not c.net.forced   # healed


@pytest.mark.slow
@pytest.mark.parametrize("seed", [1, 2, 4])
@pytest.mark.parametrize("scenario", ["combined", "auto_lease",
                                      "stale_serve", "replace_replica"])
def test_seeded_fault_matrix(pooled_cluster, fault_injector, seed, scenario):
    """Heavier seeded matrix: combined crash+reconfigure+partition
    schedules, lease-driven auto-reconfiguration, a Byzantine stale-serving
    memory node, and a mid-workload replica replacement — all underneath a
    live workload."""
    if scenario == "combined":
        c = pooled_cluster(n_pools=2, seed=seed, cfg=_registers_cfg())
        sched = FaultSchedule.seeded(
            seed, horizon_us=5000.0, memory=["m0", "p1m0"], pools=c.pools,
            replicas=["r1"], partitions=[("r1", "r2")],
            n_memory_crashes=2, n_replica_crashes=1, n_partitions=1,
            reconfigure=True)
        fault_injector(c, sched)
    elif scenario == "stale_serve":
        # one stale-serving node per pool (≤ f_m each): old-but-well-formed
        # blobs cannot break regularity — READs take the highest valid
        # timestamp over an f_m+1 quorum, and some fresh responder outbids
        c = pooled_cluster(n_pools=2, seed=seed, cfg=_registers_cfg())
        sched = FaultSchedule.seeded(
            seed, horizon_us=5000.0, n_memory_crashes=0,
            stale_serve=["m1", "p1m2"])
        fault_injector(c, sched)
    elif scenario == "replace_replica":
        c = pooled_cluster(n_pools=2, seed=seed, cfg=_registers_cfg())
        sched = FaultSchedule.seeded(
            seed, horizon_us=5000.0, n_memory_crashes=0, pools=c.pools,
            replicas=["r2"], n_replica_crashes=1, replace_replicas=True)
        fault_injector(c, sched)
    else:
        c = pooled_cluster(n_pools=2, seed=seed, cfg=_registers_cfg(),
                           auto_reconfigure=True, lease_us=300.0)
        sched = FaultSchedule([FaultEvent(1000.0, "crash", "m2")])
        fault_injector(c, sched)
    acked = _run_workload(c, n_reqs=20)
    _assert_safe(c, acked)
    if scenario == "auto_lease":
        c.sim.run(until=c.sim.now + 5000)
        assert c.pools[0].reconfigurations, "lease never fired"
    if scenario == "stale_serve":
        stale = [n for p in c.pools for n in p.member_nodes()
                 if n.stale_serve]
        assert stale, "the stale-serve adversary never engaged"
    if scenario == "replace_replica":
        c.sim.run(until=c.sim.now + 100_000)
        live = [r for r in c.replicas if not r.crashed]
        assert len(live) == 3 and all(r.membership.epoch == 1 for r in live)
        _assert_safe(c, acked)


def test_message_rate_plateaus_after_crash_recover(pooled_cluster,
                                                   fault_injector):
    """Quiesce regression (ISSUE 7): after a replica crash+recover the
    TBcast layer must settle.  Stranded ``ack_pending`` / ``rto_pending``
    entries previously survived the crash, so every live sender kept
    re-firing its retransmission timer forever and the idle message rate
    never returned to baseline."""
    c = pooled_cluster(n_pools=2, seed=9, cfg=_registers_cfg())
    sched = (FaultSchedule()
             .add(800.0, "crash", "r2")
             .add(2_000.0, "recover", "r2"))
    fault_injector(c, sched)
    acked = _run_workload(c, n_reqs=12)
    _assert_safe(c, acked)
    # settle well past recovery and any in-flight retransmission backoff
    c.sim.run(until=c.sim.now + 200_000.0)

    def idle_window(us=100_000.0):
        n0 = c.net.msgs_sent
        c.sim.run(until=c.sim.now + us)
        return c.net.msgs_sent - n0

    w1, w2 = idle_window(), idle_window()
    # plateau: the idle rate is flat (not still growing with backoff
    # resets) and a trickle, not a retransmission storm
    assert w2 <= max(w1, 8), (w1, w2)
    assert w2 <= 50, f"post-recovery chatter never quiesced: {w2}/100ms"


def test_schedules_are_deterministic():
    def make(seed, mem):
        return FaultSchedule.seeded(seed, horizon_us=1000.0, memory=mem,
                                    n_memory_crashes=2, n_partitions=1,
                                    partitions=[("a", "b"), ("c", "d")])

    s1, s2 = make(42, ["m0", "m1"]), make(42, ["m0", "m1"])
    assert s1.events == s2.events   # FaultEvent equality includes targets
    assert [e.target for e in s1.events] == [e.target for e in s2.events]
    assert s1.events != make(43, ["m0", "m1"]).events
    # same seed, different targets must NOT compare equal
    assert s1.events != make(42, ["x0", "x1"]).events


def test_reconfigure_noop_is_logged_as_skipped(pooled_cluster,
                                               fault_injector):
    c = pooled_cluster(n_pools=1, seed=0)
    inj = fault_injector(c, FaultSchedule([
        FaultEvent(100.0, "reconfigure", ("pool0", None))]))
    c.sim.run(until=1000.0)
    assert inj.log == []            # nothing was crashed: nothing applied
    assert len(inj.skipped) == 1


# --------------------------------------------------------------------------
# Gray failures (slow_replica) and false-suspicion hysteresis (ISSUE 8)
# --------------------------------------------------------------------------
def test_slow_replica_fault_event_degrades_and_clears(pooled_cluster,
                                                      fault_injector):
    c = pooled_cluster(n_pools=1, seed=0)
    inj = fault_injector(c, FaultSchedule([
        FaultEvent(100.0, "slow_replica",
                   ("r1", {"delay_us": 800.0, "drop": 0.2, "seed": 7})),
        FaultEvent(200.0, "slow_replica", "r1"),       # re-degrade: no-op
        FaultEvent(300.0, "slow_replica", ("r1", False)),   # recover
        FaultEvent(400.0, "slow_replica", ("r2", False)),   # healthy: no-op
    ]))
    c.sim.run(until=150.0)
    assert "r1" in c.net.degraded
    assert c.net.degraded["r1"][:2] == (800.0, 0.2)
    c.sim.run(until=250.0)
    assert [a for (_t, a, _tgt) in inj.log] == ["slow_replica"]
    assert len(inj.skipped) == 1                      # the re-degrade
    c.sim.run(until=500.0)
    assert "r1" not in c.net.degraded
    assert len(inj.skipped) == 2                      # clearing healthy r2


def test_slow_replica_rejects_bad_drop_fraction(pooled_cluster,
                                                fault_injector):
    c = pooled_cluster(n_pools=1, seed=0)
    fault_injector(c, FaultSchedule([
        FaultEvent(100.0, "slow_replica", ("r1", {"drop": 1.5}))]))
    with pytest.raises(ValueError):
        c.sim.run(until=200.0)


def test_seeded_slow_replica_schedules_are_deterministic():
    def make(seed, **kw):
        return FaultSchedule.seeded(seed, horizon_us=2000.0,
                                    replicas=["r0", "r1", "r2"],
                                    n_memory_crashes=0, **kw)

    s1 = make(42, n_slow_replicas=2, slow_recover=True)
    s2 = make(42, n_slow_replicas=2, slow_recover=True)
    assert s1.events == s2.events
    assert s1.events != make(43, n_slow_replicas=2,
                             slow_recover=True).events
    slow = [e for e in s1.events if e.action == "slow_replica"]
    degrades = [e for e in slow if isinstance(e.target[1], dict)]
    recovers = [e for e in slow if e.target[1] is False]
    assert len(degrades) == 2 and len(recovers) == 2
    for e in degrades:
        assert 300.0 <= e.target[1]["delay_us"] <= 2000.0
        assert 0.1 <= e.target[1]["drop"] <= 0.6
        assert "seed" in e.target[1]
    # pinned parameters override the drawn ones (the seed stays drawn)
    pinned = make(42, n_slow_replicas=1,
                  slow_params={"delay_us": 999.0, "drop": 0.25})
    (ev,) = [e for e in pinned.events if e.action == "slow_replica"]
    assert ev.target[1]["delay_us"] == 999.0 and ev.target[1]["drop"] == 0.25
    # a zero-count request draws nothing: schedules without gray failures
    # are bit-identical to the pre-ISSUE-8 generation
    assert make(42) .events == make(42, n_slow_replicas=0).events


@pytest.mark.parametrize("seed", [1, 5])
def test_byzantine_accusation_spammer_cannot_evict(pooled_cluster, seed):
    """f Byzantine replicas spamming maximal accusations never meet the
    f+1 accuser quorum — zero replacements, the group stays at epoch 0."""
    c = pooled_cluster(n_pools=2, seed=seed, cfg=_registers_cfg())
    mon = c.enable_self_healing(True)
    spammer = c.replicas[2]
    c.sim.periodic(200.0, lambda: spammer.send(
        mon.pid, "HEALTH_ACCUSE", ("r1", 99.0)))
    acked = _run_workload(c, n_reqs=16)
    c.sim.run(until=c.sim.now + 150_000)
    assert mon.replacements == []
    assert c.replacements == [] and c.current_epoch() == 0
    # the spam was seen (and logged) but never formed a quorum
    assert "r1" in mon.accusations
    assert set(mon.accusations["r1"]) <= {spammer.pid}
    _assert_safe(c, acked)


@pytest.mark.slow
@pytest.mark.parametrize("seed", [2, 9])
def test_byzantine_leader_view_churn_evicts_no_honest_replica(
        pooled_cluster, seed):
    """A Byzantine leader that refuses to propose forces repeated view
    changes.  The starvation episodes seat past *its* pid only — honest
    replicas are never evicted, and if anyone is auto-replaced it is the
    silent leader itself."""
    c = pooled_cluster(n_pools=2, seed=seed, cfg=_registers_cfg())
    mon = c.enable_self_healing(True)
    c.replicas[0]._drain_proposals = lambda: None      # silent leader
    acked = _run_workload(c, n_reqs=12)
    c.sim.run(until=c.sim.now + 200_000)
    assert all(rec["target"] == "r0" for rec in mon.replacements)
    assert all(old == "r0" for (_t, old, _new) in c.replacements)
    for rep in c.replicas:
        if not rep.crashed and not rep.joining:
            assert {"r1", "r2"} <= set(rep.membership.replicas)
    _assert_safe(c, acked)


@pytest.mark.slow
@pytest.mark.parametrize("seed", [3, 8])
def test_seeded_gray_matrix_only_degraded_replica_evicted(
        pooled_cluster, fault_injector, seed):
    """Seeded gray-failure matrix: the degraded replica is detected and
    replaced autonomously; no honest replica is ever touched."""
    c = pooled_cluster(n_pools=2, seed=seed, cfg=_registers_cfg())
    mon = c.enable_self_healing(True)
    sched = FaultSchedule.seeded(
        seed, horizon_us=3000.0, replicas=["r1"], n_memory_crashes=0,
        n_slow_replicas=1,
        slow_params={"delay_us": 1500.0, "drop": 0.5})
    fault_injector(c, sched)
    acked = _run_workload(c, n_reqs=20)
    c.sim.run(until=c.sim.now + 120_000)
    assert mon.replacements, "gray failure went undetected"
    assert all(rec["target"] == "r1" for rec in mon.replacements)
    assert "r1" not in c.current_members()
    c.net.clear_degrade("r1")
    _assert_safe(c, acked)


# --------------------------------------------------------------------------
# Cross-app isolation on a shared substrate (ISSUE 4)
# --------------------------------------------------------------------------
def _run_kv_workload(cluster, n_reqs=10, timeout=600_000_000):
    """Like _run_workload but keyed per app (no cross-app key overlap)."""
    client = cluster.new_client()
    acked = {}
    for i in range(n_reqs):
        k, v = b"%s.k%d" % (cluster.name.encode(), i % 5), b"v%d" % i
        r, _ = cluster.run_request(client, set_req(k, v), timeout=timeout)
        assert r == b"OK"
        acked[k] = v
    return acked


def _assert_app_safe(substrate, cluster, acked):
    alive = [r for r in cluster.replicas if not r.crashed]
    assert len(alive) >= 2
    for rep in alive:
        for k, v in acked.items():
            assert rep.app.store.get(k) == v, (rep.pid, k, v)
    for a, b in zip(alive, alive[1:]):
        assert a.app.store == b.app.store
    # per-app Table 2 bound on the SHARED pools (not just the pool total)
    for pool_name, nbytes in substrate.app_pool_bytes(cluster.name).items():
        assert nbytes < POOL_BUDGET, (cluster.name, pool_name, nbytes)


def test_cross_app_isolation_memory_crash_and_reconfig(shared_substrate,
                                                       fault_injector):
    """A crashed memory node + pool reconfiguration while app A is active
    must not violate safety or per-app memory bounds in app B on the same
    substrate (both apps run the registers-heavy slow path)."""
    substrate, clusters = shared_substrate(["A", "B"], n_pools=2,
                                           cfg_fn=_registers_cfg)
    a, b = clusters["A"], clusters["B"]
    sched = (FaultSchedule()
             .add(600.0, "crash", "m0")
             .add(1800.0, "reconfigure", ("pool0", "m0")))
    inj = FaultInjector(substrate.sim, substrate.net,
                        substrate.pools).install(sched)
    # interleave the two apps' workloads on the one event loop
    acked_a, acked_b = {}, {}
    for i in range(12):
        cluster, acked = (a, acked_a) if i % 2 == 0 else (b, acked_b)
        client = (cluster.clients[0] if cluster.clients
                  else cluster.new_client())
        k, v = b"%s.k%d" % (cluster.name.encode(), i % 5), b"v%d" % i
        r, _ = cluster.run_request(client, set_req(k, v),
                                   timeout=600_000_000)
        assert r == b"OK"
        acked[k] = v
    substrate.sim.run(until=substrate.sim.now + 100_000)
    _assert_app_safe(substrate, a, acked_a)
    _assert_app_safe(substrate, b, acked_b)
    assert len(inj.log) == 2
    assert substrate.pools[0].reconfigurations
    assert not substrate.audit_budgets()


def test_cross_app_isolation_byzantine_leader(shared_substrate):
    """App A's leader equivocates (different PREPAREs to different
    followers below CTBcast).  App B — sharing the substrate — must stay
    safe and live, and A's own followers must not diverge."""
    substrate, clusters = shared_substrate(["A", "B"], n_pools=2,
                                           cfg_fn=_registers_cfg)
    a, b = clusters["A"], clusters["B"]
    leader = a.replicas[0]
    cl_a = a.new_client()

    reqA = (("evil", 0), cl_a.pid, set_req(b"k", b"A1"))
    reqB = (("evil", 0), cl_a.pid, set_req(b"k", b"A2"))
    stream = leader.my_ctb._s_lock
    leader.tb.broadcast(stream, 0, ("PREPARE", 0, 0, reqA), ["A/r1"])
    leader.tb.broadcast(stream, 0, ("PREPARE", 0, 0, reqB), ["A/r2"])
    leader.tb.broadcast(stream, 0, ("PREPARE", 0, 0, reqA), ["A/r0"])
    substrate.sim.run(until=substrate.sim.now + 50_000)

    # A's followers never decide different values for the equivocated slot
    decided = [crypto.encode(rep.decided[0]) for rep in a.replicas[1:]
               if 0 in rep.decided]
    assert len(set(decided)) <= 1
    # B is fully functional and bounded despite A's Byzantine leader
    acked_b = _run_kv_workload(b, n_reqs=10)
    substrate.sim.run(until=substrate.sim.now + 100_000)
    _assert_app_safe(substrate, b, acked_b)
    # ...and B's stores never saw A's keys
    for rep in b.replicas:
        assert b"k" not in rep.app.store
    assert not substrate.audit_budgets()


def test_reconfigure_sync_timeout_unwedges_pool():
    """A reconfiguration started while the crash budget is transiently
    exceeded cannot gather f_m+1 pull acks; the sync must abort (not wedge
    the pool forever) and a retry after recovery must succeed."""
    from repro.core import crypto
    from repro.core.registers import MemoryPool
    from repro.sim.events import Simulator
    from repro.sim.net import NetworkModel

    sim = Simulator(seed=0)
    pool = MemoryPool(sim, NetworkModel(sim), crypto.KeyRegistry(),
                      name="pool0", prefix="m", sync_timeout_us=500.0)
    pool.crash_node("m0")
    pool.crash_node("m1")           # over budget: only one survivor
    assert pool.reconfigure("m0") is True
    sim.run(until=sim.now + 2000.0)
    assert pool.epoch == 0 and pool.aborted_syncs   # aborted, not wedged
    assert pool._reconfiguring is False
    pool.recover_node("m1")
    done = {}
    assert pool.reconfigure("m0", cb=lambda: done.setdefault("rc", 1))
    sim.run(until=sim.now + 2000.0)
    assert "rc" in done and pool.epoch == 1
    assert "m0" not in pool.members
