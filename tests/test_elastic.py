"""Elastic scaling: a checkpoint saved on one layout resumes on a different
mesh (re-sharded) with identical loss — the re-mesh event a 1000-node job
hits when its pod allocation changes."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, sys.argv[1])
    ckpt_dir = sys.argv[2]
    import jax, jax.numpy as jnp, numpy as np, json
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.models.common import init_params
    from repro.models.transformer import lm_loss
    from repro.checkpoint import save_checkpoint, load_checkpoint, reshard
    from repro.parallel.sharding import param_pspecs, shard_ctx_for_mesh

    cfg = get_smoke_config("qwen3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 4, 16
    inputs = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    targets = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    loss0 = float(jax.jit(lambda p: lm_loss(cfg, p, inputs, targets))(params))
    fp = save_checkpoint(ckpt_dir, 3, params)

    # "restart" on a different mesh: 2x4 instead of single-device
    step, p2, _ = load_checkpoint(ckpt_dir, expect_fp=fp)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = shard_ctx_for_mesh(mesh)
    pspecs = param_pspecs(cfg, p2, mesh)
    p_sharded = reshard(p2, mesh, pspecs)
    loss1 = float(jax.jit(lambda p, i, t: lm_loss(cfg, p, i, t, ctx))(
        p_sharded, inputs, targets))
    print("RESULT:" + json.dumps([loss0, loss1]))
""")


@pytest.mark.slow
def test_checkpoint_reshards_onto_new_mesh(tmp_path):
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = tmp_path / "elastic_check.py"
    script.write_text(SCRIPT)
    proc = subprocess.run(
        [sys.executable, str(script), src, str(tmp_path / "ckpt")],
        capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout
    loss0, loss1 = json.loads(line[0][len("RESULT:"):])
    assert abs(loss0 - loss1) < 0.05 + 0.02 * abs(loss0)
