"""Pipeline parallelism: GPipe schedule over a stage axis == sequential."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, sys.argv[1])
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel.pipeline import pipeline_apply

    S, n_micro, mb, d = 4, 8, 2, 16
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (S, d, d)) / d ** 0.5
    x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))

    def stage_fn(w, h):
        return jnp.tanh(h @ w)

    mesh = jax.make_mesh((4,), ("stage",))
    out = pipeline_apply(stage_fn, ws, x, mesh)

    ref = x
    for i in range(S):
        ref = jnp.tanh(ref @ ws[i])
    err = float(jnp.max(jnp.abs(out - ref)))
    print("RESULT:" + str(err))
""")


@pytest.mark.slow
def test_pipeline_matches_sequential(tmp_path):
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = tmp_path / "pipe_check.py"
    script.write_text(SCRIPT)
    proc = subprocess.run([sys.executable, str(script), src],
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout
    assert float(line[0][len("RESULT:"):]) < 1e-5
