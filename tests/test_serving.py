"""Replicated inference serving plane + workload library (ISSUE 9).

Covers the tentpole and its invariants:

* the workload library's generators are seeded-deterministic, and
  ``ramp_times`` is draw-for-draw identical to the hand-rolled rush it
  replaced in ``benchmarks/sharded.py`` (the split gate's byte-identical
  schedules depend on it);
* ``Workload(kind="trace")`` replays a precomputed schedule through the
  scenario runner;
* the roofline serving-cost model (analytic counts; the JAX-backed
  ``from_arch`` constructor is slow-marked);
* admission control invariants: BUSY replies are *agreed* — identical
  result vectors and identical app state at every replica, never torn
  against applied state — and a Byzantine leader over-shedding honest
  requests under light load loses its view through the normal progress
  timer;
* the deferred execution engine (``App.cost_us``) keeps replicas
  identical and survives a crash mid-decode (the completion timer is
  swallowed; the recover hook re-enters the slot);
* ``TokenServerApp`` snapshot/adopt: a joiner installed via
  ``Cluster.replace_replica`` mid-generation adopts the session state
  and continues decoding consistently.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.core.consensus import AdmissionConfig, ConsensusConfig
from repro.core.smr import Cluster
from repro.core.substrate import Substrate
from repro.runtime.server import ReplicatedServer, TokenServerApp
from repro.scenario import (AppSpec, ScenarioSpec, Workload, run_scenario)
from repro.serve import (InferencePlane, ServingCostModel, SLOSpec,
                         admission_for, greedy_decode_fn)
from repro.workloads import (auction_day_trace, diurnal_times,
                             flash_crowd_times, llm_session_trace,
                             ramp_times)

# --------------------------------------------------------------------------
# Workload library
# --------------------------------------------------------------------------
KEYSPACE, THETA = 128, 1.2


def test_ramp_times_matches_inline_recipe():
    """Byte-for-byte the benchmarks/sharded.py rush — including leaving
    the RNG stream positioned exactly where the inline recipe left it."""
    duration_us, r0_rps, r1_rps = 30_000.0, 800_000.0, 1_400_000.0
    rng = np.random.default_rng(11)
    r0, r1 = r0_rps / 1e6, r1_rps / 1e6
    slope = (r1 - r0) / duration_us
    lam_total = (r0 + r1) / 2.0 * duration_us
    lam = np.cumsum(rng.exponential(1.0, size=int(lam_total * 1.1) + 100))
    lam = lam[lam <= lam_total]
    t_old = (np.sqrt(r0 * r0 + 2.0 * slope * lam) - r0) / slope
    p = np.arange(1, KEYSPACE + 1, dtype=float) ** -THETA
    k_old = rng.choice(KEYSPACE, size=len(t_old), p=p / p.sum())

    rng = np.random.default_rng(11)
    t_new = ramp_times(rng, r0_rps, r1_rps, duration_us)
    k_new = rng.choice(KEYSPACE, size=len(t_new), p=p / p.sum())
    assert t_old.shape == t_new.shape
    assert (t_old == t_new).all()
    assert (k_old == k_new).all()      # stream state preserved


def test_flash_crowd_spikes_and_is_deterministic():
    kw = dict(base_rps=1_000.0, peak_rps=20_000.0, t_start_us=20_000.0,
              ramp_us=5_000.0, hold_us=10_000.0, decay_us=5_000.0,
              duration_us=60_000.0)
    a = flash_crowd_times(np.random.default_rng(4), **kw)
    b = flash_crowd_times(np.random.default_rng(4), **kw)
    assert (a == b).all()
    in_spike = ((a >= 25_000.0) & (a < 35_000.0)).sum() / 10_000.0
    before = (a < 20_000.0).sum() / 20_000.0
    assert in_spike > 5 * before       # the crowd actually arrives


def test_diurnal_peak_to_trough():
    rng = np.random.default_rng(9)
    t = diurnal_times(rng, mean_rps=50_000.0, amplitude=0.8,
                      period_us=100_000.0, duration_us=100_000.0,
                      phase=np.pi / 2)       # peak at t=0, trough mid-period
    peak = (t < 10_000.0).sum()
    trough = ((t >= 45_000.0) & (t < 55_000.0)).sum()
    assert peak > 3 * trough
    with pytest.raises(ValueError):
        diurnal_times(rng, 1000.0, 1.5, 1000.0, 1000.0)


def test_auction_day_trace_shape():
    tr = auction_day_trace(seed=2, duration_us=50_000.0, base_rps=2_000.0,
                           open_peak_rps=40_000.0, close_peak_rps=30_000.0)
    tr2 = auction_day_trace(seed=2, duration_us=50_000.0, base_rps=2_000.0,
                            open_peak_rps=40_000.0, close_peak_rps=30_000.0)
    assert tr == tr2
    times = np.array([t for t, _ in tr])
    assert all(len(p) == 32 for _, p in tr)   # order_req wire format
    open_burst = (times < 5_000.0).sum()
    midday = ((times >= 20_000.0) & (times < 25_000.0)).sum()
    assert open_burst > 2 * midday            # U-shaped volume


def test_llm_session_trace_multiturn():
    tr = llm_session_trace(5, 50_000.0, session_rate_rps=2_000.0,
                           mean_turns=3.0, think_us=1_000.0)
    assert tr == llm_session_trace(5, 50_000.0, session_rate_rps=2_000.0,
                                   mean_turns=3.0, think_us=1_000.0)
    sessions = {}
    for t, payload in tr:
        msg = json.loads(payload.decode())
        sessions.setdefault(msg["session"], []).append(msg)
        assert msg["n"] >= 1 and len(msg["prompt"]) >= 1
    assert any(len(v) > 1 for v in sessions.values())   # multi-turn
    first = [v[0] for v in sessions.values()]
    later = [m for v in sessions.values() for m in v[1:]]
    if later:
        avg = lambda ms: sum(len(m["prompt"]) for m in ms) / len(ms)
        assert avg(first) > 2 * avg(later)   # long first prompts
    with pytest.raises(ValueError):
        llm_session_trace(0, 1000.0)         # needs an arrival process


def test_trace_workload_kind_replays_schedule():
    trace = llm_session_trace(3, 8_000.0, session_rate_rps=1_500.0,
                              mean_turns=1.5, think_us=500.0,
                              first_prompt_tokens=4, next_prompt_tokens=2,
                              decode_tokens=2)
    assert trace
    spec = ScenarioSpec(apps=[AppSpec(
        name="tok", app=lambda: TokenServerApp(greedy_decode_fn()),
        cfg=ConsensusConfig(t=16, window=16, view_timeout_us=20_000.0),
        workload=Workload(kind="trace", trace=trace))])
    res = run_scenario(spec)
    assert res.apps["tok"].issued == len(trace)
    assert res.apps["tok"].completed == len(trace)
    with pytest.raises(ValueError):
        Workload(kind="trace")               # needs a non-empty trace


# --------------------------------------------------------------------------
# Serving cost model
# --------------------------------------------------------------------------
def test_cost_model_roofline_shape():
    cm = ServingCostModel.from_counts("toy-1b", n_params=1e9,
                                      kv_bytes_per_token=26_624, batch=32)
    # small-batch decode is HBM-bound on the weight read:
    # 2e9 B / 819 GB/s ≈ 2.44 ms per step, /32 ≈ 76 µs per token
    per_tok = cm.decode_us_per_token(ctx=0)
    assert 70.0 < per_tok < 85.0
    assert cm.decode_us_per_token(ctx=4096) > per_tok   # KV read grows
    big = ServingCostModel.from_counts("toy-1b", n_params=1e9,
                                       kv_bytes_per_token=26_624, batch=256)
    assert big.decode_us_per_token() < per_tok / 4      # batching amortizes
    req = cm.request_us(n_prompt=16, n_decode=8)
    assert req > 8 * per_tok                            # prefill is extra


@pytest.mark.slow
def test_cost_model_from_arch_gemma3():
    cm = ServingCostModel.from_arch("gemma3-1b", batch=32)
    n_params = cm.param_bytes / 2
    assert 0.9e9 < n_params < 1.1e9                     # ~1B analytic count
    assert 26 * 2 * 256 * 2 * 0.9 < cm.kv_bytes_per_token < 26 * 2 * 256 * 2 * 1.1
    assert 50.0 < cm.decode_us_per_token() < 120.0


# --------------------------------------------------------------------------
# Admission control invariants
# --------------------------------------------------------------------------
def _serving_cfg(**kw):
    base = dict(t=16, window=32, max_batch=4, pipeline_depth=8,
                view_timeout_us=50_000.0, max_request_bytes=4096)
    base.update(kw)
    return ConsensusConfig(**base)


def _flash_plane(queue_high=3, **cfg_kw):
    cm = ServingCostModel.from_counts("toy-1b", n_params=1e9,
                                      kv_bytes_per_token=26_624, batch=32)
    adm = AdmissionConfig(queue_high=queue_high,
                          queue_accept=max(1, queue_high // 2))
    plane = InferencePlane.build(
        cm, SLOSpec(deadline_us=3_000.0), admission=adm,
        cfg=_serving_cfg(**cfg_kw))
    return plane


def _reply_map(replica):
    """rid -> reply bytes over every executed slot (shed markers resolve
    to their target rid)."""
    out = {}
    for s, batch in replica.decided.items():
        if s > replica.exec_upto:
            continue
        for i, r in enumerate(batch):
            rid = r[0]
            if isinstance(rid, tuple) and len(rid) == 2 and rid[0] == "shed":
                rid = rid[1]
            out.setdefault(rid, []).append(replica.results[s][i])
    return out


def test_busy_replies_agreed_and_never_torn():
    plane = _flash_plane(queue_high=3)
    trace = llm_session_trace(7, 20_000.0, session_rate_rps=3_000.0,
                              mean_turns=2.0, think_us=1_000.0,
                              first_prompt_tokens=8, next_prompt_tokens=4,
                              decode_tokens=4)
    plane.run_trace(trace)
    rep = plane.slo_report()
    assert rep["shed"] > 0, "overload never tripped admission"
    assert rep["served"] > 0
    assert rep["served"] + rep["shed"] == rep["issued"] == len(trace)
    replicas = plane.cluster.replicas
    # every replica executed the identical schedule to the same state ...
    assert (replicas[0].app.snapshot() == replicas[1].app.snapshot()
            == replicas[2].app.snapshot())
    # ... with identical per-slot result vectors (BUSY included)
    maps = [_reply_map(r) for r in replicas]
    assert maps[0] == maps[1] == maps[2]
    busy = {rid for rid, reps in maps[0].items() if b"BUSY" in reps}
    assert busy, "no shed marker executed"
    applied = {rid for rid, reps in maps[0].items()
               if any(rep not in (b"", b"BUSY") for rep in reps)}
    # never torn: a BUSY rid is never also applied, on any replica
    assert not busy & applied
    # agreed stats: every replica sent the same number of BUSY replies
    # (the lifetime counter — _reply_map only sees un-checkpointed slots)
    stats = plane.cluster.stats()["admission"]
    busies = {v["busy_replies"] for v in stats.values()}
    assert len(busies) == 1
    assert busies.pop() >= len(busy) > 0


def test_shed_for_applied_rid_degrades_to_noop():
    """A shed marker that loses the race to a real proposal must not
    overwrite applied state — it executes as a no-op, identically
    everywhere (exercised via _valid_batch/_execute_slot directly)."""
    plane = _flash_plane(queue_high=3)
    r0 = plane.cluster.replicas[0]
    # a shed for an already-executed rid is valid on the wire ...
    rid = ("c999", 0)
    r0.executed_rids.add(rid)
    batch = ((("shed", rid), "", b""),)
    assert r0._valid_batch(batch) is not None
    # ... and executes as a reply-less no-op (dup_sheds, result b"")
    s = r0.exec_upto + 1
    r0.decided[s] = batch
    before = dict(r0.admission_stats)
    r0._execute_slot(s)
    assert r0.results[s] == (b"",)
    assert r0.admission_stats["dup_sheds"] == before["dup_sheds"] + 1
    assert r0.admission_stats["busy_replies"] == before["busy_replies"]


def test_shed_markers_invalid_without_admission():
    """Deployments without admission control reject shed markers at the
    wire (a Byzantine leader cannot smuggle BUSYs into a classic
    deployment)."""
    sub = Substrate(n_pools=1, seed=0)
    c = Cluster.attach(sub, lambda: TokenServerApp(greedy_decode_fn()),
                       name="plain", cfg=_serving_cfg())
    r0 = c.replicas[0]
    batch = ((("shed", ("c0", 0)), "", b""),)
    assert r0._valid_batch(batch) is None


def test_byzantine_overshed_loses_view():
    """A leader shedding honest requests under light load never collects
    an honest certificate quorum: the progress timer fires and the view
    moves — and the request is then served, not shed."""
    plane = _flash_plane(queue_high=8, view_timeout_us=20_000.0)
    cluster = plane.cluster
    leader = cluster.replicas[0]
    assert leader.is_leader()
    # the leader alone runs a zero-threshold admission config: it sheds
    # the very first request while every honest follower sees an empty
    # queue (backlog far below their queue_accept floor of 4)
    leader.cfg = dataclasses.replace(
        leader.cfg, admission=AdmissionConfig(queue_high=-1, queue_accept=0))
    client = cluster.new_client()
    tokens, _lat = plane.server.generate(client, "s0", [1, 2, 3], 2,
                                         timeout=2_000_000.0)
    assert leader.admission_stats["shed"] >= 1   # it really tried
    assert tokens is not None, "honest request was censored"
    live_views = {r.view for r in cluster.replicas[1:]}
    assert max(live_views) > 0, "over-shedding leader kept its view"


# --------------------------------------------------------------------------
# Deferred execution engine
# --------------------------------------------------------------------------
def test_costed_execution_defers_and_stays_deterministic():
    """With a cost model, execution lags decision by the service time —
    and replicas still converge to identical state."""
    cm = ServingCostModel.from_counts("toy-1b", n_params=1e9,
                                      kv_bytes_per_token=26_624, batch=32)
    plane = InferencePlane.build(cm, SLOSpec(deadline_us=50_000.0),
                                 admission=False, cfg=_serving_cfg())
    cluster = plane.cluster
    client = cluster.new_client()
    t0 = cluster.sim.now
    tokens, lat = plane.server.generate(client, "s", [1] * 16, 8)
    assert tokens is not None and len(tokens) == 8
    # the reply cannot arrive before the roofline service time elapsed
    assert lat >= cm.request_us(16, 8) - 1e-6
    assert cluster.sim.now - t0 >= cm.request_us(16, 8)
    snaps = {r.app.snapshot() for r in cluster.replicas}
    assert len(snaps) == 1


def test_costed_engine_survives_crash_mid_decode():
    """Node.timer swallows callbacks that fire while crashed: without
    the recover hook, a replica crashing mid-service would wedge with
    _exec_inflight set forever.  After recovery it must re-enter the
    slot and converge."""
    cm = ServingCostModel.from_counts("toy-1b", n_params=1e9,
                                      kv_bytes_per_token=26_624, batch=32)
    plane = InferencePlane.build(cm, SLOSpec(deadline_us=50_000.0),
                                 admission=False, cfg=_serving_cfg())
    cluster = plane.cluster
    sim = cluster.sim
    client = cluster.new_client()
    victim = cluster.replicas[2]
    done = {}
    payload = json.dumps({"session": "s", "prompt": [1] * 16,
                          "n": 8}).encode()
    client.request(payload, lambda res, lat: done.setdefault("lat", lat))
    # crash the victim the moment its decode engine is busy, stay down
    # past the completion timer, then recover
    sim.run_until(lambda: victim._exec_inflight is not None,
                  timeout=1_000_000.0)
    assert victim._exec_inflight is not None
    victim.crash()
    sim.run(until=sim.now + 3 * cm.request_us(16, 8))
    victim.recover()
    sim.run_until(lambda: "lat" in done, timeout=2_000_000.0)
    assert "lat" in done
    sim.run(until=sim.now + 200_000.0)
    assert victim._exec_inflight is None or victim.exec_upto >= 0
    sim.run_until(lambda: victim.app.snapshot() ==
                  cluster.replicas[0].app.snapshot(), timeout=2_000_000.0)
    assert victim.app.snapshot() == cluster.replicas[0].app.snapshot()


# --------------------------------------------------------------------------
# Snapshot/adopt: joiner mid-generation (satellite)
# --------------------------------------------------------------------------
def test_token_server_joiner_adopts_sessions_mid_generation():
    """Replace a replica in the middle of a multi-turn generation run:
    the joiner adopts the session/KV metadata via the pools and keeps
    decoding bit-identically with the survivors."""
    sub = Substrate(n_pools=2, seed=5)
    server = ReplicatedServer.build(
        greedy_decode_fn(), substrate=sub, name="tok",
        cfg=ConsensusConfig(t=16, window=16, slow_mode="always",
                            ctb_fast_enabled=False,
                            view_timeout_us=20_000.0))
    cluster = server.cluster
    client = cluster.new_client()
    expected = {}
    for turn in range(4):
        toks, _ = server.generate(client, "alice", [10 + turn], 3)
        expected[turn] = toks
    cluster.replicas[2].crash()
    joiner = cluster.replace_replica(cluster.replicas[2].pid)
    assert joiner is not None
    # mid-generation continuation: more turns on the SAME session
    for turn in range(4, 8):
        toks, _ = server.generate(client, "alice", [10 + turn], 3)
        expected[turn] = toks
    cluster.sim.run(until=cluster.sim.now + 100_000.0)
    # the joiner holds the full session history and matches the survivors
    assert joiner.app.snapshot() == cluster.replicas[0].app.snapshot()
    hist = joiner.app.sessions["alice"]
    # history = per-turn [prompt, tok, tok, tok] in order
    assert len(hist) == 8 * 4
    for turn in range(8):
        seg = hist[turn * 4: turn * 4 + 4]
        assert seg[0] == 10 + turn
        assert seg[1:] == expected[turn]
