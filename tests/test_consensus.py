"""uBFT consensus (Algorithms 2-5): fast/slow decisions, checkpoints,
view changes, Byzantine leader containment."""

import pytest

from repro.apps.flip import FlipApp
from repro.apps.kvstore import KVStoreApp, get_req, mset_req, set_req
from repro.core import crypto
from repro.core.consensus import ConsensusConfig
from repro.core.smr import build_cluster


def test_fast_path_microsecond_latency():
    c = build_cluster(FlipApp)
    cl = c.new_client()
    r, lat = c.run_request(cl, b"abcdef")
    assert r == b"fedcba"
    assert lat < 15.0, f"fast path should be ~10 µs, got {lat}"


def test_slow_path_decides_without_fast_path():
    cfg = ConsensusConfig(slow_mode="always", fast_enabled=False,
                          ctb_fast_enabled=False)
    c = build_cluster(KVStoreApp, cfg=cfg)
    cl = c.new_client()
    r, lat = c.run_request(cl, set_req(b"k", b"v"))
    assert r == b"OK"
    assert 50.0 < lat < 2000.0
    r, _ = c.run_request(cl, get_req(b"k"))
    assert r == b"v"


def test_replicas_converge_and_apply_same_order():
    c = build_cluster(KVStoreApp)
    cl = c.new_client()
    for i in range(30):
        c.run_request(cl, set_req(b"k%d" % (i % 3), b"v%d" % i))
    c.sim.run(until=c.sim.now + 5000)
    stores = [r.app.store for r in c.replicas]
    assert stores[0] == stores[1] == stores[2]
    assert len({r.exec_upto for r in c.replicas}) == 1


def test_checkpoint_advances_and_bounds_memory():
    cfg = ConsensusConfig(window=16, t=8)
    c = build_cluster(KVStoreApp, cfg=cfg)
    cl = c.new_client()
    for i in range(40):
        c.run_request(cl, set_req(b"k", b"v%d" % i))
    c.sim.run(until=c.sim.now + 50000)
    for r in c.replicas:
        assert r.checkpoint.start >= 32
        # bounded state: everything below the window is forgotten
        assert all(s >= r.checkpoint.start for s in r.my_prepared)
        assert all(s >= r.checkpoint.start for s in r.decided)
        assert len(r.state["r0"].prepares) <= cfg.window


def test_follower_crash_fast_path_falls_back_to_slow():
    c = build_cluster(KVStoreApp)
    cl = c.new_client()
    c.run_request(cl, set_req(b"a", b"1"))
    c.replicas[2].crash()   # follower crash: fast path loses unanimity
    r, lat = c.run_request(cl, set_req(b"b", b"2"), timeout=5_000_000)
    assert r == b"OK"
    assert lat > 100.0      # decided via the slow path


def test_leader_crash_view_change_preserves_state():
    cfg = ConsensusConfig(view_timeout_us=2000.0)
    c = build_cluster(KVStoreApp, cfg=cfg)
    cl = c.new_client()
    r, _ = c.run_request(cl, set_req(b"a", b"1"))
    assert r == b"OK"
    c.replicas[0].crash()
    r, _ = c.run_request(cl, set_req(b"b", b"2"), timeout=60_000_000)
    assert r == b"OK"
    assert max(x.view for x in c.replicas[1:]) >= 1
    # previously applied request survives the view change (Lemma B.5)
    r, _ = c.run_request(cl, get_req(b"a"), timeout=60_000_000)
    assert r == b"1"
    r, _ = c.run_request(cl, get_req(b"b"), timeout=60_000_000)
    assert r == b"2"


def test_equivocating_leader_cannot_diverge_replicas():
    """A Byzantine leader PREPAREs different requests to different followers
    for the same slot by equivocating at the TBcast layer underneath its
    CTBcast; followers must not decide differently."""
    c = build_cluster(KVStoreApp,
                      cfg=ConsensusConfig(view_timeout_us=3000.0))
    leader = c.replicas[0]
    r1, r2 = c.replicas[1], c.replicas[2]
    cl = c.new_client()

    reqA = (("evil", 0), cl.pid, set_req(b"k", b"A"))
    reqB = (("evil", 0), cl.pid, set_req(b"k", b"B"))
    # byzantine equivocation below CTBcast: different LOCKs per receiver
    stream = leader.my_ctb._s_lock
    leader.tb.broadcast(stream, 0, ("PREPARE", 0, 0, reqA), ["r1"])
    leader.tb.broadcast(stream, 0, ("PREPARE", 0, 0, reqB), ["r2"])
    leader.tb.broadcast(stream, 0, ("PREPARE", 0, 0, reqA), ["r0"])
    c.sim.run(until=c.sim.now + 100000)
    vals = set()
    for rep in (r1, r2):
        if 0 in rep.decided:
            vals.add(crypto.encode(rep.decided[0]))
    assert len(vals) <= 1, "replicas decided different values for slot 0"


def test_byzantine_peer_blocked_on_invalid_message():
    c = build_cluster(KVStoreApp)
    byz = c.replicas[2]
    # broadcast a PREPARE though not the leader — Alg. 5 check must block it
    byz._ctb_broadcast(("PREPARE", 0, 0, (("x", 0), "c0", b"G")))
    c.sim.run(until=c.sim.now + 50000)
    assert c.replicas[0].state["r2"].blocked
    assert c.replicas[1].state["r2"].blocked
    # and the cluster still works (2f+1 with f=1 Byzantine)
    cl = c.new_client()
    r, _ = c.run_request(cl, set_req(b"a", b"1"), timeout=60_000_000)
    assert r == b"OK"


# --------------------------------------------------------------------------
# Batching + pipelining invariants (the batched consensus hot path)
# --------------------------------------------------------------------------
def _submit_concurrent(c, payload_fn, n):
    """n single-shot clients firing concurrently; returns {i: (result, lat)}."""
    clients = [c.new_client() for _ in range(n)]
    done = {}
    for i, cl in enumerate(clients):
        cl.request(payload_fn(i),
                   (lambda i: lambda res, lat: done.__setitem__(i, (res, lat)))(i))
    assert c.sim.run_until(lambda: len(done) == n, timeout=60_000_000)
    return done


def test_batched_slots_coalesce_and_reply_per_request():
    cfg = ConsensusConfig(max_batch=8, pipeline_depth=4, batch_timeout_us=20.0)
    c = build_cluster(KVStoreApp, cfg=cfg)
    done = _submit_concurrent(c, lambda i: set_req(b"k%d" % i, b"v%d" % i), 12)
    # every client got its own reply
    assert all(res == b"OK" for res, _ in done.values())
    c.sim.run(until=c.sim.now + 20000)
    # requests were coalesced: fewer slots than requests, some batch > 1
    batches = [b for _s, b in sorted(c.replicas[0].decided.items())]
    assert sum(len(b) for b in batches) == 12
    assert len(batches) < 12
    assert max(len(b) for b in batches) > 1
    # agreement on batch order: identical decided batches on every replica
    for s, b in c.replicas[0].decided.items():
        for rep in c.replicas[1:]:
            assert crypto.encode(rep.decided[s]) == crypto.encode(b)
    stores = [r.app.store for r in c.replicas]
    assert stores[0] == stores[1] == stores[2]
    assert len(stores[0]) == 12
    # reads see every batched write
    cl = c.new_client()
    for i in range(12):
        r, _ = c.run_request(cl, get_req(b"k%d" % i))
        assert r == b"v%d" % i


def test_batched_workload_survives_follower_crash():
    cfg = ConsensusConfig(max_batch=8, pipeline_depth=4)
    c = build_cluster(KVStoreApp, cfg=cfg)
    done = _submit_concurrent(c, lambda i: set_req(b"a%d" % i, b"1"), 8)
    assert all(res == b"OK" for res, _ in done.values())
    c.replicas[2].crash()   # fast path loses unanimity → slow path
    done = _submit_concurrent(c, lambda i: set_req(b"b%d" % i, b"2"), 8)
    assert all(res == b"OK" for res, _ in done.values())
    stores = [r.app.store for r in c.replicas[:2]]
    assert stores[0] == stores[1]
    assert len(stores[0]) == 16


def test_batched_workload_survives_leader_crash():
    cfg = ConsensusConfig(max_batch=8, pipeline_depth=4,
                          view_timeout_us=2000.0)
    c = build_cluster(KVStoreApp, cfg=cfg)
    done = _submit_concurrent(c, lambda i: set_req(b"a%d" % i, b"1"), 8)
    assert all(res == b"OK" for res, _ in done.values())
    c.replicas[0].crash()
    # in-flight batched requests must survive the view change
    done = _submit_concurrent(c, lambda i: set_req(b"b%d" % i, b"2"), 8)
    assert all(res == b"OK" for res, _ in done.values())
    assert max(x.view for x in c.replicas[1:]) >= 1
    cl = c.new_client()
    for i in range(8):
        r, _ = c.run_request(cl, get_req(b"a%d" % i), timeout=60_000_000)
        assert r == b"1"
        r, _ = c.run_request(cl, get_req(b"b%d" % i), timeout=60_000_000)
        assert r == b"2"


def test_batched_workload_survives_partition():
    cfg = ConsensusConfig(max_batch=8, pipeline_depth=4)
    c = build_cluster(KVStoreApp, cfg=cfg)
    c.sim.gst = 50_000.0
    for other in ("r0", "r1"):
        c.net.partition("r2", other)
        c.net.partition(other, "r2")
    done = _submit_concurrent(c, lambda i: set_req(b"p%d" % i, b"1"), 8)
    assert all(res == b"OK" for res, _ in done.values())
    stores = [r.app.store for r in c.replicas[:2]]
    assert stores[0] == stores[1] and len(stores[0]) == 8
    # after GST the partition heals and the laggard converges
    c.sim.run(until=c.sim.gst + 1000.0)
    c.net.heal()
    c.sim.run(until=c.sim.now + 300_000)
    assert c.replicas[2].app.store == stores[0]


def test_byzantine_leader_equivocating_batches_cannot_diverge():
    """A Byzantine leader PREPAREs different *batches* to different
    followers for the same slot; agreement must hold over batches."""
    cfg = ConsensusConfig(max_batch=8, view_timeout_us=3000.0)
    c = build_cluster(KVStoreApp, cfg=cfg)
    leader = c.replicas[0]
    cl = c.new_client()
    batchA = ((("e", 0), cl.pid, set_req(b"k", b"A")),
              (("e", 1), cl.pid, set_req(b"k2", b"A")))
    batchB = ((("e", 0), cl.pid, set_req(b"k", b"B")),
              (("e", 1), cl.pid, set_req(b"k2", b"B")))
    stream = leader.my_ctb._s_lock
    leader.tb.broadcast(stream, 0, ("PREPARE", 0, 0, batchA), ["r1"])
    leader.tb.broadcast(stream, 0, ("PREPARE", 0, 0, batchB), ["r2"])
    leader.tb.broadcast(stream, 0, ("PREPARE", 0, 0, batchA), ["r0"])
    c.sim.run(until=c.sim.now + 100000)
    vals = set()
    for rep in (c.replicas[1], c.replicas[2]):
        if 0 in rep.decided:
            vals.add(crypto.encode(rep.decided[0]))
    assert len(vals) <= 1, "replicas decided different batches for slot 0"


def test_oversized_batch_blocks_byzantine_leader():
    """A batch exceeding max_batch fails Algorithm 5's structural check and
    permanently blocks the sender."""
    cfg = ConsensusConfig(max_batch=4)
    c = build_cluster(KVStoreApp, cfg=cfg)
    leader = c.replicas[0]
    too_big = tuple((("x", i), "c0", b"G") for i in range(5))
    leader._ctb_broadcast(("PREPARE", 0, 0, too_big))
    c.sim.run(until=c.sim.now + 50000)
    assert c.replicas[1].state["r0"].blocked
    assert c.replicas[2].state["r0"].blocked


def test_batched_memory_stays_bounded():
    cfg = ConsensusConfig(window=16, t=8, max_request_bytes=64,
                          max_batch=8, max_batch_bytes=512, pipeline_depth=4)
    c = build_cluster(KVStoreApp, cfg=cfg)
    cl = c.new_client()
    for wave in range(8):
        c.run_requests(cl, [set_req(b"k%d" % i, b"v%d" % wave)
                            for i in range(8)])
    m1 = c.replicas[0].memory_bytes()
    for wave in range(8):
        c.run_requests(cl, [set_req(b"k%d" % i, b"w%d" % wave)
                            for i in range(8)])
    m2 = c.replicas[0].memory_bytes()
    # steady state: memory does not grow with request count (Table 2)
    assert m2["total"] <= m1["total"] * 1.5
    assert m2["window_actual"] <= m2["window_state"]


def test_unhashable_rid_in_batch_blocks_sender_without_crashing():
    """A Byzantine leader's PREPARE with an unhashable rid must fail the
    structural check and block the sender — not crash honest followers."""
    cfg = ConsensusConfig(max_batch=4)
    c = build_cluster(KVStoreApp, cfg=cfg)
    leader = c.replicas[0]
    evil = (((["un", "hashable"], 0), "c0", b"G"),)
    leader._ctb_broadcast(("PREPARE", 0, 0, evil))
    c.sim.run(until=c.sim.now + 50000)
    assert c.replicas[1].state["r0"].blocked
    assert c.replicas[2].state["r0"].blocked


def test_duplicate_rids_in_batch_block_byzantine_leader():
    """One reply per rid: a batch carrying the same rid twice fails the
    structural check (a duplicate's empty reply could otherwise outvote
    the real one at the client)."""
    cfg = ConsensusConfig(max_batch=4)
    c = build_cluster(KVStoreApp, cfg=cfg)
    leader = c.replicas[0]
    dup = ((("d", 0), "c0", set_req(b"k", b"1")),
           (("d", 0), "c0", set_req(b"k", b"1")))
    leader._ctb_broadcast(("PREPARE", 0, 0, dup))
    c.sim.run(until=c.sim.now + 50000)
    assert c.replicas[1].state["r0"].blocked
    assert c.replicas[2].state["r0"].blocked


def test_oversized_request_gets_error_reply_not_wedge():
    """Payloads over max_request_bytes are answered with a deterministic
    error; the leader is never blocked and the cluster keeps serving."""
    cfg = ConsensusConfig(max_request_bytes=128, max_batch=4)
    c = build_cluster(KVStoreApp, cfg=cfg)
    cl = c.new_client()
    r, _ = c.run_request(cl, set_req(b"big", b"x" * 500))
    assert r == b"ERR_REQUEST_TOO_LARGE"
    assert not any(c.replicas[i].state["r0"].blocked for i in (1, 2))
    r, _ = c.run_request(cl, set_req(b"k", b"v"))
    assert r == b"OK"


def test_late_client_copy_after_decide_causes_no_view_change():
    """A follower whose direct client copy is delayed past the decision
    must clear its endorse-wait at decide time — no spurious view change."""
    cfg = ConsensusConfig(view_timeout_us=2000.0, slow_after_us=200.0)
    c = build_cluster(KVStoreApp, cfg=cfg)
    c.sim.gst = 30_000.0
    c.net.delay_link("c0", "r1", 20_000.0)   # REQ to r1 arrives very late
    cl = c.new_client()
    r, _ = c.run_request(cl, set_req(b"a", b"1"), timeout=60_000_000)
    assert r == b"OK"
    c.sim.run(until=c.sim.gst + 30_000.0)    # late copy lands, timers fire
    assert not c.replicas[1].waiting_prepare
    assert not c.replicas[1].prepare_missing
    assert all(rep.view == 0 for rep in c.replicas), \
        "decided slot must not leave pending waits that force a view change"


def test_malformed_mset_rejected_atomically():
    app = KVStoreApp()
    truncated = b"M\x02" + bytes([1]) + b"k" + bytes([1]) + b"v"  # claims 2
    assert app.apply(truncated) == b"ERR"
    assert app.store == {}
    assert app.apply(mset_req([(b"a", b"1")])) == b"OK"
    assert app.store == {b"a": b"1"}


def test_app_level_multi_put_composes_with_slot_batching():
    cfg = ConsensusConfig(max_batch=4, pipeline_depth=2)
    c = build_cluster(KVStoreApp, cfg=cfg)
    cl = c.new_client()
    r, _ = c.run_request(cl, mset_req([(b"a", b"1"), (b"b", b"22")]))
    assert r == b"OK"
    assert c.run_request(cl, get_req(b"a"))[0] == b"1"
    assert c.run_request(cl, get_req(b"b"))[0] == b"22"


def test_memory_accounting_reports_bounded_buffers():
    cfg = ConsensusConfig(window=16, t=8, max_request_bytes=64)
    c = build_cluster(KVStoreApp, cfg=cfg)
    cl = c.new_client()
    for i in range(50):
        c.run_request(cl, set_req(b"k", b"v%d" % i))
    m1 = c.replicas[0].memory_bytes()
    for i in range(50):
        c.run_request(cl, set_req(b"k", b"w%d" % i))
    m2 = c.replicas[0].memory_bytes()
    # steady state: memory does not grow with request count
    assert m2["total"] <= m1["total"] * 1.5
