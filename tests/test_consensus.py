"""uBFT consensus (Algorithms 2-5): fast/slow decisions, checkpoints,
view changes, Byzantine leader containment."""

import pytest

from repro.apps.flip import FlipApp
from repro.apps.kvstore import KVStoreApp, get_req, set_req
from repro.core import crypto
from repro.core.consensus import ConsensusConfig
from repro.core.smr import build_cluster


def test_fast_path_microsecond_latency():
    c = build_cluster(FlipApp)
    cl = c.new_client()
    r, lat = c.run_request(cl, b"abcdef")
    assert r == b"fedcba"
    assert lat < 15.0, f"fast path should be ~10 µs, got {lat}"


def test_slow_path_decides_without_fast_path():
    cfg = ConsensusConfig(slow_mode="always", fast_enabled=False,
                          ctb_fast_enabled=False)
    c = build_cluster(KVStoreApp, cfg=cfg)
    cl = c.new_client()
    r, lat = c.run_request(cl, set_req(b"k", b"v"))
    assert r == b"OK"
    assert 50.0 < lat < 2000.0
    r, _ = c.run_request(cl, get_req(b"k"))
    assert r == b"v"


def test_replicas_converge_and_apply_same_order():
    c = build_cluster(KVStoreApp)
    cl = c.new_client()
    for i in range(30):
        c.run_request(cl, set_req(b"k%d" % (i % 3), b"v%d" % i))
    c.sim.run(until=c.sim.now + 5000)
    stores = [r.app.store for r in c.replicas]
    assert stores[0] == stores[1] == stores[2]
    assert len({r.exec_upto for r in c.replicas}) == 1


def test_checkpoint_advances_and_bounds_memory():
    cfg = ConsensusConfig(window=16, t=8)
    c = build_cluster(KVStoreApp, cfg=cfg)
    cl = c.new_client()
    for i in range(40):
        c.run_request(cl, set_req(b"k", b"v%d" % i))
    c.sim.run(until=c.sim.now + 50000)
    for r in c.replicas:
        assert r.checkpoint.start >= 32
        # bounded state: everything below the window is forgotten
        assert all(s >= r.checkpoint.start for s in r.my_prepared)
        assert all(s >= r.checkpoint.start for s in r.decided)
        assert len(r.state["r0"].prepares) <= cfg.window


def test_follower_crash_fast_path_falls_back_to_slow():
    c = build_cluster(KVStoreApp)
    cl = c.new_client()
    c.run_request(cl, set_req(b"a", b"1"))
    c.replicas[2].crash()   # follower crash: fast path loses unanimity
    r, lat = c.run_request(cl, set_req(b"b", b"2"), timeout=5_000_000)
    assert r == b"OK"
    assert lat > 100.0      # decided via the slow path


def test_leader_crash_view_change_preserves_state():
    cfg = ConsensusConfig(view_timeout_us=2000.0)
    c = build_cluster(KVStoreApp, cfg=cfg)
    cl = c.new_client()
    r, _ = c.run_request(cl, set_req(b"a", b"1"))
    assert r == b"OK"
    c.replicas[0].crash()
    r, _ = c.run_request(cl, set_req(b"b", b"2"), timeout=60_000_000)
    assert r == b"OK"
    assert max(x.view for x in c.replicas[1:]) >= 1
    # previously applied request survives the view change (Lemma B.5)
    r, _ = c.run_request(cl, get_req(b"a"), timeout=60_000_000)
    assert r == b"1"
    r, _ = c.run_request(cl, get_req(b"b"), timeout=60_000_000)
    assert r == b"2"


def test_equivocating_leader_cannot_diverge_replicas():
    """A Byzantine leader PREPAREs different requests to different followers
    for the same slot by equivocating at the TBcast layer underneath its
    CTBcast; followers must not decide differently."""
    c = build_cluster(KVStoreApp,
                      cfg=ConsensusConfig(view_timeout_us=3000.0))
    leader = c.replicas[0]
    r1, r2 = c.replicas[1], c.replicas[2]
    cl = c.new_client()

    reqA = (("evil", 0), cl.pid, set_req(b"k", b"A"))
    reqB = (("evil", 0), cl.pid, set_req(b"k", b"B"))
    # byzantine equivocation below CTBcast: different LOCKs per receiver
    stream = leader.my_ctb._s_lock
    leader.tb.broadcast(stream, 0, ("PREPARE", 0, 0, reqA), ["r1"])
    leader.tb.broadcast(stream, 0, ("PREPARE", 0, 0, reqB), ["r2"])
    leader.tb.broadcast(stream, 0, ("PREPARE", 0, 0, reqA), ["r0"])
    c.sim.run(until=c.sim.now + 100000)
    vals = set()
    for rep in (r1, r2):
        if 0 in rep.decided:
            vals.add(crypto.encode(rep.decided[0]))
    assert len(vals) <= 1, "replicas decided different values for slot 0"


def test_byzantine_peer_blocked_on_invalid_message():
    c = build_cluster(KVStoreApp)
    byz = c.replicas[2]
    # broadcast a PREPARE though not the leader — Alg. 5 check must block it
    byz._ctb_broadcast(("PREPARE", 0, 0, (("x", 0), "c0", b"G")))
    c.sim.run(until=c.sim.now + 50000)
    assert c.replicas[0].state["r2"].blocked
    assert c.replicas[1].state["r2"].blocked
    # and the cluster still works (2f+1 with f=1 Byzantine)
    cl = c.new_client()
    r, _ = c.run_request(cl, set_req(b"a", b"1"), timeout=60_000_000)
    assert r == b"OK"


def test_memory_accounting_reports_bounded_buffers():
    cfg = ConsensusConfig(window=16, t=8, max_request_bytes=64)
    c = build_cluster(KVStoreApp, cfg=cfg)
    cl = c.new_client()
    for i in range(50):
        c.run_request(cl, set_req(b"k", b"v%d" % i))
    m1 = c.replicas[0].memory_bytes()
    for i in range(50):
        c.run_request(cl, set_req(b"k", b"w%d" % i))
    m2 = c.replicas[0].memory_bytes()
    # steady state: memory does not grow with request count
    assert m2["total"] <= m1["total"] * 1.5
