"""Hypothesis property tests over the system's invariants.

Strategy space: random request workloads, network jitter seeds, crash/
partition schedules, CTBcast tails — asserting the protocol's safety
invariants (agreement, integrity, bounded memory) always hold.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import HealthCheck, given, settings

from repro.apps.kvstore import KVStoreApp, set_req
from repro.core import crypto
from repro.core.consensus import ConsensusConfig
from repro.core.smr import build_cluster
from repro.sim.net import NetParams

COMMON = dict(deadline=None, max_examples=12,
              suppress_health_check=[HealthCheck.too_slow])


@settings(**COMMON)
@given(seed=st.integers(0, 10_000), n_reqs=st.integers(1, 12),
       t=st.sampled_from([8, 16, 64]))
def test_agreement_and_order_random_workloads(seed, n_reqs, t):
    cfg = ConsensusConfig(t=t, window=max(16, t))
    c = build_cluster(KVStoreApp, cfg=cfg, seed=seed)
    cl = c.new_client()
    for i in range(n_reqs):
        r, _ = c.run_request(cl, set_req(b"k%d" % (i % 4), b"v%d" % i))
        assert r == b"OK"
    c.sim.run(until=c.sim.now + 20000)
    # all replicas executed the same prefix with identical state
    stores = [rep.app.store for rep in c.replicas]
    assert stores[0] == stores[1] == stores[2]
    decided = [dict(rep.decided) for rep in c.replicas]
    for s in set(decided[0]) & set(decided[1]):
        assert crypto.encode(decided[0][s]) == crypto.encode(decided[1][s])


@settings(**COMMON)
@given(seed=st.integers(0, 10_000),
       crash_idx=st.sampled_from([1, 2]),
       n_reqs=st.integers(2, 8))
def test_safety_under_follower_crash(seed, crash_idx, n_reqs):
    c = build_cluster(KVStoreApp, seed=seed)
    cl = c.new_client()
    for i in range(n_reqs):
        if i == n_reqs // 2:
            c.replicas[crash_idx].crash()
        r, _ = c.run_request(cl, set_req(b"k", b"v%d" % i),
                             timeout=30_000_000)
        assert r == b"OK"
    alive = [rep for rep in c.replicas if not rep.crashed]
    c.sim.run(until=c.sim.now + 50000)
    assert alive[0].app.store == alive[1].app.store


@settings(**COMMON)
@given(seed=st.integers(0, 10_000), jitter=st.floats(0.0, 0.5))
def test_fast_path_latency_distribution_bounded(seed, jitter):
    params = NetParams(jitter_sigma=jitter)
    c = build_cluster(KVStoreApp, params=params, seed=seed)
    cl = c.new_client()
    for i in range(5):
        r, lat = c.run_request(cl, set_req(b"a", b"b"), timeout=10_000_000)
        assert r == b"OK"
        assert lat < 5000.0   # escalation bound: never unbounded


@settings(**COMMON)
@given(data=st.binary(min_size=0, max_size=512))
def test_crypto_roundtrip_and_unforgeability(data):
    reg = crypto.KeyRegistry()
    s_alice = reg.keygen("alice")
    s_bob = reg.keygen("bob")
    sig = s_alice.sign(data)
    assert reg.verify("alice", data, sig)
    assert not reg.verify("bob", data, sig)
    assert not reg.verify("alice", data + b"x", sig)
    assert not reg.verify("alice", data, s_bob.sign(data))


@settings(**COMMON)
@given(obj=st.recursive(
    st.one_of(st.none(), st.booleans(), st.integers(-2**40, 2**40),
              st.binary(max_size=32), st.text(max_size=16)),
    lambda children: st.tuples(children, children), max_leaves=8))
def test_encode_decode_roundtrip(obj):
    assert crypto.decode(crypto.encode(obj)) == obj


@settings(**COMMON)
@given(seed=st.integers(0, 1000), t=st.sampled_from([4, 8, 16]))
def test_ctbcast_memory_bounded_regardless_of_load(seed, t):
    from repro.baselines.sgx_counter import build_ctbcast
    sim, nodes, deliv = build_ctbcast(t=t, fast=True, seed=seed)
    for k in range(6 * t):
        nodes[0].ctb.broadcast(k, b"x" * 32)
        sim.run(until=sim.now + 30)
    sim.run(until=sim.now + 50000)
    for n in nodes:
        assert len(n.ctb.buf) <= 2 * t
        assert len(n.ctb.locks) == t
        for q in n.ctb.locked.values():
            assert len(q) == t


def _register_rig(seed):
    from repro.core.node import Node
    from repro.core.registers import MemoryNode, RegisterClient
    from repro.sim.events import Simulator
    from repro.sim.net import NetworkModel

    class Host(Node):
        pass

    sim = Simulator(seed=seed)
    net = NetworkModel(sim)
    reg = crypto.KeyRegistry()
    mems = [MemoryNode(sim, net, reg, f"m{i}") for i in range(3)]
    wc = RegisterClient(Host(sim, net, reg, "w0"), [m.pid for m in mems], 1)
    rc = RegisterClient(Host(sim, net, reg, "q0"), [m.pid for m in mems], 1)
    return sim, wc, rc


@settings(**COMMON)
@given(seed=st.integers(0, 10_000), n_writes=st.integers(1, 5),
       gaps=st.lists(st.floats(0.0, 30.0), min_size=1, max_size=5),
       read_times=st.lists(st.floats(0.0, 250.0), min_size=1, max_size=8))
def test_register_regularity_under_torn_reads(seed, n_writes, gaps,
                                              read_times):
    """SWMR regularity under interleaved WRITE/READ timings (§6.1): a READ
    never returns a value older than the last WRITE that completed before
    the READ started, never returns a value that was never written, and
    never fabricates a Byzantine verdict for an honest writer — even when
    READs land inside write windows and see torn 8-byte splices."""
    sim, wc, rc = _register_rig(seed)
    values = {i + 1: b"w%03d" % i * 3 for i in range(n_writes)}
    acked = []      # completion times, in ts order (writes are chained)

    def write(i=0):
        if i > 0:
            acked.append(sim.now)
        if i < n_writes:
            gap = gaps[i % len(gaps)]
            sim.after(gap, lambda: wc.write("reg", values[i + 1],
                                            lambda: write(i + 1)))

    write()
    reads = []

    def issue(rt):
        start = sim.now
        rc.read("w0", "reg",
                lambda val, byz: reads.append((start, val, byz)))

    for rt in read_times:
        sim.after(rt, lambda rt=rt: issue(rt))
    assert sim.run_until(
        lambda: len(reads) == len(read_times) and len(acked) == n_writes,
        timeout=10_000_000)
    for start, val, byz in reads:
        assert byz is False, "honest writer flagged Byzantine"
        floor = sum(1 for t_ack in acked if t_ack < start)
        if val is None:
            # ⊥ is regular only while no WRITE had completed
            assert floor == 0
        else:
            ts, data = val
            assert data == values[ts], "fabricated value"
            assert ts >= floor, (ts, floor)


@settings(deadline=None, max_examples=6,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 10_000), crash_at=st.integers(1, 6))
def test_leader_crash_at_random_point_is_safe(seed, crash_at):
    """Crash the leader after a random number of requests; whatever was
    applied before the crash must survive the view change (Lemma B.5)."""
    cfg = ConsensusConfig(view_timeout_us=2000.0)
    c = build_cluster(KVStoreApp, cfg=cfg, seed=seed)
    cl = c.new_client()
    applied = {}
    for i in range(crash_at):
        r, _ = c.run_request(cl, set_req(b"k%d" % i, b"v%d" % i),
                             timeout=60_000_000)
        assert r == b"OK"
        applied[b"k%d" % i] = b"v%d" % i
    c.replicas[0].crash()
    r, _ = c.run_request(cl, set_req(b"post", b"crash"), timeout=120_000_000)
    assert r == b"OK"
    for rep in c.replicas[1:]:
        for k, v in applied.items():
            assert rep.app.store.get(k) == v, (seed, crash_at, k)
