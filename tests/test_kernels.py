"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles
(interpret mode — the kernel bodies execute on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KV,dh,w", [
    (1, 32, 2, 2, 8, 8),
    (2, 64, 4, 2, 16, 16),
    (1, 96, 4, 1, 32, 32),    # S not a multiple of 2w — exercises padding
    (2, 128, 8, 4, 16, 32),
])
def test_swa_vs_oracle(B, S, H, KV, dh, w, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, dh), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, dh), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, dh), jnp.float32).astype(dtype)
    out = ops.sliding_window_attention(q, k, v, window=w)
    G = H // KV
    qp = q.reshape(B, S, KV, G, dh).transpose(0, 2, 3, 1, 4).reshape(
        B * KV * G, S, dh)
    kp = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * KV * G, S, dh)
    vp = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * KV * G, S, dh)
    want = ref.swa_ref(qp.astype(jnp.float32), kp.astype(jnp.float32),
                       vp.astype(jnp.float32), window=w)
    want = want.reshape(B, KV, G, S, dh).transpose(0, 3, 1, 2, 4).reshape(
        B, S, H, dh)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,dh,chunk", [
    (1, 32, 2, 8, 8),
    (2, 64, 2, 16, 16),
    (1, 64, 4, 32, 32),
    (1, 48, 2, 16, 16),       # padded tail chunk
])
def test_mlstm_vs_sequential_oracle(B, S, H, dh, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    q = (jax.random.normal(ks[0], (B, S, H, dh)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (B, S, H, dh)) * 0.5).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, H, dh)).astype(dtype)
    it = jax.random.normal(ks[3], (B, S, H))
    ft = jax.random.normal(ks[4], (B, S, H)) + 2.0
    out = ops.mlstm_chunkwise(q, k, v, it, ft, chunk=chunk)

    def plane(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, -1)

    want = ref.mlstm_ref(plane(q.astype(jnp.float32)),
                         plane(k.astype(jnp.float32)),
                         plane(v.astype(jnp.float32)),
                         plane(it[..., None]), plane(ft[..., None]))
    want = want.reshape(B, H, S, dh).transpose(0, 2, 1, 3)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("B,S,W,tb", [
    (1, 32, 16, 8),
    (2, 128, 64, 32),
    (1, 100, 32, 25),
    (3, 64, 8, 64),
])
def test_rglru_vs_associative_scan(B, S, W, tb):
    ks = jax.random.split(KEY, 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W)))
    x = jax.random.normal(ks[1], (B, S, W))
    out = ops.rglru_scan(a, x, t_blk=tb)
    want = ref.rglru_ref(a, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,dtype", [
    (100, jnp.float32), (4096, jnp.float32), (5000, jnp.bfloat16),
    (12345, jnp.int32),
])
def test_fingerprint_matches_ref_and_attest(n, dtype):
    x = (jax.random.normal(KEY, (n,)) * 100).astype(dtype)
    got = ops.fingerprint(x)
    if dtype == jnp.bfloat16:
        w = jax.lax.bitcast_convert_type(x, jnp.uint16).astype(jnp.uint32)
    elif dtype == jnp.int32:
        w = x.astype(jnp.uint32)
    else:
        w = jax.lax.bitcast_convert_type(x, jnp.uint32)
    want = ref.fingerprint_ref(w)
    assert int(got[0]) == int(want[0])
    # sensitivity: flipping one element changes the digest
    x2 = x.at[n // 2].set(x[n // 2] + 1)
    assert int(ops.fingerprint(x2)[0]) != int(got[0])


def test_fingerprint_consistent_with_runtime_attest():
    from repro.runtime.attest import fingerprint_array
    x = jax.random.normal(KEY, (777,), jnp.float32)
    assert int(ops.fingerprint(x)[0]) == int(fingerprint_array(x))
